.PHONY: artifacts build test pytest bench perf figures clean

# AOT-lower the MiniMixtral stages to HLO text + weights + goldens.
# Needs jax installed; everything else in the repo runs without it.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

pytest:
	cd python && python3 -m pytest tests -q

bench:
	cargo bench

# Transfer-pipeline perf gate: demand-miss stall sync vs pipelined + pool
# reuse rate; writes BENCH_transfer_pipeline.json in the repo root.
perf:
	cargo bench --bench transfer_pipeline

figures:
	cargo run --release -- figures --out-dir results

clean:
	rm -rf target results
