.PHONY: artifacts build test pytest bench figures clean

# AOT-lower the MiniMixtral stages to HLO text + weights + goldens.
# Needs jax installed; everything else in the repo runs without it.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

pytest:
	cd python && python3 -m pytest tests -q

bench:
	cargo bench

figures:
	cargo run --release -- figures --out-dir results

clean:
	rm -rf target results
