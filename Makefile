.PHONY: artifacts build test pytest bench perf figures clean

# AOT-lower the MiniMixtral stages to HLO text + weights + goldens.
# Needs jax installed; everything else in the repo runs without it.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

pytest:
	cd python && python3 -m pytest tests -q

bench:
	cargo bench

# Perf gates, each writing a BENCH_*.json in the repo root:
# transfer_pipeline — demand-miss stall sync vs pipelined + pool reuse;
# serve_concurrent — scheduler throughput, shared-cache amortization,
# overload rejected/shed counts + queue-wait p99, and mixed long/short
# TTFT p50/p99 with chunked prefill on vs off (fields asserted below);
# tiered_store — RAM-budget sweep over the disk tier: per-budget RAM hit
# rate + disk read p99 (monotonicity and cliff asserted in the bench);
# predictor — learned cross-layer predictor: per-layer top-k accuracy and
# learned-eviction hit rate vs LRU/LFU/Belady (learned must beat both
# online baselines and close part of the LRU→Belady gap, asserted in the
# bench).
perf:
	cargo bench --bench transfer_pipeline
	cargo bench --bench serve_concurrent
	cargo bench --bench tiered_store
	cargo bench --bench predictor
	@grep -q '"ttft_p50_ns"' BENCH_serve_concurrent.json || \
		{ echo "BENCH_serve_concurrent.json missing TTFT p50"; exit 1; }
	@grep -q '"ttft_p99_ns"' BENCH_serve_concurrent.json || \
		{ echo "BENCH_serve_concurrent.json missing TTFT p99"; exit 1; }
	@grep -q '"ram_hit_rate"' BENCH_tiered_store.json || \
		{ echo "BENCH_tiered_store.json missing RAM hit rate"; exit 1; }
	@grep -q '"disk_read_p99_ns"' BENCH_tiered_store.json || \
		{ echo "BENCH_tiered_store.json missing disk read p99"; exit 1; }
	@grep -q '"topk_accuracy"' BENCH_predictor.json || \
		{ echo "BENCH_predictor.json missing top-k accuracy"; exit 1; }
	@grep -q '"gap_closed_vs_belady"' BENCH_predictor.json || \
		{ echo "BENCH_predictor.json missing Belady gap fraction"; exit 1; }

figures:
	cargo run --release -- figures --out-dir results

clean:
	rm -rf target results
