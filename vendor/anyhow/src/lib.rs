//! Minimal offline subset of the `anyhow` error-handling API.
//!
//! The build environment for this repository has no crates.io access, so the
//! pieces of `anyhow` the codebase actually uses are vendored here:
//!
//! * [`Error`] — an opaque error holding a human-readable cause chain;
//! * [`Result<T>`](Result) — `Result<T, Error>`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — error construction macros;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, prepending a message to the chain.
//!
//! Semantics intentionally mirror the real crate where the codebase depends
//! on them: `{}` displays the outermost message, `{:#}` displays the whole
//! chain separated by `": "`, and `?` converts any `std::error::Error`.
//! Downcasting and backtraces are not implemented (nothing here uses them).

use std::fmt;

/// `Result<T, anyhow::Error>`, the crate-wide error-carrying result.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with a message chain, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` produces).
    pub fn new(msg: String) -> Error {
        Error { chain: vec![msg] }
    }

    /// Construct from a displayable value.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error::new(msg.to_string())
    }

    /// Prepend a context message (what `.context(..)` produces).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    fn from_std(err: &(dyn std::error::Error + 'static)) -> Error {
        let mut chain = vec![err.to_string()];
        let mut cur = err.source();
        while let Some(src) = cur {
            chain.push(src.to_string());
            cur = src.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first — matches anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes the blanket `From` below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::from_std(&err)
    }
}

mod private {
    /// Sealed conversion used by [`super::Context`] so the trait can be
    /// implemented for both `Result<T, E: std::error::Error>` and
    /// `Result<T, anyhow::Error>` without overlap.
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> super::Error {
            super::Error::from_std(&self)
        }
    }
}

/// Attach context to errors, as in the real `anyhow`.
pub trait Context<T>: Sized {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: private::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::new(context.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::new(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (captures like `format!`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::new(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::new(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "file missing");
    }

    #[test]
    fn context_prepends_and_alternate_shows_chain() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: file missing");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let e = Err::<(), Error>(anyhow!("inner {}", 7))
            .context("outer")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
        let e = None::<u32>.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }

    #[test]
    fn macros_build_messages() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x {x} too large");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{}", f(12).unwrap_err()), "x 12 too large");
    }

    #[test]
    fn debug_renders_cause_chain() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading weights").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("loading weights"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("file missing"));
    }
}
