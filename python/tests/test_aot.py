"""AOT artifact integrity: HLO text parses, manifest complete, golden sane.

Uses the TINY config into a tmpdir so the test is self-contained and fast;
the shipped artifacts/ directory is produced by the same code path.
"""

import json
import os
import subprocess
import sys

import pytest

ART = None


@pytest.fixture(scope="module")
def art_dir(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--tiny", "--out-dir", out,
         "--golden-tokens", "2"],
        cwd=root, check=True, capture_output=True, env=env,
    )
    return out


def test_manifest_complete(art_dir):
    m = json.load(open(os.path.join(art_dir, "manifest.json")))
    names = {s["name"] for s in m["stages"]}
    assert names == {"embed", "attn", "router", "expert", "final"}
    for s in m["stages"]:
        assert os.path.exists(os.path.join(art_dir, s["file"]))
        assert s["inputs"] and s["outputs"]
    assert os.path.exists(os.path.join(art_dir, m["weights"]))
    assert os.path.exists(os.path.join(art_dir, m["testvec"]))


def test_hlo_text_is_parsable_module(art_dir):
    """HLO text artifacts must look like `HloModule ...` with an ENTRY."""
    m = json.load(open(os.path.join(art_dir, "manifest.json")))
    for s in m["stages"]:
        text = open(os.path.join(art_dir, s["file"])).read()
        assert text.startswith("HloModule"), s["name"]
        assert "ENTRY" in text, s["name"]
        # 0.5.1 gate: HLO *text* interchange, never serialized protos
        assert "\0" not in text


def test_stage_shapes_match_config(art_dir):
    m = json.load(open(os.path.join(art_dir, "manifest.json")))
    cfg = m["config"]
    st = {s["name"]: s for s in m["stages"]}
    h, v, e, f = cfg["hidden_size"], cfg["vocab_size"], cfg["n_experts"], cfg["ffn_size"]
    assert st["embed"]["inputs"][1]["shape"] == [v, h]
    assert st["router"]["inputs"][2]["shape"] == [h, e]
    assert st["router"]["outputs"][1]["shape"] == [1, e]
    assert st["expert"]["inputs"][1]["shape"] == [h, f]
    assert st["expert"]["outputs"][0]["shape"] == [1, h]
    assert st["final"]["outputs"][0]["shape"] == [1, v]


def test_golden_decode_structure(art_dir):
    m = json.load(open(os.path.join(art_dir, "manifest.json")))
    tv = json.load(open(os.path.join(art_dir, "testvec.json")))
    cfg = m["config"]
    dec = tv["decode"]
    assert len(dec["steps"]) == len(dec["prompt"]) + dec["n_gen"]
    for step in dec["steps"]:
        assert len(step["experts"]) == cfg["n_layers"]
        for sel, w in zip(step["experts"], step["expert_weights"]):
            assert len(sel) == cfg["top_k"]
            assert len(set(sel)) == cfg["top_k"]
            assert all(0 <= x < cfg["n_experts"] for x in sel)
            assert abs(sum(w) - 1.0) < 1e-4
        assert 0 <= step["argmax"] < cfg["vocab_size"]


def test_golden_continuity(art_dir):
    """Generated token at step t equals argmax of step t-1 (greedy)."""
    tv = json.load(open(os.path.join(art_dir, "testvec.json")))
    dec = tv["decode"]
    n_prompt = len(dec["prompt"])
    for i, step in enumerate(dec["steps"]):
        assert step["pos"] == i
        if i >= n_prompt:
            assert step["token"] == dec["steps"][i - 1]["argmax"]


def test_stage_vectors_present(art_dir):
    tv = json.load(open(os.path.join(art_dir, "testvec.json")))
    sv = tv["stages"]
    for key in ("x", "embed_tok3", "attn_x_res", "router_h", "router_probs",
                "expert0_y", "final_logits_sum", "final_logits_first8"):
        assert key in sv
    assert abs(sum(sv["router_probs"]) - 1.0) < 1e-5
