"""L2 model tests: stage shapes, composition, and MoE semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import weights as weights_mod
from compile.kernels import ref
from compile.model import TINY, forward_token, make_stages, topk_renorm

jax.config.update("jax_platform_name", "cpu")

CFG = TINY


@pytest.fixture(scope="module")
def params():
    return {k: jnp.asarray(v) for k, v in weights_mod.generate(CFG, seed=0).items()}


@pytest.fixture(scope="module")
def stages():
    return make_stages(CFG)


def test_stage_output_shapes(stages, params):
    """Every stage produces the shapes the manifest promises."""
    for name, (fn, example_args) in stages.items():
        outs = jax.eval_shape(fn, *example_args)
        outs = outs if isinstance(outs, tuple) else (outs,)
        for o in outs:
            assert all(d > 0 for d in o.shape), f"{name}: bad shape {o.shape}"


def test_embed_is_table_row(stages, params):
    (x,) = stages["embed"][0](jnp.asarray([5], jnp.int32), params["embed.table"])
    np.testing.assert_allclose(x[0], params["embed.table"][5], rtol=1e-6)


def test_attn_residual_property(stages, params):
    """With zero o-projection, attention must be the identity (residual)."""
    h, s, nh, hd = CFG.hidden_size, CFG.max_seq, CFG.n_heads, CFG.head_dim
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (1, h)).astype(np.float32))
    kc = jnp.zeros((s, nh, hd))
    vc = jnp.zeros((s, nh, hd))
    x_res, _, _ = stages["attn"][0](
        x, params["layer.0.ln1"], params["layer.0.wq"], params["layer.0.wk"],
        params["layer.0.wv"], jnp.zeros((h, h)), kc, vc, jnp.int32(0),
    )
    np.testing.assert_allclose(x_res, x, rtol=1e-6)


def test_attn_kv_cache_written_at_pos(stages, params):
    h, s, nh, hd = CFG.hidden_size, CFG.max_seq, CFG.n_heads, CFG.head_dim
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (1, h)).astype(np.float32))
    kc = jnp.zeros((s, nh, hd))
    vc = jnp.zeros((s, nh, hd))
    pos = 3
    _, kc2, vc2 = stages["attn"][0](
        x, params["layer.0.ln1"], params["layer.0.wq"], params["layer.0.wk"],
        params["layer.0.wv"], params["layer.0.wo"], kc, vc, jnp.int32(pos),
    )
    # only row `pos` may be non-zero
    assert float(jnp.abs(kc2[pos]).sum()) > 0
    mask = jnp.arange(s) != pos
    assert float(jnp.abs(kc2[mask]).sum()) == 0
    assert float(jnp.abs(vc2[mask]).sum()) == 0


def test_attn_causality(stages, params):
    """Writing garbage into FUTURE cache rows must not change the output."""
    h, s, nh, hd = CFG.hidden_size, CFG.max_seq, CFG.n_heads, CFG.head_dim
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 1, (1, h)).astype(np.float32))
    args = (
        params["layer.0.ln1"], params["layer.0.wq"], params["layer.0.wk"],
        params["layer.0.wv"], params["layer.0.wo"],
    )
    pos = 4
    kc = jnp.zeros((s, nh, hd))
    vc = jnp.zeros((s, nh, hd))
    a1, _, _ = stages["attn"][0](x, *args, kc, vc, jnp.int32(pos))
    poison = jnp.asarray(rng.normal(0, 9, (s, nh, hd)).astype(np.float32))
    future = (jnp.arange(s) > pos)[:, None, None]
    kc_p = jnp.where(future, poison, kc)
    vc_p = jnp.where(future, poison, vc)
    a2, _, _ = stages["attn"][0](x, *args, kc_p, vc_p, jnp.int32(pos))
    np.testing.assert_allclose(a1, a2, rtol=1e-5, atol=1e-6)


def test_router_probs_normalized(stages, params):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, (1, CFG.hidden_size)).astype(np.float32))
    hn, probs = stages["router"][0](x, params["layer.0.ln2"], params["layer.0.gate"])
    np.testing.assert_allclose(jnp.sum(probs), 1.0, rtol=1e-5)
    np.testing.assert_allclose(
        hn, ref.rmsnorm_ref(x, params["layer.0.ln2"], CFG.rms_eps), rtol=1e-5
    )


def test_topk_renorm():
    probs = jnp.asarray([[0.05, 0.4, 0.1, 0.25, 0.05, 0.05, 0.05, 0.05]])
    idx, w = topk_renorm(probs, 2)
    assert set(np.asarray(idx).tolist()) == {1, 3}
    np.testing.assert_allclose(jnp.sum(w), 1.0, rtol=1e-6)
    np.testing.assert_allclose(w[0] / w[1], 0.4 / 0.25, rtol=1e-5)


def test_forward_token_runs_and_traces(params):
    s, nh, hd = CFG.max_seq, CFG.n_heads, CFG.head_dim
    kcs = [jnp.zeros((s, nh, hd)) for _ in range(CFG.n_layers)]
    vcs = [jnp.zeros((s, nh, hd)) for _ in range(CFG.n_layers)]
    logits, kcs, vcs, trace = forward_token(
        CFG, params, jnp.asarray([1], jnp.int32), kcs, vcs, jnp.int32(0)
    )
    assert logits.shape == (1, CFG.vocab_size)
    assert len(trace) == CFG.n_layers
    for idx, w, probs in trace:
        assert idx.shape == (CFG.top_k,)
        assert len(set(np.asarray(idx).tolist())) == CFG.top_k  # distinct experts
        np.testing.assert_allclose(jnp.sum(w), 1.0, rtol=1e-5)


def test_forward_deterministic(params):
    """Same token, same caches -> bit-identical logits (semantic transparency
    baseline: the rust cache layers must preserve exactly this)."""
    s, nh, hd = CFG.max_seq, CFG.n_heads, CFG.head_dim

    def run():
        kcs = [jnp.zeros((s, nh, hd)) for _ in range(CFG.n_layers)]
        vcs = [jnp.zeros((s, nh, hd)) for _ in range(CFG.n_layers)]
        logits, *_ = forward_token(
            CFG, params, jnp.asarray([2], jnp.int32), kcs, vcs, jnp.int32(0)
        )
        return np.asarray(logits)

    np.testing.assert_array_equal(run(), run())


def test_gate_imbalance_shaping():
    """weights.py §docstring: mid-network gate columns are more skewed."""
    p = weights_mod.generate(TINY, seed=0)
    norms_first = np.linalg.norm(p["layer.0.gate"], axis=0)
    mid = TINY.n_layers // 2
    norms_mid = np.linalg.norm(p[f"layer.{mid}.gate"], axis=0)
    cv = lambda v: np.std(v) / np.mean(v)
    assert cv(norms_mid) > cv(norms_first)
