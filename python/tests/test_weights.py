"""MOEW weights format: roundtrip, determinism, layout invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import weights as weights_mod
from compile.model import TINY


def test_roundtrip(tmp_path):
    params = weights_mod.generate(TINY, seed=3)
    path = str(tmp_path / "w.bin")
    weights_mod.save(path, TINY, params)
    cfg, loaded = weights_mod.load(path)
    assert cfg["hidden_size"] == TINY.hidden_size
    assert set(loaded) == set(params)
    for k in params:
        np.testing.assert_array_equal(loaded[k], params[k])


def test_deterministic_generation():
    a = weights_mod.generate(TINY, seed=42)
    b = weights_mod.generate(TINY, seed=42)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_seed_changes_weights():
    a = weights_mod.generate(TINY, seed=1)
    b = weights_mod.generate(TINY, seed=2)
    assert not np.array_equal(a["embed.table"], b["embed.table"])


def test_expected_tensor_set():
    params = weights_mod.generate(TINY, seed=0)
    names = set(params)
    assert "embed.table" in names
    assert "final.lm_head" in names
    for l in range(TINY.n_layers):
        for t in ("ln1", "ln2", "wq", "wk", "wv", "wo", "gate"):
            assert f"layer.{l}.{t}" in names
        for e in range(TINY.n_experts):
            for t in ("w1", "w3", "w2"):
                assert f"layer.{l}.expert.{e}.{t}" in names
    # embed.table + final.ln + final.lm_head + L*(7 + 3E)
    assert len(names) == 3 + TINY.n_layers * (7 + 3 * TINY.n_experts)


def test_alignment(tmp_path):
    """Every tensor's absolute offset is 64-byte aligned (mmap-friendly)."""
    import json

    params = weights_mod.generate(TINY, seed=0)
    path = str(tmp_path / "w.bin")
    weights_mod.save(path, TINY, params)
    blob = open(path, "rb").read()
    hlen = int(np.frombuffer(blob[8:12], np.uint32)[0])
    header = json.loads(blob[12 : 12 + hlen])
    assert header["data_start"] % 64 == 0
    for t in header["tensors"]:
        assert t["offset"] % 64 == 0, t


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_gate_scales_mean_one(seed):
    """Imbalance shaping rescales but does not inflate the gate overall."""
    params = weights_mod.generate(TINY, seed=seed)
    for l in range(TINY.n_layers):
        g = params[f"layer.{l}.gate"]
        # column norms vary (imbalance) but their mean stays ~ the dense std
        norms = np.linalg.norm(g, axis=0) / np.sqrt(g.shape[0])
        assert 0.005 < norms.mean() < 0.06


def test_truncated_file_rejected(tmp_path):
    params = weights_mod.generate(TINY, seed=0)
    path = str(tmp_path / "w.bin")
    weights_mod.save(path, TINY, params)
    blob = open(path, "rb").read()
    with open(path, "wb") as fh:
        fh.write(blob[:100])
    with pytest.raises(Exception):
        weights_mod.load(path)
