"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes (and block sizes); assert_allclose against ref.py.
This is the core correctness signal for the compute hot spot.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gating, moe_ffn, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(0, 1, shape).astype(np.float32))


class TestExpertFfn:
    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 4),
        h=st.sampled_from([8, 32, 64, 256]),
        f_mult=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, b, h, f_mult, seed):
        rng = np.random.default_rng(seed)
        f = 16 * f_mult
        x = _rand(rng, b, h)
        w1, w3, w2 = _rand(rng, h, f), _rand(rng, h, f), _rand(rng, f, h)
        got = moe_ffn.expert_ffn(x, w1, w3, w2, block_f=16)
        want = ref.expert_ffn_ref(x, w1, w3, w2)
        # accumulation-order differences scale with the output magnitude
        scale = float(jnp.max(jnp.abs(want))) + 1e-6
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-5 * scale)

    @pytest.mark.parametrize("block_f", [16, 32, 64, 128])
    def test_block_size_invariance(self, block_f):
        """Output must not depend on the VMEM tile size."""
        rng = np.random.default_rng(0)
        x = _rand(rng, 1, 64)
        w1, w3, w2 = _rand(rng, 64, 128), _rand(rng, 64, 128), _rand(rng, 128, 64)
        got = moe_ffn.expert_ffn(x, w1, w3, w2, block_f=block_f)
        want = ref.expert_ffn_ref(x, w1, w3, w2)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_default_block_on_model_shapes(self):
        """The exact shapes the AOT artifact is lowered with."""
        rng = np.random.default_rng(1)
        h, f = 256, 1024
        x = _rand(rng, 1, h)
        w1, w3, w2 = _rand(rng, h, f), _rand(rng, h, f), _rand(rng, f, h)
        got = moe_ffn.expert_ffn(x, w1, w3, w2)
        want = ref.expert_ffn_ref(x, w1, w3, w2)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_bad_block_rejected(self):
        rng = np.random.default_rng(2)
        x = _rand(rng, 1, 8)
        w1, w3, w2 = _rand(rng, 8, 24), _rand(rng, 8, 24), _rand(rng, 24, 8)
        with pytest.raises(ValueError, match="must divide"):
            moe_ffn.expert_ffn(x, w1, w3, w2, block_f=16)

    def test_zero_input_gives_zero(self):
        rng = np.random.default_rng(3)
        x = jnp.zeros((1, 32))
        w1, w3, w2 = _rand(rng, 32, 32), _rand(rng, 32, 32), _rand(rng, 32, 32)
        got = moe_ffn.expert_ffn(x, w1, w3, w2, block_f=16)
        np.testing.assert_allclose(got, jnp.zeros((1, 32)), atol=1e-7)


class TestGating:
    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 4),
        h=st.sampled_from([8, 32, 256]),
        e=st.sampled_from([2, 4, 8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, b, h, e, seed):
        rng = np.random.default_rng(seed)
        hdn = _rand(rng, b, h)
        gw = _rand(rng, h, e)
        got = gating.gate_probs(hdn, gw)
        want = ref.gate_probs_ref(hdn, gw)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_rows_sum_to_one(self, seed):
        rng = np.random.default_rng(seed)
        hdn, gw = _rand(rng, 3, 32), _rand(rng, 32, 8)
        probs = gating.gate_probs(hdn, gw)
        np.testing.assert_allclose(jnp.sum(probs, axis=-1), jnp.ones(3), rtol=1e-5)
        assert bool(jnp.all(probs >= 0))

    def test_large_logits_stable(self):
        """Stable softmax: huge logits must not overflow to nan/inf."""
        hdn = jnp.full((1, 16), 100.0)
        gw = jnp.eye(16, 8) * 50.0
        probs = gating.gate_probs(hdn, gw)
        assert bool(jnp.all(jnp.isfinite(probs)))
        np.testing.assert_allclose(jnp.sum(probs), 1.0, rtol=1e-5)
