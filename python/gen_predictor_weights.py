"""Regenerate data/predictor_weights.json without a Rust toolchain.

The canonical generator is the Rust CLI:

    cargo run --release -- train-predictor --out data/predictor_weights.json

This script mirrors that default invocation (synthetic 12-layer, 1024-token
trace, seed 0, train on the first half) closely enough to produce an
equivalent-quality artifact in environments that only have Python: the
PRNG (SplitMix64 + Xoshiro256**) and trace generator are mirrored exactly,
and the trainer runs the same deterministic SGD in float32. Weights are
NOT guaranteed bit-identical to the Rust trainer (dot-product summation
order differs); the file format, dimensions, and predictive quality are
identical, and the Rust loader validates all of those.

    python3 python/gen_predictor_weights.py [--out data/predictor_weights.json]
"""

import argparse
import json
import math
import os

import numpy as np

MASK = (1 << 64) - 1


class SplitMix64:
    def __init__(self, seed):
        self.x = seed & MASK

    def next_u64(self):
        self.x = (self.x + 0x9E3779B97F4A7C15) & MASK
        z = self.x
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return z ^ (z >> 31)


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """Mirror of rust/src/util/rng.rs (Xoshiro256**)."""

    def __init__(self, seed):
        sm = SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]

    def next_u64(self):
        s = self.s
        result = (rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        zone = MASK - (MASK % n)
        while True:
            v = self.next_u64()
            if v < zone:
                return v % n

    def categorical(self, weights):
        total = 0.0
        for w in weights:
            total += w
        r = self.f64() * total
        for i, w in enumerate(weights):
            r -= w
            if r <= 0.0:
                return i
        return len(weights) - 1

    def shuffle(self, xs):
        for i in range(len(xs) - 1, 0, -1):
            j = self.below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]

    def permutation(self, n):
        v = list(range(n))
        self.shuffle(v)
        return v


def zipf_weights(n, alpha):
    raw = [1.0 / float(i + 1) ** alpha for i in range(n)]
    s = 0.0
    for w in raw:
        s += w
    return [w / s for w in raw]


def layer_skew(n_layers, layer, skew_edge, skew_mid):
    depth = layer / (max(n_layers, 2) - 1)
    return skew_edge + (skew_mid - skew_edge) * math.sin(math.pi * depth)


def generate_trace(n_layers=12, n_experts=8, top_k=2, n_tokens=1024,
                   locality=0.3, skew_edge=0.4, skew_mid=1.1, seed=0):
    """Mirror of rust/src/sim/tracegen.rs::generate."""
    rng = Rng(seed)
    stationary = []
    for l in range(n_layers):
        zipf = zipf_weights(n_experts, layer_skew(n_layers, l, skew_edge, skew_mid))
        perm = rng.permutation(n_experts)
        w = [0.0] * n_experts
        for rank, e in enumerate(perm):
            w[e] = zipf[rank]
        stationary.append(w)

    prev = [[] for _ in range(n_layers)]
    activated = []  # [token][layer] -> list of expert ids
    gates = []      # [token][layer] -> np.float32 array
    for _ in range(n_tokens):
        tok_a, tok_g = [], []
        for l in range(n_layers):
            selected = []
            for e in prev[l]:
                if len(selected) < top_k and rng.f64() < locality:
                    selected.append(e)
            while len(selected) < top_k:
                w = list(stationary[l])
                for e in selected:
                    w[e] = 0.0
                selected.append(rng.categorical(w))
            selected.sort()
            split = 0.5 + 0.4 * rng.f64()
            weights = [np.float32(split)]
            rest = (1.0 - split) / max(top_k - 1, 1)
            for _ in range(1, top_k):
                weights.append(np.float32(rest))
            tok_a.append(selected)
            tok_g.append(np.array(weights, dtype=np.float32))
            prev[l] = selected
        activated.append(tok_a)
        gates.append(tok_g)
    return activated, gates


FAST = np.float32(0.8)
SLOW = np.float32(0.98)
ONE = np.float32(1.0)


class Context:
    def __init__(self, n_layers, n_experts):
        self.prev = [[] for _ in range(n_layers)]
        self.hf = np.zeros((n_layers, n_experts), dtype=np.float32)
        self.hs = np.zeros((n_layers, n_experts), dtype=np.float32)

    def observe(self, layer, act):
        self.hf[layer] *= FAST
        self.hs[layer] *= SLOW
        for e in act:
            self.hf[layer][e] += ONE - FAST
            self.hs[layer][e] += ONE - SLOW
        self.prev[layer] = list(act)

    def reset(self):
        for p in self.prev:
            del p[:]
        self.hf.fill(0.0)
        self.hs.fill(0.0)


def features(ctx, E, tl, act, g, F):
    feat = np.zeros(F, dtype=np.float32)
    for i, e in enumerate(act):
        feat[e] = 1.0
        feat[E + e] = g[i] if i < len(g) else 0.0
    for e in ctx.prev[tl]:
        feat[2 * E + e] = 1.0
    feat[3 * E:4 * E] = ctx.hf[tl]
    feat[4 * E:5 * E] = ctx.hs[tl]
    feat[5 * E] = 1.0
    return feat


def sigmoid32(z):
    z = np.clip(z, np.float32(-30.0), np.float32(30.0))
    return ONE / (ONE + np.exp(-z))


def train(activated, gates, n_layers, n_experts, epochs=6, lr=0.1):
    """Mirror of rust/src/offload/learned.rs::train_on_trace (float32 SGD)."""
    T = len(activated)
    F = 5 * n_experts + 1
    lr32 = np.float32(lr)
    W = np.zeros((n_layers, n_experts, F), dtype=np.float32)
    ctx = Context(n_layers, n_experts)
    for _ in range(epochs):
        ctx.reset()
        for t in range(T):
            for l in range(n_layers):
                tl = (l + 1) % n_layers
                tt = t + 1 if tl == 0 else t
                if tt < T:
                    feat = features(ctx, n_experts, tl, activated[t][l],
                                    gates[t][l], F)
                    probs = sigmoid32(W[l] @ feat)
                    y = np.zeros(n_experts, dtype=np.float32)
                    for e in activated[tt][tl]:
                        y[e] = 1.0
                    g = lr32 * (probs - y)
                    W[l] -= g[:, None] * feat[None, :]
                ctx.observe(l, activated[t][l])
    return W


def top2_accuracy(W, activated, gates, n_layers, n_experts, start, end):
    """Sanity-check: top-2 guess precision over [start, end)."""
    F = 5 * n_experts + 1
    ctx = Context(n_layers, n_experts)
    tp = total = 0
    for t in range(start, end):
        for l in range(n_layers):
            tl = (l + 1) % n_layers
            tt = t + 1 if tl == 0 else t
            if tt < end:
                feat = features(ctx, n_experts, tl, activated[t][l],
                                gates[t][l], F)
                probs = sigmoid32(W[l] @ feat)
                guess = np.argsort(-probs, kind="stable")[:2]
                tp += sum(1 for e in guess if e in activated[tt][tl])
                total += 2
            ctx.observe(l, activated[t][l])
    return tp / total


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="data/predictor_weights.json")
    args = ap.parse_args()

    L, E, T = 12, 8, 1024
    activated, gates = generate_trace(n_layers=L, n_experts=E, n_tokens=T)
    # same split as `train-predictor` defaults: train on the first half
    W = train(activated[:T // 2], gates[:T // 2], L, E)
    acc = top2_accuracy(W, activated, gates, L, E, T // 2, T)
    print(f"holdout top-2 accuracy: {acc:.3f} (chance 0.25)")
    assert acc > 0.30, "trained weights do not beat chance — refusing to write"
    assert np.isfinite(W).all()

    doc = {
        "format": "moe-predictor-v1",
        "n_layers": L,
        "n_experts": E,
        "fast_decay": float(FAST),
        "slow_decay": float(SLOW),
        "weights": [[[float(x) for x in row] for row in layer] for layer in W],
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(doc, f, separators=(",", ":"))
        f.write("\n")
    print(f"weights -> {args.out}")


if __name__ == "__main__":
    main()
