"""L1 Pallas kernel: MoE router (gating network) with fused stable softmax.

The gating network is a bias-free linear layer ``[H, E]`` followed by a
softmax over the ``E`` experts (paper §4.3). Fusing the matmul and the
numerically-stable softmax keeps the tiny ``[B, E]`` logits in VMEM.

The same kernel serves two call sites in the rust coordinator:
  * the layer's own routing (which experts to activate), and
  * speculative expert pre-fetching — the *next* layer's gate applied to the
    *current* layer's hidden states (paper §3.2) — identical computation,
    different weight operand.

``interpret=True``: see moe_ffn.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gating_kernel(h_ref, w_ref, o_ref):
    logits = h_ref[...] @ w_ref[...]  # [B, E]
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@jax.jit
def gate_probs(h, gate_w):
    """Router probabilities: ``softmax(h @ gate_w, axis=-1)``.

    Args:
      h:      [B, H] (RMS-normalized) hidden states.
      gate_w: [H, E] gating network weight.

    Returns:
      [B, E] expert selection probabilities (rows sum to 1).
    """
    b, h_dim = h.shape
    h2, e = gate_w.shape
    assert h_dim == h2, f"h/gate_w mismatch: {h.shape} vs {gate_w.shape}"
    return pl.pallas_call(
        _gating_kernel,
        out_shape=jax.ShapeDtypeStruct((b, e), h.dtype),
        interpret=True,
    )(h, gate_w)
