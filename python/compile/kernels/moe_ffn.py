"""L1 Pallas kernel: fused SwiGLU expert FFN.

This is the paper's per-token compute hot spot: one Mixtral-style expert

    y = (silu(x @ w1) * (x @ w3)) @ w2

computed as a single fused Pallas kernel so the intermediate ``[B, F]``
activations never round-trip through HBM.

TPU adaptation of the paper's GPU setting (DESIGN.md §Hardware-Adaptation):
the kernel is blocked over the FFN dimension ``F``. Per grid step ``j`` it
streams one ``(H, FB)`` block of ``w1``/``w3`` and the matching ``(FB, H)``
block of ``w2`` through VMEM while ``x`` (``[B, H]``) and the accumulator
(``[B, H]``) stay resident, accumulating

    y += (silu(x @ w1[:, j]) * (x @ w3[:, j])) @ w2[j, :]

The BlockSpec grid expresses the HBM->VMEM schedule that the paper's expert
offloading expresses one level up (host->HBM over PCIe): stream the cold
weights, keep the hot activations resident.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the AOT artifact runs on
the rust-side CPU client. Real-TPU efficiency is assessed analytically in
EXPERIMENTS.md §Perf (VMEM footprint / MXU utilization from the block shapes).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default FFN-dimension block. 256 keeps the per-step VMEM footprint at
# B*H + 2*H*FB + FB*H + B*FB floats (~0.8 MB for H=256, FB=256, f32), far
# under the ~16 MB VMEM budget, leaving headroom for double buffering.
DEFAULT_BLOCK_F = 256


def _ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    """One FFN-dimension block of the fused SwiGLU expert."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    a = x @ w1_ref[...]  # [B, FB] gate path
    g = a * jax.nn.sigmoid(a)  # silu
    u = x @ w3_ref[...]  # [B, FB] up path
    o_ref[...] += (g * u) @ w2_ref[...]  # [B, H] partial down-projection


@partial(jax.jit, static_argnames=("block_f",))
def expert_ffn(x, w1, w3, w2, *, block_f: int | None = None):
    """Fused SwiGLU expert FFN: ``(silu(x@w1) * (x@w3)) @ w2``.

    Args:
      x:  [B, H] activations (resident in VMEM for the whole grid).
      w1: [H, F] gate projection.
      w3: [H, F] up projection.
      w2: [F, H] down projection.
      block_f: FFN-dimension tile; must divide F. Defaults to
        ``min(F, DEFAULT_BLOCK_F)``.

    Returns:
      [B, H] expert output.
    """
    b, h = x.shape
    h2, f = w1.shape
    assert h == h2, f"x/w1 mismatch: {x.shape} vs {w1.shape}"
    assert w3.shape == (h, f), f"bad w3 {w3.shape}"
    assert w2.shape == (f, h), f"bad w2 {w2.shape}"
    if block_f is None:
        block_f = min(f, DEFAULT_BLOCK_F)
    if f % block_f != 0:
        raise ValueError(f"block_f={block_f} must divide F={f}")
    grid = (f // block_f,)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, h), lambda j: (0, 0)),
            pl.BlockSpec((h, block_f), lambda j: (0, j)),
            pl.BlockSpec((h, block_f), lambda j: (0, j)),
            pl.BlockSpec((block_f, h), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((b, h), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h), x.dtype),
        interpret=True,
    )(x, w1, w3, w2)
