"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: pytest asserts the Pallas kernels in
``moe_ffn.py`` / ``gating.py`` match these to tight tolerances across a
hypothesis-driven sweep of shapes and dtypes. They are also reused by
``model.py`` as the building blocks of the monolithic reference forward.
"""

import jax
import jax.numpy as jnp


def expert_ffn_ref(x, w1, w3, w2):
    """Reference SwiGLU expert FFN: ``(silu(x@w1) * (x@w3)) @ w2``."""
    a = x @ w1
    return (a * jax.nn.sigmoid(a) * (x @ w3)) @ w2


def gate_probs_ref(h, gate_w):
    """Reference router: ``softmax(h @ gate_w, axis=-1)`` (stable)."""
    logits = h @ gate_w
    return jax.nn.softmax(logits, axis=-1)


def rmsnorm_ref(x, w, eps: float = 1e-5):
    """RMSNorm: ``x / rms(x) * w``."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w
