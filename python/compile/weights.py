"""Synthetic MiniMixtral weights: generation and the MOEW binary format.

The paper evaluates on Mixtral-8x7B-Instruct, whose weights (~90 GB fp16)
are unavailable here (DESIGN.md §3). We generate deterministic synthetic
weights instead, with one deliberately shaped component:

**Gate-column scaling for expert imbalance.** Paper §5.2 observes that the
distribution of activated experts is skewed — concentrated on a few experts,
most strongly in the *middle* layers. The gating network is a bias-free
linear layer, so a constant per-expert logit offset is not expressible;
instead we scale each expert's gate *column norm*. For RMS-normed hidden
states, logit_e ~ N(0, s_e^2 * |h|^2 / H): experts with larger column scale
produce more extreme logits and win top-k more often, yielding a skewed
stationary activation distribution. The skew strength follows a sine bump
over depth (peaks mid-network), matching §5.2's observation. Temporal
locality then emerges for free, because consecutive tokens' residual-stream
states are correlated.

MOEW binary format (little-endian), read by ``rust/src/model/weights.rs``:

    magic   b"MOEW"
    version u32 = 1
    hlen    u32 = length of the UTF-8 header JSON
    header  JSON: {"config": {...},
                   "tensors": [{"name", "shape", "offset", "nbytes"}, ...],
                   "data_start": int}   # absolute file offset, 64-aligned
    data    raw f32 tensors, each 64-byte aligned, offsets relative to
            data_start
"""

import json

import numpy as np

from compile.model import ModelConfig

MAGIC = b"MOEW"
VERSION = 1
ALIGN = 64


def generate(cfg: ModelConfig, seed: int = 42) -> dict:
    """Deterministic synthetic weights for ``cfg``. name -> np.float32 array."""
    rng = np.random.default_rng(seed)
    std = 0.02
    h, v, f, e = cfg.hidden_size, cfg.vocab_size, cfg.ffn_size, cfg.n_experts

    def dense(*shape):
        return rng.normal(0.0, std, size=shape).astype(np.float32)

    params = {"embed.table": dense(v, h)}
    for l in range(cfg.n_layers):
        pre = f"layer.{l}."
        params[pre + "ln1"] = np.ones(h, dtype=np.float32)
        params[pre + "ln2"] = np.ones(h, dtype=np.float32)
        for name in ("wq", "wk", "wv", "wo"):
            params[pre + name] = dense(h, h)
        gate = dense(h, e)
        # expert-imbalance shaping (see module docstring): skew strength
        # peaks mid-network, expert ranking permuted per layer.
        depth = l / max(cfg.n_layers - 1, 1)
        alpha = 0.15 + 0.55 * np.sin(np.pi * depth)
        ranks = rng.permutation(e)
        scales = (1.0 / (ranks + 1.0)) ** alpha
        scales = scales / scales.mean()
        params[pre + "gate"] = (gate * scales[None, :]).astype(np.float32)
        for x in range(e):
            epre = f"{pre}expert.{x}."
            params[epre + "w1"] = dense(h, f)
            params[epre + "w3"] = dense(h, f)
            params[epre + "w2"] = dense(f, h)
    params["final.ln"] = np.ones(h, dtype=np.float32)
    params["final.lm_head"] = dense(h, v)
    return params


def _align(n: int) -> int:
    return (n + ALIGN - 1) // ALIGN * ALIGN


def save(path: str, cfg: ModelConfig, params: dict) -> None:
    """Write ``params`` in MOEW format (see module docstring)."""
    tensors = []
    offset = 0
    for name, arr in params.items():
        assert arr.dtype == np.float32, f"{name}: {arr.dtype}"
        tensors.append(
            {
                "name": name,
                "shape": list(arr.shape),
                "offset": offset,
                "nbytes": arr.nbytes,
            }
        )
        offset = _align(offset + arr.nbytes)

    # data_start must itself be 64-aligned; pad the header.
    prefix_len = len(MAGIC) + 8  # magic + version + hlen
    header = {"config": cfg.to_dict(), "tensors": tensors, "data_start": 0}
    # two-pass: compute data_start with a stable header length
    raw = json.dumps(header).encode()
    data_start = _align(prefix_len + len(raw) + 32)  # slack for the int
    header["data_start"] = data_start
    raw = json.dumps(header).encode()
    assert prefix_len + len(raw) <= data_start, "header slack exceeded"

    with open(path, "wb") as fh:
        fh.write(MAGIC)
        fh.write(np.uint32(VERSION).tobytes())
        fh.write(np.uint32(len(raw)).tobytes())
        fh.write(raw)
        fh.write(b"\0" * (data_start - prefix_len - len(raw)))
        for t, (name, arr) in zip(tensors, params.items()):
            fh.seek(data_start + t["offset"])
            fh.write(arr.tobytes())


def load(path: str):
    """Read a MOEW file back. Returns (config_dict, params)."""
    with open(path, "rb") as fh:
        blob = fh.read()
    assert blob[:4] == MAGIC, "bad magic"
    version = int(np.frombuffer(blob[4:8], np.uint32)[0])
    assert version == VERSION, f"bad version {version}"
    hlen = int(np.frombuffer(blob[8:12], np.uint32)[0])
    header = json.loads(blob[12 : 12 + hlen].decode())
    ds = header["data_start"]
    params = {}
    for t in header["tensors"]:
        start = ds + t["offset"]
        arr = np.frombuffer(blob[start : start + t["nbytes"]], np.float32)
        params[t["name"]] = arr.reshape(t["shape"]).copy()
    return header["config"], params
