"""L2: MiniMixtral — a Mixtral-architecture MoE decoder, split into stages.

The model is a faithful scale-down of Mixtral 8x7B (the paper's testbed):
decoder-only transformer where every FFN is a top-2-of-8 MoE layer with a
bias-free linear gating network, RMSNorm pre-norms, RoPE attention.

The forward pass is deliberately split into **per-stage jitted functions**
rather than one monolithic graph, because the paper's contribution lives
*between* the stages: after ``router`` produces the expert probabilities for
layer *l*, the rust coordinator (L3) consults the expert cache, transfers
missing experts (charging the simulated PCIe clock), optionally speculatively
pre-loads layer *l+1*'s guesses, and only then invokes ``expert_ffn`` per
activated expert with the weight buffers it chose to make resident.
Top-k selection, expert-output weighting, the residual adds around the MoE
block, and sampling are done in rust (tiny vector ops; keeping them in L3
gives the cache/prefetch logic full control).

Stages (all f32, batch fixed at B=1 decode, matching the paper's setup):

  embed  (tok i32[1], table[V,H])                          -> x[1,H]
  attn   (x[1,H], ln1[H], wq,wk,wv,wo[H,H],
          k_cache[S,nh,hd], v_cache[S,nh,hd], pos i32[])   -> (x_res[1,H], k_cache', v_cache')
  router (x_res[1,H], ln2[H], gate_w[H,E])                 -> (h[1,H], probs[1,E])
  expert (h[1,H], w1[H,F], w3[H,F], w2[F,H])               -> y[1,H]   (Pallas)
  final  (x[1,H], lnf[H], lm_head[H,V])                    -> logits[1,V]

Composition per layer (done by L3, mirrored by ``forward_reference``):

  x_res, kc, vc = attn(x, ...)
  h, probs      = router(x_res, ...)
  sel, w        = topk2(probs); w /= sum(w)
  x             = x_res + sum_i w_i * expert(h, W[sel_i])
"""

from dataclasses import dataclass, asdict, field

import jax
import jax.numpy as jnp

from compile.kernels import moe_ffn, gating
from compile.kernels.ref import rmsnorm_ref


@dataclass(frozen=True)
class ModelConfig:
    """MiniMixtral hyper-parameters (Mixtral-8x7B scaled to ~79 M params)."""

    vocab_size: int = 1024
    hidden_size: int = 256
    n_layers: int = 12
    n_heads: int = 8
    n_experts: int = 8
    top_k: int = 2
    ffn_size: int = 1024
    max_seq: int = 256
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.hidden_size // self.n_heads

    def to_dict(self) -> dict:
        return asdict(self)


# A tiny config for fast tests; same code paths, smaller dims.
TINY = ModelConfig(
    vocab_size=64,
    hidden_size=32,
    n_layers=2,
    n_heads=4,
    n_experts=8,
    top_k=2,
    ffn_size=64,
    max_seq=16,
)

DEFAULT = ModelConfig()


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------

def _rope(x, pos, theta: float):
    """Rotate-half RoPE for one position. x: [nh, hd], pos: scalar i32."""
    nh, hd = x.shape
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)  # [half]
    angle = pos.astype(jnp.float32) * freqs  # [half]
    cos, sin = jnp.cos(angle), jnp.sin(angle)
    x1, x2 = x[:, :half], x[:, half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# --------------------------------------------------------------------------
# stages
# --------------------------------------------------------------------------

def make_stages(cfg: ModelConfig):
    """Build the per-stage functions for ``cfg``.

    Returns a dict name -> (fn, example_args) where example_args are
    ShapeDtypeStructs suitable for ``jax.jit(fn).lower(*example_args)``.
    """
    v, h = cfg.vocab_size, cfg.hidden_size
    e, f, s = cfg.n_experts, cfg.ffn_size, cfg.max_seq
    nh, hd = cfg.n_heads, cfg.head_dim
    eps, theta = cfg.rms_eps, cfg.rope_theta

    def embed(tok, table):
        # tok: i32[1]; table: [V, H]  ->  x: [1, H]
        return (jnp.take(table, tok, axis=0),)

    def attn(x, ln1, wq, wk, wv, wo, k_cache, v_cache, pos):
        # Pre-norm multi-head attention with RoPE and a static-shape KV
        # cache updated in place at `pos`. Returns the post-residual hidden
        # states (the paper's "hidden states obtained after the multi-head
        # attention block", i.e. the speculative-gating input).
        hn = rmsnorm_ref(x, ln1, eps)  # [1, H]
        q = (hn @ wq).reshape(nh, hd)
        k = (hn @ wk).reshape(nh, hd)
        val = (hn @ wv).reshape(nh, hd)
        q = _rope(q, pos, theta)
        k = _rope(k, pos, theta)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k[None], (pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, val[None], (pos, 0, 0))
        scores = jnp.einsum("nd,snd->ns", q, k_cache) / jnp.sqrt(
            jnp.float32(hd)
        )  # [nh, S]
        mask = jnp.arange(s)[None, :] > pos  # causal: future positions
        scores = jnp.where(mask, -1e30, scores)
        att = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("ns,snd->nd", att, v_cache).reshape(1, h) @ wo
        return x + o, k_cache, v_cache

    def router(x_res, ln2, gate_w):
        # Post-attention norm + gating (Pallas kernel). Returns both the
        # normed hidden states (the experts' input) and the probabilities
        # (L3 takes top-k). Also invoked by the speculative prefetcher with
        # the *next* layer's (ln2, gate_w).
        hn = rmsnorm_ref(x_res, ln2, eps)
        probs = gating.gate_probs(hn, gate_w)
        return hn, probs

    def expert(hn, w1, w3, w2):
        # One expert's fused SwiGLU FFN — the L1 Pallas hot-spot kernel.
        # block_f choice is per-target (EXPERIMENTS.md §Perf): on a real TPU
        # the grid streams (H,256) weight tiles through VMEM (double-buffer
        # headroom under the ~16 MB budget); on the CPU-PJRT artifact the
        # interpret-mode grid lowers to an HLO while-loop with dynamic
        # slices, which costs ~21x wallclock — so the shipped artifact uses
        # a single full-F block (measured 3316 -> 154 us/call at F=1024).
        return (moe_ffn.expert_ffn(hn, w1, w3, w2, block_f=f),)

    def final(x, lnf, lm_head):
        hn = rmsnorm_ref(x, lnf, eps)
        return (hn @ lm_head,)

    f32, i32 = jnp.float32, jnp.int32
    sd = jax.ShapeDtypeStruct
    return {
        "embed": (embed, (sd((1,), i32), sd((v, h), f32))),
        "attn": (
            attn,
            (
                sd((1, h), f32), sd((h,), f32),
                sd((h, h), f32), sd((h, h), f32), sd((h, h), f32), sd((h, h), f32),
                sd((s, nh, hd), f32), sd((s, nh, hd), f32),
                sd((), i32),
            ),
        ),
        "router": (router, (sd((1, h), f32), sd((h,), f32), sd((h, e), f32))),
        "expert": (
            expert,
            (sd((1, h), f32), sd((h, f), f32), sd((h, f), f32), sd((f, h), f32)),
        ),
        "final": (final, (sd((1, h), f32), sd((h,), f32), sd((h, v), f32))),
    }


# --------------------------------------------------------------------------
# monolithic reference forward (tests + trace capture only; never exported)
# --------------------------------------------------------------------------

def topk_renorm(probs, k: int):
    """Top-k expert selection with renormalized weights (Mixtral style)."""
    w, idx = jax.lax.top_k(probs[0], k)
    w = w / jnp.sum(w)
    return idx, w


def forward_token(cfg: ModelConfig, params: dict, tok, k_caches, v_caches, pos):
    """Run one token through all layers by composing the stage functions.

    ``params`` layout matches weights.py. Returns (logits, k_caches,
    v_caches, trace) where trace is the per-layer list of (selected experts,
    weights, probs) — the ground truth the rust tracing system reproduces.
    """
    stages = make_stages(cfg)
    embed, attn, router = stages["embed"][0], stages["attn"][0], stages["router"][0]
    expert, final = stages["expert"][0], stages["final"][0]

    (x,) = embed(tok, params["embed.table"])
    trace = []
    for l in range(cfg.n_layers):
        p = lambda name: params[f"layer.{l}.{name}"]
        x, k_caches[l], v_caches[l] = attn(
            x, p("ln1"), p("wq"), p("wk"), p("wv"), p("wo"),
            k_caches[l], v_caches[l], pos,
        )
        hn, probs = router(x, p("ln2"), p("gate"))
        idx, w = topk_renorm(probs, cfg.top_k)
        y = jnp.zeros_like(x)
        for j in range(cfg.top_k):
            ej = idx[j]
            # gather the expert weights (reference path only; rust selects
            # buffers instead of gathering)
            w1 = jnp.stack([params[f"layer.{l}.expert.{i}.w1"] for i in range(cfg.n_experts)])[ej]
            w3 = jnp.stack([params[f"layer.{l}.expert.{i}.w3"] for i in range(cfg.n_experts)])[ej]
            w2 = jnp.stack([params[f"layer.{l}.expert.{i}.w2"] for i in range(cfg.n_experts)])[ej]
            (yj,) = expert(hn, w1, w3, w2)
            y = y + w[j] * yj
        x = x + y
        trace.append((idx, w, probs))
    (logits,) = final(x, params["final.ln"], params["final.lm_head"])
    return logits, k_caches, v_caches, trace
