"""AOT compile path: lower every MiniMixtral stage to HLO text artifacts.

This is the only place python touches the system: ``make artifacts`` runs it
once, producing everything the rust coordinator needs to be self-contained:

    artifacts/
      manifest.json        stage metadata (shapes/dtypes/arity) + config
      <stage>.hlo.txt      one HLO-text module per stage (embed, attn,
                           router, expert, final)
      weights.bin          deterministic synthetic weights (MOEW format)
      testvec.json         golden vectors: per-stage checks + an 8-token
                           greedy decode with per-layer expert selections,
                           used by `moe-offload selfcheck` to validate the
                           rust PJRT + native paths against jax

Interchange format is HLO **text**, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import weights as weights_mod
from compile.model import DEFAULT, TINY, ModelConfig, forward_token, make_stages

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_to_json(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_stages(cfg: ModelConfig, out_dir: str) -> list:
    """Lower every stage, write ``<name>.hlo.txt``, return manifest entries."""
    entries = []
    for name, (fn, example_args) in make_stages(cfg).items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as fh:
            fh.write(text)
        out_specs = jax.eval_shape(fn, *example_args)
        if not isinstance(out_specs, tuple):
            out_specs = (out_specs,)
        entries.append(
            {
                "name": name,
                "file": fname,
                "inputs": [_spec_to_json(s) for s in example_args],
                "outputs": [_spec_to_json(s) for s in out_specs],
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"  lowered {name:8s} -> {fname} ({len(text)} chars)")
    return entries


def golden_decode(cfg: ModelConfig, params: dict, prompt_toks, n_gen: int):
    """Greedy-decode ``n_gen`` tokens; record selections + logit digests.

    The rust selfcheck replays the same decode through the PJRT artifacts
    (and the native fallback) and asserts: same expert selections at every
    (token, layer), same argmax tokens, logit checksums within tolerance.
    """
    jparams = {k: jnp.asarray(v) for k, v in params.items()}
    s, nh, hd = cfg.max_seq, cfg.n_heads, cfg.head_dim
    k_caches = [jnp.zeros((s, nh, hd), jnp.float32) for _ in range(cfg.n_layers)]
    v_caches = [jnp.zeros((s, nh, hd), jnp.float32) for _ in range(cfg.n_layers)]

    toks = list(prompt_toks)
    steps = []
    pos = 0
    next_tok = None
    # teacher-force the prompt, then generate greedily
    total = len(prompt_toks) + n_gen
    for step in range(total):
        tok = toks[step] if step < len(prompt_toks) else next_tok
        if step >= len(prompt_toks):
            toks.append(tok)
        logits, k_caches, v_caches, trace = forward_token(
            cfg, jparams, jnp.asarray([tok], jnp.int32), k_caches, v_caches,
            jnp.int32(pos),
        )
        next_tok = int(jnp.argmax(logits[0]))
        steps.append(
            {
                "pos": pos,
                "token": int(tok),
                "argmax": next_tok,
                "logits_sum": float(jnp.sum(logits)),
                "logits_max": float(jnp.max(logits)),
                "experts": [[int(i) for i in idx] for idx, _, _ in trace],
                "expert_weights": [[float(x) for x in w] for _, w, _ in trace],
            }
        )
        pos += 1
    return {"prompt": [int(t) for t in prompt_toks], "n_gen": n_gen, "steps": steps}


def stage_vectors(cfg: ModelConfig, params: dict) -> dict:
    """Small per-stage golden vectors (layer 0) for debugging the rust port."""
    stages = make_stages(cfg)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(0, 1, (1, cfg.hidden_size)).astype(np.float32))
    p = lambda n: jnp.asarray(params[n])
    out = {"x": x[0].tolist()}

    (xe,) = stages["embed"][0](jnp.asarray([3], jnp.int32), p("embed.table"))
    out["embed_tok3"] = xe[0].tolist()

    s, nh, hd = cfg.max_seq, cfg.n_heads, cfg.head_dim
    kc = jnp.zeros((s, nh, hd), jnp.float32)
    vc = jnp.zeros((s, nh, hd), jnp.float32)
    x_res, kc2, vc2 = stages["attn"][0](
        x, p("layer.0.ln1"), p("layer.0.wq"), p("layer.0.wk"),
        p("layer.0.wv"), p("layer.0.wo"), kc, vc, jnp.int32(0),
    )
    out["attn_x_res"] = x_res[0].tolist()
    out["attn_kc_sum"] = float(jnp.sum(kc2))
    out["attn_vc_sum"] = float(jnp.sum(vc2))

    hn, probs = stages["router"][0](x, p("layer.0.ln2"), p("layer.0.gate"))
    out["router_h"] = hn[0].tolist()
    out["router_probs"] = probs[0].tolist()

    (y,) = stages["expert"][0](
        hn, p("layer.0.expert.0.w1"), p("layer.0.expert.0.w3"),
        p("layer.0.expert.0.w2"),
    )
    out["expert0_y"] = y[0].tolist()

    (logits,) = stages["final"][0](x, p("final.ln"), p("final.lm_head"))
    out["final_logits_sum"] = float(jnp.sum(logits))
    out["final_logits_first8"] = logits[0][:8].tolist()
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tiny", action="store_true", help="use the tiny test config")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--skip-golden", action="store_true")
    ap.add_argument("--golden-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = TINY if args.tiny else DEFAULT
    os.makedirs(args.out_dir, exist_ok=True)

    print(f"[aot] config: {cfg}")
    print("[aot] lowering stages to HLO text...")
    stage_entries = lower_stages(cfg, args.out_dir)

    print("[aot] generating weights...")
    params = weights_mod.generate(cfg, seed=args.seed)
    wpath = os.path.join(args.out_dir, "weights.bin")
    weights_mod.save(wpath, cfg, params)
    n_params = sum(int(np.prod(a.shape)) for a in params.values())
    print(f"[aot] wrote {wpath}: {n_params/1e6:.1f} M params")

    manifest = {
        "version": MANIFEST_VERSION,
        "config": cfg.to_dict(),
        "seed": args.seed,
        "stages": stage_entries,
        "weights": "weights.bin",
        "testvec": None if args.skip_golden else "testvec.json",
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)

    if not args.skip_golden:
        print("[aot] computing golden vectors (stage + decode)...")
        tv = {
            "stages": stage_vectors(cfg, params),
            "decode": golden_decode(
                cfg, params, prompt_toks=[1, 7, 42, 9], n_gen=args.golden_tokens
            ),
        }
        with open(os.path.join(args.out_dir, "testvec.json"), "w") as fh:
            json.dump(tv, fh)
        print("[aot] wrote testvec.json")

    print("[aot] done")


if __name__ == "__main__":
    main()
