//! Bench: cache policy micro-ops + full trace-replay throughput.
//! One criterion-style target per paper-relevant axis (harness = false).

use moe_offload::bench_harness::Bencher;
use moe_offload::cache::{LayerCache, PolicyKind};
use moe_offload::sim::{cachesim, tracegen};

fn main() {
    let mut b = Bencher::new(2, 10);

    // micro: hot-path lookup+insert mix per policy
    for kind in PolicyKind::all_online() {
        let mut cache: LayerCache<u64> = LayerCache::new(4, kind.build(0, None));
        let pattern: Vec<usize> = (0..10_000).map(|i| (i * 7 + i / 13) % 8).collect();
        b.bench_units(
            &format!("policy/{}/lookup+insert", kind.name()),
            Some((pattern.len() as f64, "op")),
            &mut || {
                for &e in &pattern {
                    if cache.access(e).is_none() {
                        cache.insert(e, e as u64);
                    }
                }
            },
        );
    }

    // macro: full 32-layer trace replay (the paper's analysis workload)
    let trace = tracegen::generate(&tracegen::TraceGenConfig::mixtral(256, 0));
    for kind in [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::LfuAged, PolicyKind::Belady] {
        b.bench_units(
            &format!("replay/{}/256tok-32layer", kind.name()),
            Some((256.0, "tok")),
            &mut || {
                let mut t = trace.clone();
                cachesim::replay(&mut t, kind, 4, 0)
            },
        );
    }

    println!("{}", b.render());
}
