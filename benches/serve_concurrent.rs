//! Bench: the concurrent serve scheduler — N sessions interleaved
//! round-robin over one engine with a shared expert cache, versus the same
//! work decoded sequentially. Measures scheduler overhead and reports the
//! shared-cache amortization (misses/token falls as sessions share
//! transfers).

use moe_offload::bench_harness::Bencher;
use moe_offload::cache::PolicyKind;
use moe_offload::engine::{EngineConfig, InferenceEngine};
use moe_offload::model::sampler::Sampling;
use moe_offload::model::weights::generate_weights;
use moe_offload::model::ModelConfig;
use moe_offload::offload::store::HostExpertStore;
use moe_offload::quant::Scheme;
use moe_offload::runtime::native::NativeBackend;
use moe_offload::serve::scheduler::{run_scheduler, SchedulerConfig, ServeSnapshot};
use moe_offload::serve::{GenRequest, ServerMetrics};
use std::sync::mpsc::{channel, sync_channel};
use std::sync::{Arc, Mutex};

/// Byte-tokenizer-compatible small config (vocab ≥ 260).
fn cfg() -> ModelConfig {
    ModelConfig { vocab_size: 320, max_seq: 96, ..ModelConfig::TINY }
}

fn main() {
    let weights = Arc::new(generate_weights(cfg(), 42));
    let store = Arc::new(HostExpertStore::build(&weights, Scheme::Int4 { block: 16 }).unwrap());
    let n_tokens = 12usize;
    let mut b = Bencher::new(2, 10);
    let mut amortization: Vec<(usize, f64)> = Vec::new();

    for n_sessions in [1usize, 2, 4, 8] {
        let weights = Arc::clone(&weights);
        let store = Arc::clone(&store);
        let mut last_miss_rate = 0.0;
        b.bench_units(
            &format!("serve/{n_sessions}-sessions/{n_tokens}tok"),
            Some(((n_sessions * n_tokens) as f64, "tok")),
            &mut || {
                let engine = InferenceEngine::new(
                    Box::new(NativeBackend::new(Arc::clone(&weights))),
                    Arc::clone(&store),
                    EngineConfig::serving(4, PolicyKind::Lfu, true),
                );
                let (tx, rx) = sync_channel::<GenRequest>(n_sessions);
                let mut resp_rxs = Vec::with_capacity(n_sessions);
                for i in 0..n_sessions {
                    let (resp_tx, resp_rx) = channel();
                    tx.send(GenRequest {
                        prompt: format!("bench prompt {i}"),
                        n_tokens,
                        sampling: Sampling::Greedy,
                        resp: resp_tx,
                    })
                    .unwrap();
                    resp_rxs.push(resp_rx);
                }
                drop(tx);
                let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
                run_scheduler(
                    engine,
                    rx,
                    SchedulerConfig { max_sessions: n_sessions },
                    Arc::new(ServerMetrics::default()),
                    Arc::clone(&snapshot),
                );
                let mut total_tokens = 0u64;
                for resp_rx in resp_rxs {
                    let r = resp_rx.recv().unwrap().expect("generation ok");
                    assert_eq!(r.n_generated, n_tokens);
                    total_tokens += (r.n_prompt + r.n_generated) as u64;
                }
                let snap = snapshot.lock().unwrap();
                last_miss_rate = snap.cache.misses as f64 / total_tokens as f64;
                total_tokens
            },
        );
        amortization.push((n_sessions, last_miss_rate));
    }

    println!("{}", b.render());
    println!("shared-cache amortization (misses per stepped token):");
    for (n, mr) in &amortization {
        println!("  {n} sessions: {mr:.3}");
    }
    let solo = amortization[0].1;
    let most = amortization.last().unwrap().1;
    println!(
        "  -> {:.1}% of solo miss traffic at {} sessions",
        100.0 * most / solo.max(1e-12),
        amortization.last().unwrap().0
    );
}
