//! Bench: the completion-routed serve scheduler — N sessions interleaved
//! round-robin over one engine with a shared expert cache, versus the same
//! work decoded sequentially. Measures scheduler overhead, reports the
//! shared-cache amortization (misses/token falls as sessions share
//! transfers), exercises the admission-control path (bounded queue
//! rejections + queue-timeout sheds), and runs a mixed long-prompt/
//! short-prompt overload with chunked prefill on and off, writing a
//! `BENCH_serve_concurrent.json` artifact with rejected/shed counts, the
//! queue-wait p99, and TTFT p50/p99 for the chunked vs unchunked rounds.
//! A churn section hangs up half the fleet mid-decode at a fixed rate
//! (scheduler-driven cancels) and records `cancelled_sessions`, the
//! reclaimed-round fraction, the interactive-vs-batch TTFT p99 split, and
//! the churn-vs-no-churn engine throughput; a faulted pass stalls every
//! expert past a demand deadline and records `degraded_tokens`. A
//! `replica_scaling` section drains one fixed burst through N = 1, 2, 4
//! engine replicas (own scheduler loop + device cache each, ONE shared
//! admission queue and host store) and records tokens/s plus the
//! per-replica session counts from the router.
//!
//!     cargo bench --bench serve_concurrent [-- --smoke]

use moe_offload::bench_harness::Bencher;
use moe_offload::cache::PolicyKind;
use moe_offload::engine::{EngineConfig, EngineReplica, InferenceEngine};
use moe_offload::metrics::ServeMetrics;
use moe_offload::model::sampler::Sampling;
use moe_offload::model::weights::generate_weights;
use moe_offload::model::ModelConfig;
use moe_offload::offload::store::HostExpertStore;
use moe_offload::offload::transfer::FaultPlan;
use moe_offload::quant::Scheme;
use moe_offload::runtime::native::NativeBackend;
use moe_offload::serve::scheduler::{
    run_replica, run_scheduler, Scheduler, SchedulerConfig, ServeSnapshot,
};
use moe_offload::serve::{AdmissionQueue, GenRequest, GenResult, Priority, ReplicaRouter, ReplyTo};
use moe_offload::util::json::{self, Value};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Byte-tokenizer-compatible small config (vocab ≥ 260).
fn cfg() -> ModelConfig {
    ModelConfig { vocab_size: 320, max_seq: 96, ..ModelConfig::TINY }
}

fn make_engine(
    weights: &Arc<moe_offload::model::Weights>,
    store: &Arc<HostExpertStore>,
) -> InferenceEngine {
    InferenceEngine::new(
        Box::new(NativeBackend::new(Arc::clone(weights))),
        Arc::clone(store),
        EngineConfig::serving(4, PolicyKind::Lfu, true),
    )
}

fn push_request(
    queue: &AdmissionQueue,
    prompt: String,
    n_tokens: usize,
    enqueued: Instant,
) -> Option<Receiver<GenResult>> {
    push_request_pri(queue, prompt, n_tokens, Priority::Interactive, enqueued)
}

fn push_request_pri(
    queue: &AdmissionQueue,
    prompt: String,
    n_tokens: usize,
    priority: Priority,
    enqueued: Instant,
) -> Option<Receiver<GenResult>> {
    let (tx, rx) = channel();
    let req = GenRequest {
        prompt,
        n_tokens,
        sampling: Sampling::Greedy,
        priority,
        reply: ReplyTo::Channel(tx),
        enqueued,
        affinity: None,
    };
    queue.try_push(req).ok().map(|_| rx)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let weights = Arc::new(generate_weights(cfg(), 42));
    let store = Arc::new(HostExpertStore::build(&weights, Scheme::Int4 { block: 16 }).unwrap());
    let n_tokens = if smoke { 6usize } else { 12 };
    let (warmup, iters) = if smoke { (1, 3) } else { (2, 10) };
    let mut b = Bencher::new(warmup, iters);
    let mut amortization: Vec<(usize, f64, f64)> = Vec::new();

    // --- session scaling: shared-cache amortization vs session count
    for n_sessions in [1usize, 2, 4, 8] {
        let weights = Arc::clone(&weights);
        let store = Arc::clone(&store);
        let mut last_miss_rate = 0.0;
        b.bench_units(
            &format!("serve/{n_sessions}-sessions/{n_tokens}tok"),
            Some(((n_sessions * n_tokens) as f64, "tok")),
            &mut || {
                let engine = make_engine(&weights, &store);
                let metrics = Arc::new(ServeMetrics::default());
                let queue = AdmissionQueue::new(n_sessions, Arc::clone(&metrics));
                let (completions, _completion_rx) = channel();
                let mut resp_rxs = Vec::with_capacity(n_sessions);
                for i in 0..n_sessions {
                    resp_rxs.push(
                        push_request(
                            &queue,
                            format!("bench prompt {i}"),
                            n_tokens,
                            Instant::now(),
                        )
                        .expect("queue sized for the burst"),
                    );
                }
                queue.close();
                let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
                run_scheduler(
                    engine,
                    queue,
                    completions,
                    SchedulerConfig { max_sessions: n_sessions, ..SchedulerConfig::default() },
                    metrics,
                    Arc::clone(&snapshot),
                );
                let mut total_tokens = 0u64;
                for resp_rx in resp_rxs {
                    let r = resp_rx.recv().unwrap().expect("generation ok");
                    assert_eq!(r.n_generated, n_tokens);
                    total_tokens += (r.n_prompt + r.n_generated) as u64;
                }
                let snap = snapshot.lock().unwrap();
                last_miss_rate = snap.cache.misses as f64 / total_tokens as f64;
                total_tokens
            },
        );
        let rate = b.results.last().and_then(|r| r.per_second()).unwrap_or(0.0);
        amortization.push((n_sessions, rate, last_miss_rate));
    }

    // --- overload: bounded-queue rejections + queue-timeout sheds.
    // Offered load exceeds the queue depth (rejections at push) and part
    // of the accepted burst is backdated past the queue timeout (sheds at
    // dequeue); served + shed must equal accepted exactly.
    let offered = if smoke { 10usize } else { 24 };
    let queue_depth = 4usize;
    let backdate = Instant::now().checked_sub(Duration::from_secs(300));
    let metrics = Arc::new(ServeMetrics::default());
    let queue = AdmissionQueue::new(queue_depth, Arc::clone(&metrics));
    let (completions, _completion_rx) = channel();
    let mut accepted_rxs: Vec<(Receiver<GenResult>, bool)> = Vec::new();
    let mut rejected = 0u64;
    for i in 0..offered {
        // the first two requests are stale (when the clock allows
        // backdating): they land in the queue and must be shed, not decoded
        let (enqueued, stale) = match (i < 2, backdate) {
            (true, Some(t)) => (t, true),
            _ => (Instant::now(), false),
        };
        match push_request(&queue, format!("overload {i}"), 4, enqueued) {
            Some(rx) => accepted_rxs.push((rx, stale)),
            None => rejected += 1,
        }
    }
    queue.close();
    let engine = make_engine(&weights, &store);
    let overload_t0 = Instant::now();
    run_scheduler(
        engine,
        queue,
        completions,
        SchedulerConfig {
            max_sessions: 2,
            queue_timeout: Some(Duration::from_secs(60)),
            ..SchedulerConfig::default()
        },
        Arc::clone(&metrics),
        Arc::new(Mutex::new(ServeSnapshot::default())),
    );
    let overload_wall_s = overload_t0.elapsed().as_secs_f64();
    let accepted = accepted_rxs.len() as u64;
    let mut served = 0u64;
    let mut shed = 0u64;
    for (rx, stale) in accepted_rxs {
        match rx.recv().expect("accepted requests are answered") {
            Ok(r) => {
                assert!(!stale, "stale request decoded instead of shed");
                assert_eq!(r.n_generated, 4);
                served += 1;
            }
            Err(ge) => {
                assert!(stale, "fresh request refused: {}", ge.message);
                assert_eq!(ge.status, 503);
                shed += 1;
            }
        }
    }
    let queue_wait_p99_ns = metrics.queue_wait.percentile_ns(0.99);
    let queue_wait_p50_ns = metrics.queue_wait.percentile_ns(0.50);

    // --- mixed long-prompt/short-prompt overload: TTFT with chunked
    // prefill on vs off. Long prompts are pushed FIRST so, unchunked,
    // short sessions' first tokens queue behind whole-prompt prefill
    // rounds; with chunking the prefill interleaves.
    let (n_long, n_short) = if smoke { (1usize, 4usize) } else { (2, 8) };
    let long_prompt_len = 60usize;
    let mixed_chunk = 8usize;
    let mixed_budget = 16usize;
    let run_mixed = |prefill_chunk: usize, round_budget_tokens: usize| {
        let metrics = Arc::new(ServeMetrics::default());
        let queue = AdmissionQueue::new(n_long + n_short, Arc::clone(&metrics));
        let (completions, _completion_rx) = channel();
        let mut rxs = Vec::new();
        for _ in 0..n_long {
            rxs.push(
                push_request(&queue, "L".repeat(long_prompt_len), 4, Instant::now())
                    .expect("queue sized for the burst"),
            );
        }
        for i in 0..n_short {
            rxs.push(
                push_request(&queue, format!("short {i}"), 4, Instant::now())
                    .expect("queue sized for the burst"),
            );
        }
        queue.close();
        let t0 = Instant::now();
        run_scheduler(
            make_engine(&weights, &store),
            queue,
            completions,
            SchedulerConfig {
                max_sessions: 4,
                prefill_chunk,
                round_budget_tokens,
                ..SchedulerConfig::default()
            },
            Arc::clone(&metrics),
            Arc::new(Mutex::new(ServeSnapshot::default())),
        );
        let wall_s = t0.elapsed().as_secs_f64();
        for rx in rxs {
            let r = rx.recv().unwrap().expect("mixed generation ok");
            assert_eq!(r.n_generated, 4);
        }
        let count = metrics.ttft.count();
        assert_eq!(
            count,
            (n_long + n_short) as u64,
            "every session's first token must be TTFT-stamped"
        );
        (
            count,
            metrics.ttft.percentile_ns(0.50),
            metrics.ttft.percentile_ns(0.99),
            wall_s,
        )
    };
    let (ttft_count_unchunked, unchunked_p50, unchunked_p99, unchunked_wall_s) = run_mixed(0, 0);
    let (ttft_count_chunked, chunked_p50, chunked_p99, chunked_wall_s) =
        run_mixed(mixed_chunk, mixed_budget);

    // --- round-level expert batching: identical-prompt sessions admitted
    // in one drain decode in lockstep, so every decode round is maximally
    // dedupable — one fetch+dequant per distinct (layer, expert), joined by
    // the other sessions. Same workload through the legacy per-session
    // path for the tokens/s comparison and a bit-identity check.
    let n_batch_sessions = 6usize;
    let batch_tokens = if smoke { 6usize } else { 16 };
    let run_batched = |round_batching: bool| {
        let metrics = Arc::new(ServeMetrics::default());
        let queue = AdmissionQueue::new(n_batch_sessions, Arc::clone(&metrics));
        let (completions, _completion_rx) = channel();
        let mut rxs = Vec::new();
        for _ in 0..n_batch_sessions {
            rxs.push(
                push_request(
                    &queue,
                    "shared expert path".to_string(),
                    batch_tokens,
                    Instant::now(),
                )
                .expect("queue sized for the burst"),
            );
        }
        queue.close();
        let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
        let t0 = Instant::now();
        run_scheduler(
            make_engine(&weights, &store),
            queue,
            completions,
            SchedulerConfig {
                max_sessions: n_batch_sessions,
                round_batching,
                ..SchedulerConfig::default()
            },
            Arc::clone(&metrics),
            Arc::clone(&snapshot),
        );
        let wall_s = t0.elapsed().as_secs_f64();
        let mut texts = Vec::new();
        let mut tokens = 0u64;
        for rx in rxs {
            let r = rx.recv().unwrap().expect("batched generation ok");
            assert_eq!(r.n_generated, batch_tokens);
            tokens += (r.n_prompt + r.n_generated) as u64;
            texts.push(r.text);
        }
        let stats = snapshot.lock().unwrap().round_batching;
        (texts, tokens as f64 / wall_s.max(1e-12), stats)
    };
    let (legacy_texts, tps_off, _off_stats) = run_batched(false);
    let (batched_texts, tps_on, rb_stats) = run_batched(true);

    // --- churn: half the fleet hangs up mid-decode at a fixed rate. The
    // driven scheduler cancels each doomed session after its 2nd generated
    // token; the freed round capacity goes to survivors, so the engine's
    // token rate holds while total rounds shrink. Interactive requests are
    // pushed (and admitted) first, so ids 1..=n/2 are interactive and the
    // rest batch; doomed = even ids, hitting both tiers.
    struct ChurnStats {
        rounds: u64,
        tokens_per_s: f64,
        cancelled: u64,
        reclaimed_round_fraction: f64,
        ttft_interactive_p99_ns: u64,
        ttft_batch_p99_ns: u64,
    }
    let n_churn = 8usize;
    let churn_tokens = if smoke { 8usize } else { 24 };
    let run_churn = |churn: bool| -> ChurnStats {
        let metrics = Arc::new(ServeMetrics::default());
        let queue = AdmissionQueue::new(n_churn, Arc::clone(&metrics));
        let (completions, _completion_rx) = channel();
        let mut rxs = Vec::new();
        for i in 0..n_churn {
            let pri =
                if i < n_churn / 2 { Priority::Interactive } else { Priority::Batch };
            rxs.push(
                push_request_pri(
                    &queue,
                    format!("churn {i}"),
                    churn_tokens,
                    pri,
                    Instant::now(),
                )
                .expect("queue sized for the burst"),
            );
        }
        queue.close();
        let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
        let mut sched = Scheduler::new(
            make_engine(&weights, &store),
            queue,
            completions,
            SchedulerConfig { max_sessions: 4, ..SchedulerConfig::default() },
            Arc::clone(&metrics),
            Arc::clone(&snapshot),
        );
        let doomed: Vec<u64> = if churn {
            (1..=n_churn as u64).filter(|id| id % 2 == 0).collect()
        } else {
            Vec::new()
        };
        let mut generated: std::collections::HashMap<u64, u64> = Default::default();
        let mut cancelled: Vec<u64> = Vec::new();
        let mut advanced_tokens = 0u64;
        let mut rounds = 0u64;
        let mut last_cancel_round = 0u64;
        let t0 = Instant::now();
        while let Some(r) = sched.turn() {
            rounds += 1;
            advanced_tokens += (r.decode_tokens + r.prefill_tokens) as u64;
            for a in &r.advanced {
                if !a.prefill {
                    *generated.entry(a.session).or_insert(0) += a.tokens as u64;
                }
            }
            for &id in &doomed {
                if !cancelled.contains(&id)
                    && generated.get(&id).copied().unwrap_or(0) >= 2
                {
                    assert!(sched.cancel(id), "cancel({id}) found no active session");
                    cancelled.push(id);
                    last_cancel_round = rounds;
                }
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        for (i, rx) in rxs.iter().enumerate() {
            let id = (i + 1) as u64;
            match rx.recv() {
                Ok(r) => {
                    assert!(!doomed.contains(&id), "doomed session {id} was answered");
                    assert_eq!(r.expect("churn generation ok").n_generated, churn_tokens);
                }
                Err(_) => assert!(doomed.contains(&id), "survivor {id} unanswered"),
            }
        }
        assert_eq!(cancelled.len(), doomed.len(), "every doomed session cancelled");
        assert_eq!(
            metrics.cancelled_sessions.load(Ordering::Relaxed),
            doomed.len() as u64
        );
        assert_eq!(snapshot.lock().unwrap().failed_sessions, 0, "hang-ups are not failures");
        assert_eq!(
            metrics.ttft_interactive.count() + metrics.ttft_batch.count(),
            n_churn as u64,
            "every session's first token lands in exactly one TTFT tier"
        );
        ChurnStats {
            rounds,
            tokens_per_s: advanced_tokens as f64 / wall_s.max(1e-12),
            cancelled: cancelled.len() as u64,
            reclaimed_round_fraction: if churn && rounds > 0 {
                (rounds - last_cancel_round) as f64 / rounds as f64
            } else {
                0.0
            },
            ttft_interactive_p99_ns: metrics.ttft_interactive.percentile_ns(0.99),
            ttft_batch_p99_ns: metrics.ttft_batch.percentile_ns(0.99),
        }
    };
    let nochurn = run_churn(false);
    let churned = run_churn(true);

    // --- degrade: every expert stalled 1000 virtual ms against a 1 ms
    // demand deadline — interactive rounds renormalize around the stalls
    // and still complete, counted in degraded_tokens
    let degraded_tokens = {
        let metrics = Arc::new(ServeMetrics::default());
        let queue = AdmissionQueue::new(2, Arc::clone(&metrics));
        let (completions, _completion_rx) = channel();
        let rxs: Vec<_> = (0..2)
            .map(|i| {
                push_request(&queue, format!("degrade {i}"), 4, Instant::now())
                    .expect("queue sized for the burst")
            })
            .collect();
        queue.close();
        let mut ecfg = EngineConfig::serving(4, PolicyKind::Lfu, false);
        ecfg.demand_deadline_ms = 1;
        let mut engine = InferenceEngine::new(
            Box::new(NativeBackend::new(Arc::clone(&weights))),
            Arc::clone(&store),
            ecfg,
        );
        let mc = cfg();
        let mut plan = FaultPlan::seeded(5);
        for l in 0..mc.n_layers {
            for e in 0..mc.n_experts {
                plan = plan.stall_ms(l, e, 1000.0);
            }
        }
        engine.inject_faults(plan);
        let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
        run_scheduler(
            engine,
            queue,
            completions,
            SchedulerConfig::default(),
            Arc::clone(&metrics),
            Arc::clone(&snapshot),
        );
        for rx in rxs {
            let r = rx.recv().unwrap().expect("degraded generation ok");
            assert_eq!(r.n_generated, 4, "degraded session cut short");
        }
        snapshot.lock().unwrap().degraded_tokens
    };

    // --- replica scaling: the SAME burst drained by N = 1, 2, 4 engine
    // replicas. Each replica owns its scheduler loop and device cache;
    // all of them pull unpinned requests least-loaded from ONE admission
    // queue and fetch through ONE shared host store, so tokens/s should
    // scale near-linearly while N fits the machine.
    let n_scale_sessions = if smoke { 8usize } else { 16 };
    let scale_tokens = if smoke { 8usize } else { 16 };
    let run_replicated = |n_replicas: usize| -> (f64, Vec<u64>) {
        let metrics = Arc::new(ServeMetrics::default());
        let queue = AdmissionQueue::new(n_scale_sessions, Arc::clone(&metrics));
        let router = ReplicaRouter::new(n_replicas);
        let (completions, _completion_rx) = channel();
        let mut rxs = Vec::new();
        for i in 0..n_scale_sessions {
            rxs.push(
                push_request(
                    &queue,
                    format!("replica scaling {i}"),
                    scale_tokens,
                    Instant::now(),
                )
                .expect("queue sized for the burst"),
            );
        }
        queue.close();
        let t0 = Instant::now();
        let workers: Vec<_> = (0..n_replicas)
            .map(|r| {
                let weights = Arc::clone(&weights);
                let store = Arc::clone(&store);
                let queue = Arc::clone(&queue);
                let completions = completions.clone();
                let metrics = Arc::clone(&metrics);
                let router = Arc::clone(&router);
                std::thread::spawn(move || {
                    run_replica(
                        EngineReplica::new(r, make_engine(&weights, &store)),
                        queue,
                        completions,
                        SchedulerConfig { max_sessions: 4, ..SchedulerConfig::default() },
                        metrics,
                        Arc::new(Mutex::new(ServeSnapshot::default())),
                        router,
                    );
                })
            })
            .collect();
        drop(completions);
        for w in workers {
            w.join().expect("replica thread");
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let mut tokens = 0u64;
        for rx in rxs {
            let r = rx.recv().unwrap().expect("replicated generation ok");
            assert_eq!(r.n_generated, scale_tokens);
            tokens += (r.n_prompt + r.n_generated) as u64;
        }
        (tokens as f64 / wall_s.max(1e-12), router.admitted_counts())
    };
    let (scale_tps_1, scale_counts_1) = run_replicated(1);
    let (scale_tps_2, scale_counts_2) = run_replicated(2);
    let (scale_tps_4, scale_counts_4) = run_replicated(4);
    let speedup_2x = scale_tps_2 / scale_tps_1.max(1e-12);
    let speedup_4x = scale_tps_4 / scale_tps_1.max(1e-12);

    println!("{}", b.render());
    println!("shared-cache amortization (misses per stepped token):");
    for (n, _, mr) in &amortization {
        println!("  {n} sessions: {mr:.3}");
    }
    let solo = amortization[0].2;
    let most = amortization.last().unwrap().2;
    println!(
        "  -> {:.1}% of solo miss traffic at {} sessions",
        100.0 * most / solo.max(1e-12),
        amortization.last().unwrap().0
    );
    println!(
        "overload: offered {offered}, accepted {accepted}, rejected {rejected}, \
         served {served}, shed {shed}, queue-wait p99 {:.1} µs",
        queue_wait_p99_ns as f64 / 1e3
    );
    println!(
        "mixed TTFT ({n_long} long x {long_prompt_len} + {n_short} short): \
         unchunked p50 {:.1} µs / p99 {:.1} µs, \
         chunk {mixed_chunk} budget {mixed_budget} p50 {:.1} µs / p99 {:.1} µs",
        unchunked_p50 as f64 / 1e3,
        unchunked_p99 as f64 / 1e3,
        chunked_p50 as f64 / 1e3,
        chunked_p99 as f64 / 1e3
    );
    println!(
        "round batching ({n_batch_sessions} identical sessions x {batch_tokens} tok): \
         {:.1} tok/s on vs {:.1} tok/s off ({:.2}x), \
         {} joins over {} distinct experts in {} rounds (join rate {:.2})",
        tps_on,
        tps_off,
        tps_on / tps_off.max(1e-12),
        rb_stats.dedup_joins,
        rb_stats.distinct_experts,
        rb_stats.rounds,
        rb_stats.join_rate()
    );
    println!(
        "churn ({n_churn} sessions x {churn_tokens} tok, half hang up mid-decode): \
         cancelled_sessions {}, reclaimed-round fraction {:.2}, \
         ttft p99 interactive {:.1} µs vs batch {:.1} µs, \
         {:.1} tok/s churn vs {:.1} tok/s no-churn ({:.2}x)",
        churned.cancelled,
        churned.reclaimed_round_fraction,
        churned.ttft_interactive_p99_ns as f64 / 1e3,
        churned.ttft_batch_p99_ns as f64 / 1e3,
        churned.tokens_per_s,
        nochurn.tokens_per_s,
        churned.tokens_per_s / nochurn.tokens_per_s.max(1e-12)
    );
    println!(
        "degraded pass (every expert stalled past the demand deadline): \
         degraded_tokens {degraded_tokens}"
    );
    println!(
        "replica scaling ({n_scale_sessions} sessions x {scale_tokens} tok, one shared \
         queue + host store): N=1 {scale_tps_1:.1} tok/s, N=2 {scale_tps_2:.1} tok/s \
         ({speedup_2x:.2}x), N=4 {scale_tps_4:.1} tok/s ({speedup_4x:.2}x); \
         sessions per replica N=2 {scale_counts_2:?}, N=4 {scale_counts_4:?}"
    );

    // --- artifact
    let sessions_json: Vec<Value> = amortization
        .iter()
        .map(|(n, rate, mr)| {
            Value::obj(vec![
                ("sessions", Value::from(*n)),
                ("tokens_per_s", Value::from(*rate)),
                ("misses_per_token", Value::from(*mr)),
            ])
        })
        .collect();
    let artifact = Value::obj(vec![
        ("bench", Value::from("serve_concurrent")),
        ("smoke", Value::from(smoke)),
        ("n_tokens", Value::from(n_tokens)),
        ("scaling", Value::Arr(sessions_json)),
        (
            "overload",
            Value::obj(vec![
                ("offered", Value::from(offered)),
                ("queue_depth", Value::from(queue_depth)),
                ("accepted", Value::from(accepted as f64)),
                ("rejected", Value::from(rejected as f64)),
                ("served", Value::from(served as f64)),
                ("shed", Value::from(shed as f64)),
                ("shed_total_metric", Value::from(metrics.shed_total.load(Ordering::Relaxed) as f64)),
                ("queue_wait_p50_ns", Value::from(queue_wait_p50_ns as f64)),
                ("queue_wait_p99_ns", Value::from(queue_wait_p99_ns as f64)),
                ("wall_s", Value::from(overload_wall_s)),
            ]),
        ),
        (
            "ttft",
            Value::obj(vec![
                ("n_long", Value::from(n_long)),
                ("n_short", Value::from(n_short)),
                ("long_prompt_len", Value::from(long_prompt_len)),
                ("prefill_chunk", Value::from(mixed_chunk)),
                ("round_budget_tokens", Value::from(mixed_budget)),
                (
                    "unchunked",
                    Value::obj(vec![
                        ("count", Value::from(ttft_count_unchunked as f64)),
                        ("ttft_p50_ns", Value::from(unchunked_p50 as f64)),
                        ("ttft_p99_ns", Value::from(unchunked_p99 as f64)),
                        ("wall_s", Value::from(unchunked_wall_s)),
                    ]),
                ),
                (
                    "chunked",
                    Value::obj(vec![
                        ("count", Value::from(ttft_count_chunked as f64)),
                        ("ttft_p50_ns", Value::from(chunked_p50 as f64)),
                        ("ttft_p99_ns", Value::from(chunked_p99 as f64)),
                        ("wall_s", Value::from(chunked_wall_s)),
                    ]),
                ),
            ]),
        ),
        (
            "round_batching",
            Value::obj(vec![
                ("sessions", Value::from(n_batch_sessions)),
                ("n_tokens", Value::from(batch_tokens)),
                ("tokens_per_s_on", Value::from(tps_on)),
                ("tokens_per_s_off", Value::from(tps_off)),
                ("speedup", Value::from(tps_on / tps_off.max(1e-12))),
                ("rounds", Value::from(rb_stats.rounds as f64)),
                ("distinct_experts", Value::from(rb_stats.distinct_experts as f64)),
                ("dedup_joins", Value::from(rb_stats.dedup_joins as f64)),
                ("batched_rows", Value::from(rb_stats.batched_rows as f64)),
                ("join_rate", Value::from(rb_stats.join_rate())),
            ]),
        ),
        (
            "churn",
            Value::obj(vec![
                ("sessions", Value::from(n_churn)),
                ("n_tokens", Value::from(churn_tokens)),
                ("cancelled_sessions", Value::from(churned.cancelled as f64)),
                (
                    "reclaimed_round_fraction",
                    Value::from(churned.reclaimed_round_fraction),
                ),
                (
                    "ttft_interactive_p99_ns",
                    Value::from(churned.ttft_interactive_p99_ns as f64),
                ),
                ("ttft_batch_p99_ns", Value::from(churned.ttft_batch_p99_ns as f64)),
                ("tokens_per_s_churn", Value::from(churned.tokens_per_s)),
                ("tokens_per_s_nochurn", Value::from(nochurn.tokens_per_s)),
                ("rounds_churn", Value::from(churned.rounds as f64)),
                ("rounds_nochurn", Value::from(nochurn.rounds as f64)),
            ]),
        ),
        ("degraded_tokens", Value::from(degraded_tokens as f64)),
        (
            "replica_scaling",
            Value::obj(vec![
                ("sessions", Value::from(n_scale_sessions)),
                ("n_tokens", Value::from(scale_tokens)),
                (
                    "runs",
                    Value::Arr(
                        [
                            (1usize, scale_tps_1, &scale_counts_1),
                            (2, scale_tps_2, &scale_counts_2),
                            (4, scale_tps_4, &scale_counts_4),
                        ]
                        .iter()
                        .map(|(n, tps, counts)| {
                            Value::obj(vec![
                                ("replicas", Value::from(*n)),
                                ("tokens_per_s", Value::from(*tps)),
                                (
                                    "sessions_per_replica",
                                    Value::Arr(
                                        counts.iter().map(|&c| Value::from(c as f64)).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                    ),
                ),
                ("speedup_2x", Value::from(speedup_2x)),
                ("speedup_4x", Value::from(speedup_4x)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_serve_concurrent.json", json::to_string(&artifact))
        .expect("write BENCH_serve_concurrent.json");
    println!("wrote BENCH_serve_concurrent.json");

    // structural assertions keep CI honest without depending on machine
    // speed
    assert_eq!(accepted + rejected, offered as u64, "every offered request accounted");
    assert_eq!(served + shed, accepted, "accepted requests either served or shed");
    assert!(rejected > 0, "offered load must overflow the bounded queue");
    if backdate.is_some() {
        assert!(shed > 0, "backdated requests must be shed");
        assert_eq!(metrics.shed_total.load(Ordering::Relaxed), shed);
    }
    assert!(queue_wait_p99_ns >= queue_wait_p50_ns);
    assert_eq!(ttft_count_chunked, ttft_count_unchunked, "mixed runs saw the same sessions");
    assert!(unchunked_p99 >= unchunked_p50);
    assert!(chunked_p99 >= chunked_p50);
    assert_eq!(batched_texts, legacy_texts, "round batching changed session outputs");
    assert!(
        rb_stats.dedup_joins > 0,
        "identical-prompt lockstep sessions must produce dedup joins"
    );
    assert_eq!(
        rb_stats.batched_rows - rb_stats.distinct_experts,
        rb_stats.dedup_joins,
        "dedup ledger: every batched row beyond the first per group is a join"
    );
    assert_eq!(churned.cancelled, (n_churn / 2) as u64, "half the fleet must hang up");
    assert_eq!(nochurn.cancelled, 0);
    assert!(
        churned.rounds < nochurn.rounds,
        "cancelled capacity must be reclaimed: churn took {} rounds vs {} without",
        churned.rounds,
        nochurn.rounds
    );
    assert!(churned.reclaimed_round_fraction > 0.0);
    assert!(degraded_tokens > 0, "stalled experts never tripped the degrade path");
    for (n, counts) in
        [(1usize, &scale_counts_1), (2, &scale_counts_2), (4, &scale_counts_4)]
    {
        assert_eq!(counts.len(), n, "router reports one count per replica");
        assert_eq!(
            counts.iter().sum::<u64>(),
            n_scale_sessions as u64,
            "every session of the burst admitted by exactly one replica at N={n}"
        );
    }
    // the scaling gate needs real cores under the replica threads — skip
    // it (but still record the artifact) on a starved machine
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    if cores >= 2 {
        assert!(
            scale_counts_2.iter().all(|&c| c > 0),
            "both replicas must claim work at N=2: {scale_counts_2:?}"
        );
        assert!(
            speedup_2x >= 1.6,
            "two replicas must reach 1.6x one replica's tokens/s: \
             {scale_tps_2:.1} vs {scale_tps_1:.1} ({speedup_2x:.2}x)"
        );
    }
}
