//! Bench: demand-miss stall time and decode tokens/s for the synchronous
//! transfer path vs the 1-worker and N-worker async pipelines, plus the
//! steady-state buffer-pool reuse rate (the zero-allocation criterion).
//! Writes a `BENCH_transfer_pipeline.json` artifact (see EXPERIMENTS.md).
//!
//!     cargo bench --bench transfer_pipeline [-- --smoke]
//!
//! Part 1 replays a decode-shaped access pattern directly against the
//! transfer layer with an oracle prefetcher (next step's experts are known),
//! so the measured quantity is pure transfer-pipeline mechanics: how much
//! demand-miss stall survives when dequantization can overlap the compute
//! between layers. Part 2 runs the full engine end-to-end.

use moe_offload::cache::PolicyKind;
use moe_offload::engine::{EngineConfig, InferenceEngine};
use moe_offload::model::sampler::{Sampler, Sampling};
use moe_offload::model::weights::generate_weights;
use moe_offload::model::ModelConfig;
use moe_offload::model::weights::Weights;
use moe_offload::offload::pipeline::{BufferPool, TransferPipeline};
use moe_offload::offload::store::HostExpertStore;
use moe_offload::offload::transfer::TransferEngine;
use moe_offload::quant::Scheme;
use moe_offload::runtime::native::{expert_ffn_into, NativeBackend};
use moe_offload::runtime::ExpertHandle;
use moe_offload::util::json::{self, Value};
use moe_offload::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

const N_WORKERS: usize = 4;

fn bench_config() -> ModelConfig {
    ModelConfig {
        vocab_size: 256,
        hidden_size: 192,
        n_layers: 4,
        n_heads: 6,
        n_experts: 8,
        top_k: 2,
        ffn_size: 768,
        max_seq: 160,
    }
}

/// Per-step demanded experts: `top_k` distinct experts per layer, with the
/// mild temporal locality real gate traffic shows.
fn demand_schedule(cfg: &ModelConfig, steps: usize, seed: u64) -> Vec<Vec<(usize, usize)>> {
    let mut rng = Rng::new(seed);
    (0..steps)
        .map(|_| {
            let mut step = Vec::new();
            for l in 0..cfg.n_layers {
                let first = rng.below(cfg.n_experts);
                let mut second = rng.below(cfg.n_experts);
                while second == first {
                    second = rng.below(cfg.n_experts);
                }
                step.push((l, first));
                step.push((l, second));
            }
            step
        })
        .collect()
}

/// Fixed per-step compute (the work transfers are supposed to hide behind).
struct ComputeLoad {
    h: Vec<f32>,
    w1: Vec<f32>,
    w3: Vec<f32>,
    w2: Vec<f32>,
    a: Vec<f32>,
    u: Vec<f32>,
    out: Vec<f32>,
    ffn: usize,
    iters: usize,
}

impl ComputeLoad {
    fn new(store: &HostExpertStore, cfg: &ModelConfig, iters: usize) -> ComputeLoad {
        let (w1, w3, w2) = store.fetch(0, 0);
        ComputeLoad {
            h: (0..cfg.hidden_size).map(|i| (i as f32 * 0.37).sin()).collect(),
            w1,
            w3,
            w2,
            a: Vec::new(),
            u: Vec::new(),
            out: vec![0.0; cfg.hidden_size],
            ffn: cfg.ffn_size,
            iters,
        }
    }

    fn run(&mut self) {
        for _ in 0..self.iters {
            expert_ffn_into(
                &self.h, &self.w1, &self.w3, &self.w2, self.ffn, &mut self.a, &mut self.u,
                &mut self.out,
            );
        }
        std::hint::black_box(&self.out);
    }
}

/// Synchronous baseline: every demanded expert dequantizes on the critical
/// path. Returns (total stall seconds, fetches performed).
fn run_sync(
    store: &Arc<HostExpertStore>,
    pool: &Arc<BufferPool>,
    schedule: &[Vec<(usize, usize)>],
    compute: &mut ComputeLoad,
) -> (f64, u64) {
    let mut stall = 0.0;
    let mut fetches = 0u64;
    for step in schedule {
        compute.run();
        for &(l, e) in step {
            let t0 = Instant::now();
            let (w1, w3, w2) = store.fetch_pooled(pool, l, e);
            stall += t0.elapsed().as_secs_f64();
            fetches += 1;
            pool.release(w1);
            pool.release(w3);
            pool.release(w2);
        }
    }
    (stall, fetches)
}

/// Pipelined run with an oracle prefetcher: while computing step *s*, the
/// workers dequantize step *s+1*'s experts; each demand then joins its
/// prefetch. Returns (total stall seconds, completed transfers).
fn run_pipelined(
    store: &Arc<HostExpertStore>,
    pool: &Arc<BufferPool>,
    schedule: &[Vec<(usize, usize)>],
    compute: &mut ComputeLoad,
    workers: usize,
) -> (f64, u64) {
    let mut pipe = TransferPipeline::spawn(Arc::clone(store), Arc::clone(pool), workers);
    let mut stall = 0.0;
    for (i, step) in schedule.iter().enumerate() {
        if let Some(next) = schedule.get(i + 1) {
            for &(l, e) in next {
                pipe.submit_prefetch(l, e);
            }
        }
        compute.run();
        for &(l, e) in step {
            let t0 = Instant::now();
            pipe.submit_demand(l, e);
            let r = pipe.wait_for(l, e).expect("pipeline result");
            stall += t0.elapsed().as_secs_f64();
            pool.release(r.w1);
            pool.release(r.w3);
            pool.release(r.w2);
        }
        // results that belong to later steps stay stashed inside the
        // pipeline and are consumed by their own wait_for
    }
    let completed = pipe.stats().completed;
    (stall, completed)
}

/// Byte-accounting parity: replay the SAME demand trace through the
/// un-deduped synchronous path (one `TransferEngine::fetch` per demand,
/// each recording its own bytes) and through the pipelined path under the
/// engine's record-at-issue discipline (a prefetch records its bytes when
/// its bus slot is reserved; a demand that *joins* it records nothing
/// further). Dedup changes WHO pays for a transfer, never HOW MUCH — the
/// two ledgers must agree to the byte. A demand join that re-recorded its
/// bytes (the latent double-count this guards against) shows up here as
/// an inflated pipelined total.
/// Returns (sync transfers, sync bytes, pipelined transfers, pipelined bytes).
fn run_byte_parity(
    weights: &Arc<Weights>,
    store: &Arc<HostExpertStore>,
    schedule: &[Vec<(usize, usize)>],
    workers: usize,
) -> (u64, u64, u64, u64) {
    let be = NativeBackend::new(Arc::clone(weights));

    // un-deduped: every demand is its own fetch and its own ledger entry
    let sync_pool = BufferPool::new();
    let mut sync_te = TransferEngine::new(Arc::clone(store), Arc::clone(&sync_pool));
    for step in schedule {
        for &(l, e) in step {
            let (h, _) = sync_te.fetch(&be, l, e).expect("sync fetch");
            let ExpertHandle::Host { w1, w3, w2 } = h else {
                unreachable!("native backend returns host handles")
            };
            sync_pool.release(w1);
            sync_pool.release(w3);
            sync_pool.release(w2);
        }
    }

    // deduped: oracle prefetch of step s+1 while demanding step s, byte
    // accounting mirrored from the engine — record at issue, skip issuing
    // (and recording) when the key is already in flight, and never record
    // on a join
    let pool = BufferPool::new();
    let mut te = TransferEngine::new(Arc::clone(store), Arc::clone(&pool));
    let mut pipe = TransferPipeline::spawn(Arc::clone(store), Arc::clone(&pool), workers);
    for (i, step) in schedule.iter().enumerate() {
        if let Some(next) = schedule.get(i + 1) {
            for &(l, e) in next {
                if !pipe.in_flight(l, e) {
                    pipe.submit_prefetch(l, e);
                    te.record_scheduled();
                }
            }
        }
        for &(l, e) in step {
            if !pipe.submit_demand(l, e) {
                te.record_scheduled(); // fresh demand: bus reserved here
            }
            let r = pipe.wait_for(l, e).expect("pipeline result");
            pool.release(r.w1);
            pool.release(r.w3);
            pool.release(r.w2);
        }
    }
    (sync_te.stats.transfers, sync_te.stats.bytes, te.stats.transfers, te.stats.bytes)
}

/// End-to-end decode tokens/s through the full engine.
fn run_engine(workers: usize, n_tokens: usize) -> (f64, moe_offload::metrics::PipelineStats) {
    let cfg = bench_config();
    let weights = Arc::new(generate_weights(cfg, 42));
    let store = Arc::new(HostExpertStore::build(&weights, Scheme::Int4 { block: 16 }).unwrap());
    let mut ecfg = EngineConfig::serving(4, PolicyKind::Lru, true);
    ecfg.transfer_workers = workers;
    let mut engine = InferenceEngine::new(Box::new(NativeBackend::new(weights)), store, ecfg);
    let mut sampler = Sampler::new(Sampling::Greedy, 0);
    let t0 = Instant::now();
    let out = engine.generate(&[1, 7, 42], n_tokens, &mut sampler).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(out.generated.len(), n_tokens);
    ((out.tokens.len() as f64) / wall, engine.pipeline_stats())
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (steps, compute_iters, gen_tokens) = if smoke { (12, 2, 16) } else { (60, 6, 140) };

    let cfg = bench_config();
    let weights = Arc::new(generate_weights(cfg, 42));
    let store = Arc::new(HostExpertStore::build(&weights, Scheme::Int4 { block: 16 }).unwrap());
    let schedule = demand_schedule(&cfg, steps, 7);
    let mut compute = ComputeLoad::new(&store, &cfg, compute_iters);

    // --- part 1: demand-miss stall, transfer layer only ------------------
    // warmup pass populates the pool so the measured passes are steady-state
    let pool = BufferPool::new();
    let _ = run_sync(&store, &pool, &schedule[..steps.min(4)], &mut compute);
    let (sync_stall, sync_fetches) = run_sync(&store, &pool, &schedule, &mut compute);
    let (one_stall, one_completed) =
        run_pipelined(&store, &pool, &schedule, &mut compute, 1);
    let (n_stall, n_completed) =
        run_pipelined(&store, &pool, &schedule, &mut compute, N_WORKERS);
    let pool_reuse = pool.reuse_rate();

    let speedup_1 = sync_stall / one_stall.max(1e-12);
    let speedup_n = sync_stall / n_stall.max(1e-12);
    println!("== transfer_pipeline: demand-miss stall ({steps} steps, int4) ==");
    println!("sync:                {:>9.3} ms  ({sync_fetches} fetches)", sync_stall * 1e3);
    println!(
        "pipeline 1 worker:   {:>9.3} ms  ({one_completed} transfers, {speedup_1:.2}x)",
        one_stall * 1e3
    );
    println!(
        "pipeline {N_WORKERS} workers:  {:>9.3} ms  ({n_completed} transfers, {speedup_n:.2}x)",
        n_stall * 1e3
    );
    println!("pool reuse rate:     {:>9.1}%", pool_reuse * 100.0);

    // --- part 1b: byte-accounting parity under dedup ----------------------
    let (sync_transfers, sync_bytes, piped_transfers, piped_bytes) =
        run_byte_parity(&weights, &store, &schedule, N_WORKERS);
    assert_eq!(
        (sync_transfers, sync_bytes),
        (piped_transfers, piped_bytes),
        "demand-join dedup changed the reported transfer volume"
    );
    println!(
        "byte parity:         sync {sync_transfers} transfers / {sync_bytes} B == \
         pipelined {piped_transfers} transfers / {piped_bytes} B"
    );

    // --- part 2: end-to-end decode ---------------------------------------
    let (tps_sync, _) = run_engine(0, gen_tokens);
    let (tps_one, _) = run_engine(1, gen_tokens);
    let (tps_n, pipe_stats) = run_engine(N_WORKERS, gen_tokens);
    let engine_pool_reuse = pipe_stats.pool_reuse_rate();
    println!("== transfer_pipeline: end-to-end decode ({gen_tokens} tokens) ==");
    println!("tokens/s  sync {tps_sync:.1}   1-worker {tps_one:.1}   {N_WORKERS}-worker {tps_n:.1}");
    println!(
        "engine pool reuse {:.1}%  joins {}  cancelled {}  peak in-flight {}",
        engine_pool_reuse * 100.0,
        pipe_stats.demand_joined_prefetch,
        pipe_stats.cancelled_prefetches,
        pipe_stats.peak_in_flight
    );

    let artifact = Value::obj(vec![
        ("bench", Value::from("transfer_pipeline")),
        ("smoke", Value::from(smoke)),
        ("scheme", Value::from("int4")),
        ("steps", Value::from(steps)),
        ("workers", Value::from(N_WORKERS)),
        (
            "demand_stall",
            Value::obj(vec![
                ("sync_s", Value::from(sync_stall)),
                ("one_worker_s", Value::from(one_stall)),
                ("n_worker_s", Value::from(n_stall)),
                ("speedup_one_worker", Value::from(speedup_1)),
                ("speedup_n_worker", Value::from(speedup_n)),
            ]),
        ),
        (
            "tokens_per_s",
            Value::obj(vec![
                ("sync", Value::from(tps_sync)),
                ("one_worker", Value::from(tps_one)),
                ("n_worker", Value::from(tps_n)),
            ]),
        ),
        (
            "pool",
            Value::obj(vec![
                ("transfer_layer_reuse_rate", Value::from(pool_reuse)),
                ("engine_reuse_rate", Value::from(engine_pool_reuse)),
                ("engine_allocs", Value::from(pipe_stats.pool_allocs as f64)),
                ("engine_reuses", Value::from(pipe_stats.pool_reuses as f64)),
            ]),
        ),
        (
            "byte_parity",
            Value::obj(vec![
                ("sync_transfers", Value::from(sync_transfers as f64)),
                ("sync_bytes", Value::from(sync_bytes as f64)),
                ("pipelined_transfers", Value::from(piped_transfers as f64)),
                ("pipelined_bytes", Value::from(piped_bytes as f64)),
            ]),
        ),
        (
            "pipeline_counters",
            Value::obj(vec![
                ("demand_joined_prefetch", Value::from(pipe_stats.demand_joined_prefetch as f64)),
                ("cancelled_prefetches", Value::from(pipe_stats.cancelled_prefetches as f64)),
                ("peak_in_flight", Value::from(pipe_stats.peak_in_flight as f64)),
                ("completed", Value::from(pipe_stats.completed as f64)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_transfer_pipeline.json", json::to_string(&artifact))
        .expect("write BENCH_transfer_pipeline.json");
    println!("wrote BENCH_transfer_pipeline.json");

    // smoke assertions keep CI honest without depending on machine speed
    assert!(pool_reuse > 0.9, "transfer-layer pool reuse {pool_reuse} below 0.9");
    assert!(sync_fetches > 0 && n_completed > 0);
    // the full run IS the perf gate: the N-worker pipeline must cut
    // demand-miss stall >= 2x vs the synchronous path (ISSUE acceptance
    // bar; not enforced in --smoke where timings are too small to trust)
    if !smoke {
        assert!(
            speedup_n >= 2.0,
            "perf gate: {N_WORKERS}-worker stall speedup {speedup_n:.2}x < 2x vs sync"
        );
    }
}
