//! Bench target for paper Table 1: regenerates the table end-to-end
//! (trace gen -> LRU replay at each offload count -> cost model) and
//! times the pipeline.

use moe_offload::bench_harness::Bencher;
use moe_offload::figures::{table1, FigCtx};

fn main() {
    let dir = std::env::temp_dir().join(format!("bench-t1-{}", std::process::id()));
    let ctx = FigCtx::synthetic(&dir, 128, 0);
    let mut b = Bencher::new(1, 5);
    b.bench("table1/regenerate", || table1::run(&ctx).unwrap());
    println!("{}", b.render());
    println!("--- Table 1 output ---");
    println!("{}", std::fs::read_to_string(dir.join("table1.txt")).unwrap());
    std::fs::remove_dir_all(&dir).ok();
}
