//! Bench: end-to-end decode tokens/s through the full engine (cache +
//! transfer + prefetch), native backend by default so the bench runs
//! without artifacts; pass --pjrt (env MOE_BENCH_PJRT=1) to bench the AOT
//! path when artifacts/ exists.

use moe_offload::bench_harness::Bencher;
use moe_offload::cache::PolicyKind;
use moe_offload::engine::{EngineConfig, InferenceEngine};
use moe_offload::model::sampler::{Sampler, Sampling};
use moe_offload::model::weights::generate_weights;
use moe_offload::model::{ModelConfig, Weights};
use moe_offload::offload::prefetch::PrefetchConfig;
use moe_offload::offload::store::HostExpertStore;
use moe_offload::quant::Scheme;
use moe_offload::runtime::{native::NativeBackend, Backend};
use moe_offload::sim::hardware;
use std::sync::Arc;

fn bench_config(
    b: &mut Bencher,
    name: &str,
    weights: &Arc<Weights>,
    make_backend: &dyn Fn() -> Box<dyn Backend>,
    policy: PolicyKind,
    spec: bool,
    transfer_workers: usize,
    n_tokens: usize,
) {
    let store =
        Arc::new(HostExpertStore::build(weights, Scheme::Int4 { block: 16 }).unwrap());
    b.bench_units(name, Some((n_tokens as f64, "tok")), &mut || {
        let mut engine = InferenceEngine::new(
            make_backend(),
            Arc::clone(&store),
            EngineConfig {
                cache_capacity: 4,
                policy,
                prefetch: PrefetchConfig { enabled: spec, k: 2 },
                transfer_workers,
                profile: hardware::by_name("A6000").unwrap(),
                disk: hardware::DiskProfile::default(),
                seed: 0,
                record_trace: false,
                fetch_retries: 2,
                demand_deadline_ms: 0,
            },
        );
        let mut sampler = Sampler::new(Sampling::Greedy, 0);
        let prompt = [1u32, 7, 42, 9];
        engine.generate(&prompt, n_tokens - prompt.len(), &mut sampler).unwrap()
    });
}

fn main() {
    // small config so the native matmuls keep iterations short
    let cfg = ModelConfig { n_layers: 6, ..ModelConfig::DEFAULT };
    let weights = Arc::new(generate_weights(cfg, 42));
    let mut b = Bencher::new(1, 5);

    let native = {
        let w = Arc::clone(&weights);
        move || -> Box<dyn Backend> { Box::new(NativeBackend::new(Arc::clone(&w))) }
    };
    for (name, policy, spec, workers) in [
        ("e2e/native/lru", PolicyKind::Lru, false, 0),
        ("e2e/native/lfu", PolicyKind::Lfu, false, 0),
        ("e2e/native/lfu-aged", PolicyKind::LfuAged, false, 0),
        ("e2e/native/lru+spec", PolicyKind::Lru, true, 0),
        ("e2e/native/lru+spec+pipeline1", PolicyKind::Lru, true, 1),
        ("e2e/native/lru+spec+pipeline4", PolicyKind::Lru, true, 4),
    ] {
        bench_config(&mut b, name, &weights, &native, policy, spec, workers, 16);
    }

    // PJRT path (opt-in: needs artifacts/)
    if std::env::var("MOE_BENCH_PJRT").ok().as_deref() == Some("1") {
        use moe_offload::runtime::artifacts::Artifacts;
        use moe_offload::runtime::pjrt::PjrtBackend;
        let artifacts = Artifacts::load(std::path::Path::new("artifacts")).expect("artifacts");
        let aw = Arc::new(Weights::load(&artifacts.weights_path).unwrap());
        let artifacts = Arc::new(artifacts);
        let make = {
            let aw = Arc::clone(&aw);
            move || -> Box<dyn Backend> {
                Box::new(PjrtBackend::new(&artifacts, &aw).unwrap())
            }
        };
        bench_config(&mut b, "e2e/pjrt/lfu", &aw, &make, PolicyKind::Lfu, false, 0, 12);
        bench_config(&mut b, "e2e/pjrt/lru+spec", &aw, &make, PolicyKind::Lru, true, 0, 12);
    }

    println!("{}", b.render());
}
