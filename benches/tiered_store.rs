//! Bench: the disk tier under host RAM (`--host-cache-mb`). Replays a
//! decode-shaped demand trace against tiered stores across a sweep of RAM
//! budgets and reports per-budget RAM hit rate, disk promotions and disk
//! read latency, plus an offline `replay_host_tier` sweep that prices the
//! same budgets on the simulated disk. Writes `BENCH_tiered_store.json`
//! (see EXPERIMENTS.md).
//!
//!     cargo bench --bench tiered_store [-- --smoke]

use moe_offload::cache::PolicyKind;
use moe_offload::model::weights::generate_weights;
use moe_offload::model::ModelConfig;
use moe_offload::offload::pipeline::BufferPool;
use moe_offload::offload::store::{HostExpertStore, HostTierConfig};
use moe_offload::quant::Scheme;
use moe_offload::sim::hardware::DiskProfile;
use moe_offload::sim::{cachesim, tracegen};
use moe_offload::util::json::{self, Value};
use moe_offload::util::rng::Rng;
use std::sync::Arc;

fn bench_config() -> ModelConfig {
    ModelConfig {
        vocab_size: 256,
        hidden_size: 192,
        n_layers: 4,
        n_heads: 6,
        n_experts: 8,
        top_k: 2,
        ffn_size: 768,
        max_seq: 160,
    }
}

/// Per-step demanded experts: `top_k` distinct experts per layer, with the
/// mild temporal locality real gate traffic shows (every fourth step
/// replays the previous step's picks).
fn demand_schedule(cfg: &ModelConfig, steps: usize, seed: u64) -> Vec<Vec<(usize, usize)>> {
    let mut rng = Rng::new(seed);
    let mut prev: Option<Vec<(usize, usize)>> = None;
    (0..steps)
        .map(|i| {
            if i % 4 == 3 {
                if let Some(p) = &prev {
                    return p.clone();
                }
            }
            let mut step = Vec::new();
            for l in 0..cfg.n_layers {
                let first = rng.below(cfg.n_experts);
                let mut second = rng.below(cfg.n_experts);
                while second == first {
                    second = rng.below(cfg.n_experts);
                }
                step.push((l, first));
                step.push((l, second));
            }
            prev = Some(step.clone());
            step
        })
        .collect()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let steps = if smoke { 12 } else { 200 };

    let cfg = bench_config();
    let weights = Arc::new(generate_weights(cfg, 42));
    let scheme = Scheme::Int4 { block: 16 };
    let ram = Arc::new(HostExpertStore::build(&weights, scheme).unwrap());
    let entry_bytes = ram.expert_transfer_bytes();
    let total_entries = cfg.n_layers * cfg.n_experts;
    let schedule = demand_schedule(&cfg, steps, 7);
    // RAM budgets in entries, smallest to the full expert set
    let budgets = [4usize, 8, 16, total_entries];

    // --- part 1: the live tiered store under a demand replay --------------
    println!(
        "== tiered_store: {} demand fetches, {} entries × {} B (int4) ==",
        steps * cfg.n_layers * cfg.top_k,
        total_entries,
        entry_bytes
    );
    let mut live_rows = Vec::new();
    let mut live_hit_rates = Vec::new();
    let mut live_disk_p99 = Vec::new();
    for &budget in &budgets {
        let tier = HostTierConfig {
            ram_budget_bytes: budget * entry_bytes,
            policy: PolicyKind::Lru,
            seed: 0,
            spill_dir: None,
        };
        let store = Arc::new(HostExpertStore::build_tiered(&weights, scheme, &tier).unwrap());
        // spot-check bit identity against the all-RAM store before timing
        for &(l, e) in schedule[0].iter().take(2) {
            assert_eq!(store.fetch(l, e), ram.fetch(l, e), "disk tier rewrote expert bytes");
        }
        let pool = BufferPool::new();
        for step in &schedule {
            for &(l, e) in step {
                let (w1, w3, w2) = store.fetch_pooled(&pool, l, e);
                pool.release(w1);
                pool.release(w3);
                pool.release(w2);
            }
        }
        let ht = store.tier_stats();
        assert_eq!(
            ht.ram_hits + ht.disk_promotions,
            ht.host_accesses,
            "tier counters leak at budget {budget}"
        );
        println!(
            "budget {budget:>2} entries: hit rate {:>5.1}%  promotions {:>5}  \
             evictions {:>5}  disk p99 {:>9} ns",
            100.0 * ht.ram_hit_rate(),
            ht.disk_promotions,
            ht.ram_evictions,
            ht.disk_read_p99_ns
        );
        live_hit_rates.push(ht.ram_hit_rate());
        live_disk_p99.push(ht.disk_read_p99_ns);
        live_rows.push(Value::obj(vec![
            ("budget_entries", Value::from(budget)),
            ("budget_bytes", Value::from((budget * entry_bytes) as f64)),
            ("ram_hit_rate", Value::from(ht.ram_hit_rate())),
            ("ram_hits", Value::from(ht.ram_hits as f64)),
            ("disk_promotions", Value::from(ht.disk_promotions as f64)),
            ("ram_evictions", Value::from(ht.ram_evictions as f64)),
            ("disk_read_ns", Value::from(ht.disk_read_ns as f64)),
            ("disk_read_p99_ns", Value::from(ht.disk_read_p99_ns as f64)),
        ]));
    }

    // --- part 2: offline RAM-budget sweep on the simulated disk ------------
    let trace = tracegen::generate(&tracegen::TraceGenConfig {
        n_layers: cfg.n_layers,
        n_tokens: steps.max(20),
        seed: 7,
        ..Default::default()
    });
    let disk = DiskProfile::default();
    let mut sim_rows = Vec::new();
    let mut sim_hit_rates = Vec::new();
    println!("== tiered_store: simulated sweep ({} tokens, SATA-class disk) ==", trace.n_tokens());
    for &budget in &budgets {
        let r = cachesim::replay_host_tier(
            &trace,
            PolicyKind::Lru,
            4,
            PolicyKind::Lru,
            budget,
            0,
            disk,
            entry_bytes,
        );
        println!(
            "budget {budget:>2} entries: hit rate {:>5.1}%  disk {:>8.3} ms",
            100.0 * r.host.ram_hit_rate(),
            r.disk_s * 1e3
        );
        sim_hit_rates.push(r.host.ram_hit_rate());
        sim_rows.push(Value::obj(vec![
            ("budget_entries", Value::from(budget)),
            ("ram_hit_rate", Value::from(r.host.ram_hit_rate())),
            ("disk_promotions", Value::from(r.host.disk_promotions as f64)),
            ("disk_s", Value::from(r.disk_s)),
        ]));
    }

    let artifact = Value::obj(vec![
        ("bench", Value::from("tiered_store")),
        ("smoke", Value::from(smoke)),
        ("scheme", Value::from("int4")),
        ("entry_bytes", Value::from(entry_bytes)),
        ("total_entries", Value::from(total_entries)),
        ("live_replay", Value::Arr(live_rows)),
        ("sim_sweep", Value::Arr(sim_rows)),
    ]);
    std::fs::write("BENCH_tiered_store.json", json::to_string(&artifact))
        .expect("write BENCH_tiered_store.json");
    println!("wrote BENCH_tiered_store.json");

    // the sweep IS the perf gate: a LRU host tier is a stack algorithm, so
    // the hit rate must be monotone in the budget, and bounding RAM far
    // below the expert set must actually cost hit rate (the second cliff);
    // not enforced in --smoke where the replay is too short to trust
    if !smoke {
        for rates in [&live_hit_rates, &sim_hit_rates] {
            for w in rates.windows(2) {
                assert!(
                    w[1] >= w[0] - 1e-9,
                    "hit rate not monotone in RAM budget: {rates:?}"
                );
            }
            assert!(
                rates[budgets.len() - 1] > rates[0] + 0.05,
                "full-RAM budget shows no cliff over {} entries: {rates:?}",
                budgets[0]
            );
        }
        assert!(
            live_disk_p99[0] > 0,
            "no disk read latency recorded at the smallest budget"
        );
    }
}
