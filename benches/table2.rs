//! Bench target for paper Table 2: regenerates the LRU-vs-LFU × 4-GPU
//! comparison (fitted + physical profiles) and times the pipeline.

use moe_offload::bench_harness::Bencher;
use moe_offload::figures::{table2, FigCtx};

fn main() {
    let dir = std::env::temp_dir().join(format!("bench-t2-{}", std::process::id()));
    let ctx = FigCtx::synthetic(&dir, 128, 0);
    let mut b = Bencher::new(1, 5);
    b.bench("table2/regenerate", || table2::run(&ctx).unwrap());
    println!("{}", b.render());
    println!("--- Table 2 output ---");
    println!("{}", std::fs::read_to_string(dir.join("table2.txt")).unwrap());
    std::fs::remove_dir_all(&dir).ok();
}
