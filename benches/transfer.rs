//! Bench: host-store build + per-expert transfer (dequantize) rates by
//! quantization scheme — the CPU half of the offloading hot path.

use moe_offload::bench_harness::Bencher;
use moe_offload::model::weights::generate_weights;
use moe_offload::model::ModelConfig;
use moe_offload::offload::store::HostExpertStore;
use moe_offload::quant::Scheme;

fn main() {
    let weights = generate_weights(ModelConfig::DEFAULT, 42);
    let mut b = Bencher::new(1, 8);

    for scheme in [Scheme::F32, Scheme::Int8 { block: 64 }, Scheme::Int4 { block: 16 }] {
        let store = HostExpertStore::build(&weights, scheme).unwrap();
        let bytes = store.expert_transfer_bytes();
        b.bench_units(
            &format!("dequant/{}/{}KB-expert", scheme.name(), bytes / 1024),
            Some((weights.config.expert_bytes_f32() as f64 / 1e6, "MBf32"),),
            &mut || store.fetch(0, 0),
        );
    }

    // store construction (startup cost)
    for scheme in [Scheme::Int8 { block: 64 }, Scheme::Int4 { block: 16 }] {
        b.bench(&format!("store-build/{}", scheme.name()), || {
            HostExpertStore::build(&weights, scheme).unwrap().total_bytes()
        });
    }

    println!("{}", b.render());
}
