//! Bench: the learned cross-layer expert predictor (`offload::learned`).
//! For each seed, trains on the first half of a synthetic activation trace
//! and scores the second half two ways: top-k guess accuracy per layer
//! boundary, and cache hit rate when the predictions drive eviction
//! (`cachesim::replay_learned`) against LRU / LFU / clairvoyant Belady at
//! the same capacity. Reports the fraction of the LRU→Belady gap the
//! learned policy closes and writes `BENCH_predictor.json`
//! (see EXPERIMENTS.md).
//!
//!     cargo bench --bench predictor [-- --smoke]

use moe_offload::cache::PolicyKind;
use moe_offload::offload::learned::{self, TrainConfig};
use moe_offload::sim::{cachesim, tracegen};
use moe_offload::util::json::{self, Value};

/// Frozen evaluation protocol (EXPERIMENTS.md §predictor): Mixtral-mini
/// depth, paper-calibrated locality, a capacity tight enough that policy
/// choice matters (4 of 8 experts resident per layer).
const LAYERS: usize = 12;
const CAPACITY: usize = 4;
const LOCALITY: f64 = 0.3;
const SEEDS: [u64; 3] = [0, 1, 2];

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let tokens = if smoke { 128 } else { 1024 };

    println!(
        "== predictor: {} layers × {} tokens/seed, train first half, \
         replay second half at capacity {} ==",
        LAYERS, tokens, CAPACITY
    );
    let mut rows = Vec::new();
    let mut agg_acc = 0.0;
    let mut agg = [0.0f64; 4]; // learned, lru, lfu, belady hit rates
    let mut agg_gap = 0.0;
    for &seed in &SEEDS {
        let mut train = tracegen::generate(&tracegen::TraceGenConfig {
            n_layers: LAYERS,
            n_tokens: tokens,
            locality: LOCALITY,
            seed,
            ..Default::default()
        });
        let eval = train.split_off(tokens / 2);
        let out = learned::train_on_trace(&train, &TrainConfig::default())
            .expect("training on a generated trace cannot fail");
        let acc = learned::evaluate_on_trace(&out.predictor, &eval, eval.top_k)
            .expect("eval half shares the train half's geometry");

        let mut t = eval.clone();
        let learned_r = cachesim::replay_learned(&mut t, &out.predictor, CAPACITY);
        let mut t = eval.clone();
        let lru = cachesim::replay(&mut t, PolicyKind::Lru, CAPACITY, seed);
        let mut t = eval.clone();
        let lfu = cachesim::replay(&mut t, PolicyKind::Lfu, CAPACITY, seed);
        let mut t = eval.clone();
        let belady = cachesim::replay(&mut t, PolicyKind::Belady, CAPACITY, seed);

        let hr = [
            learned_r.stats.hit_rate(),
            lru.stats.hit_rate(),
            lfu.stats.hit_rate(),
            belady.stats.hit_rate(),
        ];
        let denom = hr[3] - hr[1];
        let gap = if denom > 0.0 { (hr[0] - hr[1]) / denom } else { 0.0 };
        println!(
            "seed {seed}: top-{} accuracy {:>5.1}%  hit-rate learned {:>5.1}%  \
             lru {:>5.1}%  lfu {:>5.1}%  belady {:>5.1}%  gap closed {:>+5.1}%",
            eval.top_k,
            100.0 * acc.overall.precision(),
            100.0 * hr[0],
            100.0 * hr[1],
            100.0 * hr[2],
            100.0 * hr[3],
            100.0 * gap
        );
        agg_acc += acc.overall.precision();
        for (a, h) in agg.iter_mut().zip(&hr) {
            *a += h;
        }
        agg_gap += gap;
        let per_layer: Vec<Value> =
            acc.per_layer.iter().map(|pr| Value::from(pr.precision())).collect();
        rows.push(Value::obj(vec![
            ("seed", Value::from(seed as usize)),
            ("topk_accuracy", Value::from(acc.overall.precision())),
            ("topk_accuracy_per_layer", Value::Arr(per_layer)),
            ("hit_rate_learned", Value::from(hr[0])),
            ("hit_rate_lru", Value::from(hr[1])),
            ("hit_rate_lfu", Value::from(hr[2])),
            ("hit_rate_belady", Value::from(hr[3])),
            ("gap_closed_vs_belady", Value::from(gap)),
        ]));
    }
    let n = SEEDS.len() as f64;
    agg_acc /= n;
    for a in agg.iter_mut() {
        *a /= n;
    }
    agg_gap /= n;
    println!(
        "aggregate over {} seeds: accuracy {:>5.1}%  learned {:>5.1}%  lru {:>5.1}%  \
         lfu {:>5.1}%  belady {:>5.1}%  gap closed {:>+5.1}%",
        SEEDS.len(),
        100.0 * agg_acc,
        100.0 * agg[0],
        100.0 * agg[1],
        100.0 * agg[2],
        100.0 * agg[3],
        100.0 * agg_gap
    );

    let artifact = Value::obj(vec![
        ("bench", Value::from("predictor")),
        ("smoke", Value::from(smoke)),
        (
            "protocol",
            Value::obj(vec![
                ("n_layers", Value::from(LAYERS)),
                ("n_tokens", Value::from(tokens)),
                ("locality", Value::from(LOCALITY)),
                ("capacity", Value::from(CAPACITY)),
                ("n_seeds", Value::from(SEEDS.len())),
            ]),
        ),
        ("seeds", Value::Arr(rows)),
        (
            "aggregate",
            Value::obj(vec![
                ("topk_accuracy", Value::from(agg_acc)),
                ("hit_rate_learned", Value::from(agg[0])),
                ("hit_rate_lru", Value::from(agg[1])),
                ("hit_rate_lfu", Value::from(agg[2])),
                ("hit_rate_belady", Value::from(agg[3])),
                ("gap_closed_vs_belady", Value::from(agg_gap)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_predictor.json", json::to_string(&artifact))
        .expect("write BENCH_predictor.json");
    println!("wrote BENCH_predictor.json");

    // The perf gate: on the full protocol the learned policy must beat
    // both baselines it can actually see (LRU and LFU) and close a real
    // fraction of the LRU→Belady gap, and the guesses themselves must
    // beat chance (top-2-of-8 ⇒ 0.25). Not enforced in --smoke, where
    // the half-trace is too short for stable rates.
    if !smoke {
        assert!(
            agg_acc > 0.30,
            "top-k accuracy {agg_acc:.3} does not beat chance (0.25) with margin"
        );
        assert!(
            agg[0] > agg[1],
            "learned hit rate {:.3} does not beat LRU {:.3}",
            agg[0],
            agg[1]
        );
        assert!(
            agg[0] > agg[2],
            "learned hit rate {:.3} does not beat LFU {:.3}",
            agg[0],
            agg[2]
        );
        assert!(
            agg_gap > 0.05,
            "learned closes only {agg_gap:.3} of the LRU→Belady gap"
        );
    }
}
