//! Paper-figures driver: regenerate every table and figure (synthetic
//! calibrated traces) AND capture a live MiniMixtral trace through the
//! engine, reporting paper-vs-measured for the phenomena the paper claims.
//! This is the end-to-end experiment recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example paper_figures -- --out-dir results

use anyhow::Result;
use moe_offload::cache::PolicyKind;
use moe_offload::engine::{EngineConfig, InferenceEngine};
use moe_offload::figures;
use moe_offload::model::sampler::{Sampler, Sampling};
use moe_offload::model::tokenizer::Tokenizer;
use moe_offload::model::Weights;
use moe_offload::offload::prefetch::PrefetchConfig;
use moe_offload::offload::store::HostExpertStore;
use moe_offload::quant::Scheme;
use moe_offload::runtime::{artifacts::Artifacts, native::NativeBackend, pjrt::PjrtBackend, Backend};
use moe_offload::sim::hardware;
use moe_offload::trace::{export, render};
use moe_offload::util::cliargs::Args;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())?;
    let out_dir = PathBuf::from(args.str_or("out-dir", "results"));

    // 1. synthetic calibrated figures (all tables + figures)
    figures::cmd_figures(&args)?;

    // 2. live trace through the real engine (pjrt by default, native fallback)
    let artifacts = Artifacts::load(Path::new(&args.str_or("artifacts", "artifacts")))?;
    let weights = Arc::new(Weights::load(&artifacts.weights_path)?);
    let backend_kind = args.str_or("backend", "pjrt");
    let backend: Box<dyn Backend> = match backend_kind.as_str() {
        "native" => Box::new(NativeBackend::new(Arc::clone(&weights))),
        _ => Box::new(PjrtBackend::new(&artifacts, &weights)?),
    };
    let store = Arc::new(HostExpertStore::build(&weights, Scheme::Int4 { block: 16 })?);
    let mut engine = InferenceEngine::new(
        backend,
        store,
        EngineConfig {
            cache_capacity: 4,
            policy: PolicyKind::Lru,
            prefetch: PrefetchConfig { enabled: true, k: 2 },
            transfer_workers: 0,
            profile: hardware::by_name("A6000").unwrap(),
            disk: hardware::DiskProfile::default(),
            seed: 0,
            record_trace: true,
            fetch_retries: 2,
            demand_deadline_ms: 0,
        },
    );
    let tk = Tokenizer::new(engine.config().vocab_size);
    let prompt = tk.encode("Introduce yourself, limit your response in 50 words.");
    let n = args.usize_or("n", 32)?;
    let mut sampler = Sampler::new(Sampling::paper_hw_comparison(), 0);
    println!("[live] decoding {n} tokens through the {backend_kind} engine ...");
    let out = engine.generate(&prompt, n, &mut sampler)?;
    let trace = out.trace.expect("trace");

    let mut report = String::from("== live MiniMixtral trace (real engine, LRU cap=4, spec on) ==\n");
    report.push_str(&format!(
        "wall tokens/s {:.2}   sim[A6000] tokens/s {:.2}\n",
        out.throughput.tokens_per_s_wall(),
        out.throughput.tokens_per_s_sim()
    ));
    let pr = trace.cache_precision_recall();
    report.push_str(&format!(
        "cache hit-rate {:.1}%  precision {:.1}%  recall {:.1}%\n",
        100.0 * out.cache_stats.hit_rate(),
        100.0 * pr.precision(),
        100.0 * pr.recall()
    ));
    report.push_str(&format!(
        "speculative precision {:.1}% == recall {:.1}%  (paper: 84.6%)\n",
        100.0 * out.spec_pr.precision(),
        100.0 * out.spec_pr.recall()
    ));
    report.push_str(&format!(
        "temporal locality {:.1}%  (uniform baseline {:.1}%)\n",
        100.0 * trace.temporal_locality(),
        100.0 * engine.config().top_k as f64 / engine.config().n_experts as f64
    ));
    for l in figures::paper_layers(trace.n_layers) {
        report.push_str(&format!(
            "layer {:2}: imbalance cv {:.2}\n",
            l + 1,
            trace.layer_imbalance(l)
        ));
    }
    report.push('\n');
    for l in figures::paper_layers(trace.n_layers) {
        report.push_str(&render::layer_grid(&trace, l));
        report.push('\n');
    }
    export::write_file(&out_dir.join("live_trace_report.txt"), &report)?;
    export::write_file(&out_dir.join("live_trace.csv"), &export::trace_csv(&trace))?;
    println!("{report}");
    println!("[live] wrote {}", out_dir.join("live_trace_report.txt").display());
    Ok(())
}
