//! Cache-policy explorer: sweep policy × capacity × workload shape over
//! calibrated synthetic traces and print comparison tables — the tool for
//! reproducing the paper's §5 analysis and probing beyond it (Belady
//! headroom, the LFU-aged hybrid, locality/skew sensitivity).
//!
//!     cargo run --release --example cache_explorer -- --tokens 256

use anyhow::Result;
use moe_offload::cache::PolicyKind;
use moe_offload::sim::costmodel::CostModel;
use moe_offload::sim::hardware::{by_name, ModelScale};
use moe_offload::sim::{cachesim, tracegen};
use moe_offload::util::cliargs::Args;
use moe_offload::util::stats::Table;

fn main() -> Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())?;
    let tokens = args.usize_or("tokens", 256)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let scale = ModelScale::mixtral_8x7b();
    let cm = CostModel::new(by_name("A6000").unwrap(), scale);
    let policies = [
        PolicyKind::Lru,
        PolicyKind::Lfu,
        PolicyKind::LfuAged,
        PolicyKind::Fifo,
        PolicyKind::Random,
        PolicyKind::Belady,
    ];

    // --- sweep 1: capacity at the paper's workload shape ---
    println!("== capacity sweep (paper-shaped trace: locality ~0.3, mid-skew) ==");
    let trace = tracegen::generate(&tracegen::TraceGenConfig::mixtral(tokens, seed));
    let mut t = Table::new(&["capacity", "lru", "lfu", "lfu-aged", "fifo", "random", "belady"]);
    for capacity in [2usize, 3, 4, 5, 6] {
        let results = cachesim::compare(&trace, &policies, capacity, seed);
        let mut row = vec![capacity.to_string()];
        row.extend(results.iter().map(|r| format!("{:.1}%", 100.0 * r.stats.hit_rate())));
        t.row(&row);
    }
    print!("{}", t.render());
    println!("(hit rate; belady = clairvoyant upper bound)\n");

    // --- sweep 2: locality sensitivity at capacity 4 ---
    println!("== locality sweep (capacity 4): when does LRU beat LFU? ==");
    let mut t = Table::new(&["locality", "lru", "lfu", "lfu-aged", "winner"]);
    for loc in [0.0, 0.12, 0.3, 0.5, 0.7, 0.9] {
        let cfg = tracegen::TraceGenConfig {
            n_tokens: tokens,
            locality: loc,
            seed,
            ..Default::default()
        };
        let tr = tracegen::generate(&cfg);
        let rs = cachesim::compare(
            &tr,
            &[PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::LfuAged],
            4,
            seed,
        );
        let hr: Vec<f64> = rs.iter().map(|r| r.stats.hit_rate()).collect();
        let winner = if hr[0] > hr[1] { "lru" } else { "lfu" };
        t.row(&[
            format!("{loc:.2}"),
            format!("{:.1}%", 100.0 * hr[0]),
            format!("{:.1}%", 100.0 * hr[1]),
            format!("{:.1}%", 100.0 * hr[2]),
            winner.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!();

    // --- sweep 3: skew sensitivity ---
    println!("== imbalance sweep (capacity 4): LFU's advantage grows with skew ==");
    let mut t = Table::new(&["zipf-mid", "lru", "lfu", "delta tok/s (A6000)"]);
    for skew in [0.0, 0.5, 1.1, 1.6, 2.2] {
        let cfg = tracegen::TraceGenConfig {
            n_tokens: tokens,
            skew_mid: skew,
            skew_edge: skew * 0.4,
            seed,
            ..Default::default()
        };
        let tr = tracegen::generate(&cfg);
        let rs = cachesim::compare(&tr, &[PolicyKind::Lru, PolicyKind::Lfu], 4, seed);
        let tps: Vec<f64> = rs.iter().map(|r| cm.tokens_per_s(&r.events)).collect();
        t.row(&[
            format!("{skew:.1}"),
            format!("{:.1}%", 100.0 * rs[0].stats.hit_rate()),
            format!("{:.1}%", 100.0 * rs[1].stats.hit_rate()),
            format!("{:+.2}", tps[1] - tps[0]),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
