//! Quickstart: build the offloading engine and decode one prompt — the
//! minimal tour of the public API. Runs from a clean checkout (falls back
//! to seeded synthetic weights + the native backend when `artifacts/` has
//! not been built).
//!
//!     cargo run --release --example quickstart
//!     make artifacts && cargo run --release --example quickstart -- --backend pjrt
//!
//! Flags: --backend native|pjrt  --policy lru|lfu|lfu-aged  --capacity N
//!        --quant f32|int8|int4  --spec  --n N  --synthetic

use anyhow::Result;
use moe_offload::cache::PolicyKind;
use moe_offload::engine::{EngineConfig, InferenceEngine};
use moe_offload::model::sampler::{Sampler, Sampling};
use moe_offload::model::tokenizer::Tokenizer;
use moe_offload::model::weights::generate_weights;
use moe_offload::model::{ModelConfig, Weights};
use moe_offload::offload::prefetch::PrefetchConfig;
use moe_offload::offload::store::HostExpertStore;
use moe_offload::quant::Scheme;
use moe_offload::runtime::{artifacts::Artifacts, native::NativeBackend, pjrt::PjrtBackend, Backend};
use moe_offload::sim::hardware;
use moe_offload::util::cliargs::Args;
use std::path::Path;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())?;

    // 1. weights: AOT artifacts when available (produced by `make
    //    artifacts`), otherwise seeded synthetic MiniMixtral weights
    let artifacts = if args.bool("synthetic") {
        None
    } else {
        match Artifacts::load(Path::new(&args.str_or("artifacts", "artifacts"))) {
            Ok(a) => Some(a),
            Err(e) => {
                println!("note: {e} — falling back to synthetic weights + native backend");
                None
            }
        }
    };
    let weights = match &artifacts {
        Some(a) => Arc::new(Weights::load(&a.weights_path)?),
        None => Arc::new(generate_weights(ModelConfig::DEFAULT, 42)),
    };
    println!(
        "model: {} layers × {} experts (top-{}), {:.1} M params",
        weights.config.n_layers,
        weights.config.n_experts,
        weights.config.top_k,
        weights.n_params() as f64 / 1e6
    );

    // 2. backend: PJRT executes the HLO artifacts; native is the rust oracle
    let backend: Box<dyn Backend> = match (&artifacts, args.str_or("backend", "pjrt").as_str()) {
        (Some(a), "pjrt") => Box::new(PjrtBackend::new(a, &weights)?),
        _ => Box::new(NativeBackend::new(Arc::clone(&weights))),
    };

    // 3. the offloading pieces: quantized host store + engine w/ cache policy
    let scheme = Scheme::parse(&args.str_or("quant", "int4")).unwrap();
    let store = Arc::new(HostExpertStore::build(&weights, scheme)?);
    println!(
        "host store: {} per expert ({}), {:.1} MB total",
        store.expert_transfer_bytes(),
        scheme.name(),
        store.total_bytes() as f64 / (1 << 20) as f64
    );
    let mut engine = InferenceEngine::new(
        backend,
        store,
        EngineConfig {
            cache_capacity: args.usize_or("capacity", 4)?,
            policy: PolicyKind::parse(&args.str_or("policy", "lfu")).unwrap(),
            prefetch: PrefetchConfig { enabled: args.bool("spec"), k: 2 },
            transfer_workers: 0,
            profile: hardware::by_name("A100").unwrap(),
            disk: hardware::DiskProfile::default(),
            seed: 0,
            record_trace: true,
            fetch_retries: 2,
            demand_deadline_ms: 0,
        },
    );

    // 4. decode
    let tk = Tokenizer::new(engine.config().vocab_size);
    let prompt = tk.encode("Introduce yourself, limit your response in 50 words.");
    let mut sampler = Sampler::new(Sampling::paper_mmlu(), 0);
    let out = engine.generate(&prompt, args.usize_or("n", 24)?, &mut sampler)?;

    println!("\ngenerated {} tokens: {:?}", out.generated.len(), tk.decode(&out.generated));
    println!(
        "tokens/s: {:.2} wall, {:.2} simulated on {}",
        out.throughput.tokens_per_s_wall(),
        out.throughput.tokens_per_s_sim(),
        engine.cfg.profile.name
    );
    println!(
        "cache: {:.1}% hit rate ({} hits / {} misses, {} evictions)",
        100.0 * out.cache_stats.hit_rate(),
        out.cache_stats.hits,
        out.cache_stats.misses,
        out.cache_stats.evictions
    );
    if let Some(trace) = &out.trace {
        let pr = trace.cache_precision_recall();
        println!(
            "cache precision {:.1}% / recall {:.1}%  (paper LFU: 29.9 / 59.8)",
            100.0 * pr.precision(),
            100.0 * pr.recall()
        );
    }
    Ok(())
}
