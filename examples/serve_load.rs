//! End-to-end concurrent serving driver (DESIGN.md §6): start the HTTP
//! server, fire concurrent client requests, and report latency percentiles,
//! aggregate throughput, and the shared-cache /metrics breakdown — the
//! serving validation workload for the session scheduler.
//!
//! Runs from a clean checkout (no artifacts needed): by default the server
//! decodes seeded synthetic MiniMixtral weights over the native backend.
//!
//!     cargo run --release --example serve_load -- --requests 8 --concurrency 4
//!
//! Flags: --requests N       total requests              (default 8)
//!        --concurrency C    concurrent client threads   (default 4)
//!        --n T              tokens per request          (default 12)
//!        --max-sessions S   scheduler concurrency (per replica, default = C)
//!        --engine-workers R engine replicas over one shared host store (default 1)
//!        --artifacts DIR    use real artifacts instead of synthetic weights
//!        --backend pjrt     with --artifacts: the AOT PJRT backend

use anyhow::Result;
use moe_offload::cache::PolicyKind;
use moe_offload::engine::{EngineConfig, InferenceEngine};
use moe_offload::model::weights::generate_weights;
use moe_offload::model::{ModelConfig, Weights};
use moe_offload::offload::store::HostExpertStore;
use moe_offload::quant::Scheme;
use moe_offload::runtime::{artifacts::Artifacts, native::NativeBackend, pjrt::PjrtBackend, Backend};
use moe_offload::serve::http::{client_get as http_get, client_post as http_post};
use moe_offload::serve::{self, ServeConfig};
use moe_offload::util::cliargs::Args;
use moe_offload::util::json;
use moe_offload::util::stats::Summary;
use std::net::TcpListener;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

const PROMPTS: [&str; 4] = [
    "Introduce yourself, limit your response in 50 words.",
    "Explain mixture-of-experts offloading in one paragraph.",
    "What is the capital of France and why does caching matter?",
    "Summarize the benefits of LFU over LRU for expert caching.",
];

fn main() -> Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())?;
    let n_requests = args.usize_or("requests", 8)?;
    let concurrency = args.usize_or("concurrency", 4)?.max(1);
    let n_tokens = args.usize_or("n", 12)?;
    let max_sessions = args.usize_or("max-sessions", concurrency)?;
    let engine_workers = args.usize_or("engine-workers", 1)?.max(1);
    let backend_kind = args.str_or("backend", "native");
    let artifacts_dir = args.get("artifacts").map(|s| s.to_string());

    // start the server on an ephemeral port
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = Arc::clone(&shutdown);
    let server = std::thread::spawn(move || -> Result<()> {
        // weights + the host expert store are shared: every replica gets
        // the SAME Arc, so the RAM budget and disk tier stay global
        let (weights, artifacts) = match &artifacts_dir {
            Some(dir) => {
                let a = Artifacts::load(Path::new(dir))?;
                let w = Arc::new(Weights::load(&a.weights_path)?);
                (w, Some(a))
            }
            None => (Arc::new(generate_weights(ModelConfig::DEFAULT, 42)), None),
        };
        let store = Arc::new(HostExpertStore::build(&weights, Scheme::Int4 { block: 16 })?);
        let make = move |_replica: usize| -> Result<InferenceEngine> {
            let backend: Box<dyn Backend> = match (&artifacts, backend_kind.as_str()) {
                (Some(a), "pjrt") => Box::new(PjrtBackend::new(a, &weights)?),
                _ => Box::new(NativeBackend::new(Arc::clone(&weights))),
            };
            Ok(InferenceEngine::new(
                backend,
                Arc::clone(&store),
                EngineConfig::serving(4, PolicyKind::Lfu, true),
            ))
        };
        let cfg = ServeConfig {
            http_workers: concurrency.max(4),
            max_sessions,
            engine_workers,
            ..ServeConfig::default()
        };
        let _ = serve::serve(listener, make, cfg, sd);
        Ok(())
    });

    // wait for health
    loop {
        if let Ok((200, _)) = http_get(addr, "/healthz") {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!(
        "server up on {addr}; firing {n_requests} requests ({concurrency} concurrent clients, {max_sessions} scheduler sessions) ..."
    );

    // client load
    let t0 = Instant::now();
    let latencies = Arc::new(std::sync::Mutex::new(Summary::new()));
    let errors = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let mut handles = Vec::new();
    for w in 0..concurrency {
        let latencies = Arc::clone(&latencies);
        let errors = Arc::clone(&errors);
        handles.push(std::thread::spawn(move || {
            let per_worker = n_requests / concurrency + usize::from(w < n_requests % concurrency);
            for i in 0..per_worker {
                let prompt = PROMPTS[(w + i) % PROMPTS.len()];
                let body = format!(
                    r#"{{"prompt":"{prompt}","n_tokens":{n_tokens},"greedy":true}}"#
                );
                let t = Instant::now();
                match http_post(addr, "/generate", &body) {
                    Ok((200, resp_body)) => {
                        latencies.lock().unwrap().add(t.elapsed().as_secs_f64());
                        let v = json::parse(&resp_body).expect("json response");
                        assert_eq!(v.get("n_generated").as_usize(), Some(n_tokens));
                        assert!(v.get("session_id").as_usize().unwrap_or(0) > 0);
                    }
                    other => {
                        eprintln!("request failed: {other:?}");
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();

    let (_, metrics_body) = http_get(addr, "/metrics")?;
    let m = json::parse(&metrics_body).map_err(|e| anyhow::anyhow!("metrics json: {e}"))?;

    let lat = latencies.lock().unwrap();
    println!("\n== serve_load results ==");
    println!("requests ok: {}  errors: {}", lat.n(), errors.load(Ordering::Relaxed));
    println!(
        "latency: mean {:.0} ms  p50 {:.0} ms  p99 {:.0} ms",
        1e3 * lat.mean(),
        1e3 * lat.p50(),
        1e3 * lat.p99()
    );
    println!(
        "throughput: {:.2} req/s, {:.1} generated tok/s aggregate",
        lat.n() as f64 / wall,
        (lat.n() * n_tokens) as f64 / wall
    );

    let cache = m.get("shared_cache");
    println!(
        "\nshared cache [{} cap={}]: {:.1}% hit rate ({} hits / {} misses), {} prefetch hits ({} paid by another session)",
        cache.get("policy").as_str().unwrap_or("?"),
        cache.get("capacity_per_layer").as_usize().unwrap_or(0),
        100.0 * cache.get("hit_rate").as_f64().unwrap_or(0.0),
        cache.get("hits").as_usize().unwrap_or(0),
        cache.get("misses").as_usize().unwrap_or(0),
        cache.get("prefetch_hits").as_usize().unwrap_or(0),
        cache.get("cross_session_prefetch_hits").as_usize().unwrap_or(0),
    );
    println!(
        "admission: rejected {} (backpressure {} / inflight cap {}), shed {}, queue-wait p99 {:.1} µs",
        m.get("rejected_total").as_usize().unwrap_or(0),
        m.get("rejected_backpressure").as_usize().unwrap_or(0),
        m.get("rejected_inflight").as_usize().unwrap_or(0),
        m.get("shed_total").as_usize().unwrap_or(0),
        m.get("queue_wait_ns").get("p99").as_f64().unwrap_or(0.0) / 1e3,
    );
    println!(
        "completed sessions: {}   per-session share of the one shared cache:",
        m.get("completed_sessions").as_usize().unwrap_or(0)
    );
    for s in m.get("sessions").as_arr().unwrap_or(&[]) {
        println!(
            "  session {:>3} [{}]: {} tokens, hit rate {:.1}%, spec P {:.1}% / R {:.1}%",
            s.get("id").as_usize().unwrap_or(0),
            s.get("state").as_str().unwrap_or("?"),
            s.get("tokens").as_usize().unwrap_or(0),
            100.0 * s.get("hit_rate").as_f64().unwrap_or(0.0),
            100.0 * s.get("spec_precision").as_f64().unwrap_or(0.0),
            100.0 * s.get("spec_recall").as_f64().unwrap_or(0.0),
        );
    }

    shutdown.store(true, Ordering::Relaxed);
    let _ = server.join();
    assert_eq!(errors.load(Ordering::Relaxed), 0, "requests failed");
    assert!(
        m.get("completed_sessions").as_usize().unwrap_or(0) >= n_requests.min(4),
        "expected at least 4 completed sessions sharing the cache"
    );
    Ok(())
}
