//! End-to-end serving driver (DESIGN.md §5 "E2E driver"): start the HTTP
//! server on a real model backend, fire a batch of concurrent client
//! requests, and report latency percentiles + aggregate throughput — the
//! serving-paper validation workload.
//!
//!     cargo run --release --example serve_load -- --requests 8 --n 12
//!
//! Flags: --backend native|pjrt (default native for speed)
//!        --requests N  --concurrency C  --n tokens-per-request

use anyhow::Result;
use moe_offload::cache::PolicyKind;
use moe_offload::engine::{EngineConfig, InferenceEngine};
use moe_offload::offload::prefetch::PrefetchConfig;
use moe_offload::offload::store::HostExpertStore;
use moe_offload::quant::Scheme;
use moe_offload::runtime::{artifacts::Artifacts, native::NativeBackend, pjrt::PjrtBackend, Backend};
use moe_offload::serve;
use moe_offload::sim::hardware;
use moe_offload::util::cliargs::Args;
use moe_offload::util::json;
use moe_offload::util::stats::Summary;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

const PROMPTS: [&str; 4] = [
    "Introduce yourself, limit your response in 50 words.",
    "Explain mixture-of-experts offloading in one paragraph.",
    "What is the capital of France and why does caching matter?",
    "Summarize the benefits of LFU over LRU for expert caching.",
];

fn http_post(addr: std::net::SocketAddr, path: &str, body: &str) -> Result<(u16, String)> {
    let mut s = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes())?;
    let mut resp = String::new();
    s.read_to_string(&mut resp)?;
    let status: u16 = resp
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or(0);
    let body = resp.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    Ok((status, body))
}

fn main() -> Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())?;
    let n_requests = args.usize_or("requests", 8)?;
    let concurrency = args.usize_or("concurrency", 4)?;
    let n_tokens = args.usize_or("n", 12)?;
    let backend_kind = args.str_or("backend", "native");
    let artifacts_dir = args.str_or("artifacts", "artifacts");

    // start the server on an ephemeral port
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = Arc::clone(&shutdown);
    let server = std::thread::spawn(move || {
        let make = move || -> Result<InferenceEngine> {
            let artifacts = Artifacts::load(Path::new(&artifacts_dir))?;
            let weights = Arc::new(moe_offload::model::Weights::load(&artifacts.weights_path)?);
            let backend: Box<dyn Backend> = match backend_kind.as_str() {
                "pjrt" => Box::new(PjrtBackend::new(&artifacts, &weights)?),
                _ => Box::new(NativeBackend::new(Arc::clone(&weights))),
            };
            let store = Arc::new(HostExpertStore::build(&weights, Scheme::Int4 { block: 16 })?);
            Ok(InferenceEngine::new(
                backend,
                store,
                EngineConfig {
                    cache_capacity: 4,
                    policy: PolicyKind::Lfu,
                    prefetch: PrefetchConfig { enabled: true, k: 2 },
                    overlap: false,
                    profile: hardware::by_name("A100").unwrap(),
                    seed: 0,
                    record_trace: false,
                },
            ))
        };
        let _ = serve::serve(listener, make, 4, sd);
    });

    // wait for health
    loop {
        if let Ok(mut s) = TcpStream::connect(addr) {
            let _ = s.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n");
            let mut b = String::new();
            let _ = s.read_to_string(&mut b);
            if b.contains("200") {
                break;
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("server up on {addr}; firing {n_requests} requests ({concurrency} concurrent) ...");

    // client load
    let t0 = Instant::now();
    let latencies = Arc::new(std::sync::Mutex::new(Summary::new()));
    let errors = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let mut handles = Vec::new();
    for w in 0..concurrency {
        let latencies = Arc::clone(&latencies);
        let errors = Arc::clone(&errors);
        handles.push(std::thread::spawn(move || {
            let per_worker = n_requests / concurrency + usize::from(w < n_requests % concurrency);
            for i in 0..per_worker {
                let prompt = PROMPTS[(w + i) % PROMPTS.len()];
                let body = format!(
                    r#"{{"prompt":"{prompt}","n_tokens":{n_tokens},"greedy":true}}"#
                );
                let t = Instant::now();
                match http_post(addr, "/generate", &body) {
                    Ok((200, resp_body)) => {
                        latencies.lock().unwrap().add(t.elapsed().as_secs_f64());
                        let v = json::parse(&resp_body).expect("json response");
                        assert_eq!(v.get("n_generated").as_usize(), Some(n_tokens));
                    }
                    other => {
                        eprintln!("request failed: {other:?}");
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();

    // metrics endpoint
    let (_, metrics_body) = {
        let mut s = TcpStream::connect(addr)?;
        s.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n")?;
        let mut b = String::new();
        s.read_to_string(&mut b)?;
        (200u16, b.split("\r\n\r\n").nth(1).unwrap_or("").to_string())
    };

    let lat = latencies.lock().unwrap();
    println!("\n== serve_load results ==");
    println!("requests ok: {}  errors: {}", lat.n(), errors.load(Ordering::Relaxed));
    println!(
        "latency: mean {:.0} ms  p50 {:.0} ms  p99 {:.0} ms",
        1e3 * lat.mean(),
        1e3 * lat.p50(),
        1e3 * lat.p99()
    );
    println!(
        "throughput: {:.2} req/s, {:.1} generated tok/s aggregate",
        lat.n() as f64 / wall,
        (lat.n() * n_tokens) as f64 / wall
    );
    println!("server metrics: {metrics_body}");

    shutdown.store(true, Ordering::Relaxed);
    let _ = server.join();
    assert_eq!(errors.load(Ordering::Relaxed), 0, "requests failed");
    Ok(())
}
