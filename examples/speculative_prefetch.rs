//! Speculative expert pre-fetching demo (paper §3.2 / §5.4): run the live
//! engine with speculation off vs on (vs on + transfer pipeline), print
//! metrics and render the Figure-13/14-style per-token grids from the
//! live trace.
//!
//!     cargo run --release --example speculative_prefetch -- --backend native

use anyhow::Result;
use moe_offload::cache::PolicyKind;
use moe_offload::engine::{EngineConfig, GenerationOutput, InferenceEngine};
use moe_offload::model::sampler::{Sampler, Sampling};
use moe_offload::model::tokenizer::Tokenizer;
use moe_offload::model::Weights;
use moe_offload::offload::prefetch::PrefetchConfig;
use moe_offload::offload::store::HostExpertStore;
use moe_offload::quant::Scheme;
use moe_offload::runtime::{artifacts::Artifacts, native::NativeBackend, pjrt::PjrtBackend, Backend};
use moe_offload::sim::hardware;
use moe_offload::trace::render;
use moe_offload::util::cliargs::Args;
use moe_offload::util::stats::Table;
use std::path::Path;
use std::sync::Arc;

fn run_once(
    artifacts: &Artifacts,
    weights: &Arc<Weights>,
    backend_kind: &str,
    spec: bool,
    transfer_workers: usize,
    n: usize,
) -> Result<(GenerationOutput, f64)> {
    let backend: Box<dyn Backend> = match backend_kind {
        "pjrt" => Box::new(PjrtBackend::new(artifacts, weights)?),
        _ => Box::new(NativeBackend::new(Arc::clone(weights))),
    };
    let store = Arc::new(HostExpertStore::build(weights, Scheme::Int4 { block: 16 })?);
    let mut engine = InferenceEngine::new(
        backend,
        store,
        EngineConfig {
            cache_capacity: 4,
            policy: PolicyKind::Lru,
            prefetch: PrefetchConfig { enabled: spec, k: 2 },
            transfer_workers,
            profile: hardware::by_name("A6000").unwrap(),
            disk: hardware::DiskProfile::default(),
            seed: 0,
            record_trace: true,
            fetch_retries: 2,
            demand_deadline_ms: 0,
        },
    );
    let tk = Tokenizer::new(engine.config().vocab_size);
    let prompt = tk.encode("Introduce yourself, limit your response in 50 words.");
    let mut sampler = Sampler::new(Sampling::Greedy, 0);
    let out = engine.generate(&prompt, n, &mut sampler)?;
    let sim_now = engine.sim_now();
    Ok((out, sim_now))
}

fn main() -> Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())?;
    let backend_kind = args.str_or("backend", "native");
    let n = args.usize_or("n", 24)?;
    let artifacts = Artifacts::load(Path::new(&args.str_or("artifacts", "artifacts")))?;
    let weights = Arc::new(Weights::load(&artifacts.weights_path)?);

    let mut table = Table::new(&[
        "config", "sim tok/s (A6000)", "hit-rate", "transferred MB", "spec P", "spec R",
    ]);
    let mut spec_trace = None;
    for (name, spec, workers) in [
        ("baseline (no spec)", false, 0),
        ("speculative", true, 0),
        ("speculative+pipeline", true, 2),
    ] {
        let (out, _) = run_once(&artifacts, &weights, &backend_kind, spec, workers, n)?;
        table.row(&[
            name.to_string(),
            format!("{:.2}", out.throughput.tokens_per_s_sim()),
            format!("{:.1}%", 100.0 * out.cache_stats.hit_rate()),
            format!("{:.1}", out.transfer_bytes as f64 / (1 << 20) as f64),
            if spec { format!("{:.1}%", 100.0 * out.spec_pr.precision()) } else { "-".into() },
            if spec { format!("{:.1}%", 100.0 * out.spec_pr.recall()) } else { "-".into() },
        ]);
        if spec && workers == 0 {
            spec_trace = out.trace;
        }
    }
    print!("{}", table.render());
    println!(
        "\nStructural identity (paper §5.4): precision == recall for speculation\n\
         because |guessed| == |activated| forces FP == FN.\n"
    );

    if let Some(t) = spec_trace {
        let picks = [t.n_tokens() / 3, 2 * t.n_tokens() / 3];
        for (i, &tok) in picks.iter().enumerate() {
            println!("--- live Figure {} (token {tok}) ---", 13 + i);
            println!("{}", render::spec_grid(&t, tok));
        }
        let pr = t.spec_precision_recall();
        assert_eq!(pr.fp, pr.fn_, "P==R identity violated");
    }
    Ok(())
}
