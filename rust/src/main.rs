//! moe-offload CLI — leader entrypoint for the serving stack.
//!
//! Subcommands:
//!   selfcheck  validate PJRT + native runtimes against the JAX goldens
//!   generate   decode a prompt through the offloading engine
//!   simulate   trace-driven cache-policy comparison + cost model
//!   serve      completion-routed concurrent HTTP serving front (see
//!              rust/src/serve/): workers parse + admission-check only,
//!              responders write finished generations back
//!              --max-sessions N            sessions interleaved on the engine worker
//!              --queue-depth N             bounded admission queue (503 beyond it)
//!              --queue-timeout-ms N        shed queued requests older than N ms
//!                                          with 503 + Retry-After (0 = never)
//!              --max-inflight-sessions N   cap on accepted-but-unfinished
//!                                          requests (503 beyond it)
//!              --prefill-chunk N           chunked prefill: ≤ N prompt tokens
//!                                          per round, one chunk per round,
//!                                          rotated across prefilling sessions
//!                                          (0 = one-token-per-session rounds)
//!              --round-budget-tokens N     cap on total tokens advanced per
//!                                          scheduler round, deficit carry-over
//!                                          (0 = unbounded)
//!              --responders N              response-writer threads
//!              --http-workers N            parse/admission threads
//!              --transfer-workers N        async dequant pipeline workers
//!                                          (0 = sync; legacy --overlap = 1)
//!              --prefetch-source S         guess stream feeding the prefetcher:
//!                                          gate | markov | learned (per-source
//!                                          hit attribution in /metrics)
//!              --predictor-weights PATH    learned-predictor weights (default
//!                                          data/predictor_weights.json when the
//!                                          learned policy/source is active;
//!                                          absent default degrades to LFU /
//!                                          idle prefetch)
//!              --fetch-retries N           bounded retries (with exponential
//!                                          backoff) on transient expert-fetch
//!                                          failures (default 2)
//!              --demand-deadline-ms N      per-token demand-miss deadline:
//!                                          interactive rounds degrade around
//!                                          an expert stalled past N ms instead
//!                                          of waiting (0 = never degrade)
//!              --host-cache-mb N           bound the host RAM tier to N MB;
//!                                          colder quantized experts spill to
//!                                          disk and are promoted back on
//!                                          demand (0 = everything in RAM)
//!              --disk-read-mbps N          simulated read bandwidth of the
//!                                          disk tier under host RAM
//!                                          (0 = SATA-SSD class default)
//!              --retry-after-s N           Retry-After seconds advertised by
//!                                          every admission-control 503
//!                                          (default 1)
//!              --synthetic                 seeded synthetic weights + native
//!                                          backend, works from a clean checkout
//!              POST /generate?stream=1 streams chunked text as it decodes;
//!              ?priority=batch (or x-priority: batch) opts into the
//!              throughput tier
//!   figures    regenerate every paper table/figure into --out-dir
//!   train-predictor
//!              fit the cross-layer expert predictor on an activation
//!              trace (--trace activations.csv, or a synthetic trace via
//!              --tokens/--layers/--seed) and write its weights JSON
//!              (--out, default data/predictor_weights.json); holds out
//!              the trace tail for the reported precision/recall
//!              (--holdout fraction, 0 trains on everything).
//!              Consumers: `--policy learned` (reuse-distance eviction)
//!              and `--prefetch-source learned|markov|gate`.

use anyhow::{bail, Result};
use moe_offload::cache::PolicyKind;
use moe_offload::engine::{selfcheck, EngineConfig, InferenceEngine};
use moe_offload::model::sampler::{Sampler, Sampling};
use moe_offload::model::tokenizer::Tokenizer;
use moe_offload::model::Weights;
use moe_offload::offload::learned::{self, TrainConfig};
use moe_offload::offload::prefetch::{PrefetchConfig, PrefetchSource};
use moe_offload::offload::store::{HostExpertStore, HostTierConfig};
use moe_offload::quant::Scheme;
use moe_offload::runtime::{artifacts::Artifacts, native::NativeBackend, pjrt::PjrtBackend, Backend};
use moe_offload::sim::{cachesim, costmodel::CostModel, hardware, tracegen};
use moe_offload::trace::{export, render};
use moe_offload::util::cliargs::Args;
use moe_offload::util::stats::Table;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("selfcheck") => cmd_selfcheck(&args),
        Some("generate") => cmd_generate(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("serve") => moe_offload::serve::cmd_serve(&args),
        Some("figures") => moe_offload::figures::cmd_figures(&args),
        Some("train-predictor") => cmd_train_predictor(&args),
        Some(other) => bail!(
            "unknown command {other:?}; try selfcheck|generate|simulate|serve|figures|train-predictor"
        ),
        None => {
            println!(
                "usage: moe-offload <selfcheck|generate|simulate|serve|figures|train-predictor> [flags]"
            );
            Ok(())
        }
    }
}

/// Shared loading: artifacts + weights.
struct Loaded {
    artifacts: Artifacts,
    weights: Arc<Weights>,
}

fn load(args: &Args) -> Result<Loaded> {
    let dir = args.str_or("artifacts", "artifacts");
    let artifacts = Artifacts::load(Path::new(&dir))?;
    let weights = Arc::new(Weights::load(&artifacts.weights_path)?);
    weights.validate_layout()?;
    Ok(Loaded { artifacts, weights })
}

fn make_backend(kind: &str, loaded: &Loaded) -> Result<Box<dyn Backend>> {
    match kind {
        "pjrt" => Ok(Box::new(PjrtBackend::new(&loaded.artifacts, &loaded.weights)?)),
        "native" => Ok(Box::new(NativeBackend::new(Arc::clone(&loaded.weights)))),
        other => bail!("unknown backend {other:?} (pjrt|native)"),
    }
}

fn cmd_selfcheck(args: &Args) -> Result<()> {
    let loaded = load(args)?;
    let backends = match args.get("backend") {
        Some(b) => vec![b.to_string()],
        None => vec!["native".to_string(), "pjrt".to_string()],
    };
    let mut all_pass = true;
    for b in backends {
        println!("== selfcheck backend={b} ==");
        let rep = selfcheck::run_all(
            || make_backend(&b, &loaded),
            &loaded.artifacts,
            Arc::clone(&loaded.weights),
        )?;
        print!("{}", rep.render());
        all_pass &= rep.passed;
    }
    if !all_pass {
        bail!("selfcheck failed");
    }
    Ok(())
}

fn engine_from_args(args: &Args, loaded: &Loaded) -> Result<InferenceEngine> {
    let backend = make_backend(&args.str_or("backend", "pjrt"), loaded)?;
    let scheme = Scheme::parse(&args.str_or("quant", "int4"))
        .ok_or_else(|| anyhow::anyhow!("bad --quant (f32|int8|int4)"))?;
    let policy = PolicyKind::parse(&args.str_or("policy", "lru"))
        .ok_or_else(|| anyhow::anyhow!("bad --policy"))?;
    let seed = args.usize_or("seed", 0)? as u64;
    let host_cache_mb = args.usize_or("host-cache-mb", 0)?;
    let store = if host_cache_mb > 0 {
        let tier = HostTierConfig {
            ram_budget_bytes: host_cache_mb << 20,
            policy,
            seed,
            spill_dir: Some(loaded.artifacts.expert_spill_dir()),
        };
        Arc::new(HostExpertStore::build_tiered(&loaded.weights, scheme, &tier)?)
    } else {
        Arc::new(HostExpertStore::build(&loaded.weights, scheme)?)
    };
    let profile = hardware::by_name(&args.str_or("profile", "A100"))
        .ok_or_else(|| anyhow::anyhow!("bad --profile (A100|A6000|L40|RTX3090)"))?;
    let prefetch_source = PrefetchSource::parse(&args.str_or("prefetch-source", "gate"))
        .ok_or_else(|| anyhow::anyhow!("bad --prefetch-source (gate|markov|learned)"))?;
    let disk_read_mbps = args.usize_or("disk-read-mbps", 0)?;
    let cfg = EngineConfig {
        cache_capacity: args.usize_or("capacity", 4)?,
        policy,
        prefetch: PrefetchConfig { enabled: args.bool("spec"), k: args.usize_or("spec-k", 2)? },
        prefetch_source,
        transfer_workers: EngineConfig::transfer_workers_from(args)?,
        profile,
        disk: if disk_read_mbps > 0 {
            hardware::DiskProfile::from_mbps(disk_read_mbps as f64)
        } else {
            hardware::DiskProfile::default()
        },
        seed,
        record_trace: true,
        fetch_retries: args.usize_or("fetch-retries", 2)?,
        demand_deadline_ms: args.usize_or("demand-deadline-ms", 0)? as u64,
    };
    let mc = *backend.config();
    let wanted = policy == PolicyKind::Learned || prefetch_source == PrefetchSource::Learned;
    let predictor =
        learned::load_optional(args.get("predictor-weights"), wanted, mc.n_layers, mc.n_experts)?;
    Ok(InferenceEngine::with_predictor(backend, store, cfg, predictor))
}

fn cmd_generate(args: &Args) -> Result<()> {
    let loaded = load(args)?;
    let mut engine = engine_from_args(args, &loaded)?;
    let tk = Tokenizer::new(engine.config().vocab_size);
    let prompt_text =
        args.str_or("prompt", "Introduce yourself, limit your response in 50 words.");
    let n_gen = args.usize_or("n", 32)?;
    let prompt = tk.encode(&prompt_text);
    let mut sampler = Sampler::new(
        match args.str_or("sampling", "topp").as_str() {
            "greedy" => Sampling::Greedy,
            _ => Sampling::TopP {
                temperature: args.f64_or("temperature", 0.9)? as f32,
                top_p: args.f64_or("top-p", 0.9)? as f32,
            },
        },
        args.usize_or("seed", 0)? as u64,
    );
    let out = engine.generate(&prompt, n_gen, &mut sampler)?;
    println!("prompt tokens: {}  generated: {}", prompt.len(), out.generated.len());
    println!("text: {:?}", tk.decode(&out.generated));
    println!(
        "tokens/s: wall {:.2}  sim[{}] {:.2}",
        out.throughput.tokens_per_s_wall(),
        engine.cfg.profile.name,
        out.throughput.tokens_per_s_sim()
    );
    let cs = out.cache_stats;
    println!(
        "cache[{} cap={}]: hit-rate {:.1}%  hits {} misses {} evictions {}",
        engine.cfg.policy.name(),
        engine.cfg.cache_capacity,
        100.0 * cs.hit_rate(),
        cs.hits,
        cs.misses,
        cs.evictions
    );
    if let Some(trace) = &out.trace {
        let pr = trace.cache_precision_recall();
        println!(
            "cache precision {:.1}%  recall {:.1}%  locality {:.1}%",
            100.0 * pr.precision(),
            100.0 * pr.recall(),
            100.0 * trace.temporal_locality()
        );
        if engine.cfg.prefetch.enabled {
            let spr = out.spec_pr;
            println!(
                "speculative precision {:.1}%  recall {:.1}%",
                100.0 * spr.precision(),
                100.0 * spr.recall()
            );
        }
        if engine.cfg.prefetch_source != PrefetchSource::Gate {
            let ppr = engine.predictor_precision_recall();
            println!(
                "{} predictor precision {:.1}%  recall {:.1}%  skipped records {}",
                engine.cfg.prefetch_source.name(),
                100.0 * ppr.precision(),
                100.0 * ppr.recall(),
                engine.predictor_skipped_records()
            );
        }
        if engine.cfg.prefetch.enabled {
            let by_source: Vec<String> = engine
                .prefetch_hits_by_source()
                .iter()
                .map(|(name, hits)| format!("{name} {hits}"))
                .collect();
            println!("prefetch hits by source: {}", by_source.join("  "));
        }
        if args.bool("show-trace") {
            for l in layer_selection(trace.n_layers) {
                println!("{}", render::layer_grid(trace, l));
            }
        }
    }
    println!(
        "peak resident {:.1} MB   transferred {:.1} MB",
        out.peak_resident_bytes as f64 / (1 << 20) as f64,
        out.transfer_bytes as f64 / (1 << 20) as f64
    );
    Ok(())
}

/// The paper renders layers 1, 8, 16, 24, 32 (1-based); scale to n_layers.
fn layer_selection(n_layers: usize) -> Vec<usize> {
    let picks = [0.0, 0.25, 0.5, 0.75, 1.0];
    let mut v: Vec<usize> = picks
        .iter()
        .map(|p| ((n_layers - 1) as f64 * p).round() as usize)
        .collect();
    v.dedup();
    v
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let tokens = args.usize_or("tokens", 64)?;
    let capacity = args.usize_or("capacity", 4)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let scale = match args.str_or("scale", "mixtral").as_str() {
        "mixtral" => hardware::ModelScale::mixtral_8x7b(),
        _ => hardware::ModelScale::mini_mixtral_int4(),
    };
    let cfg = tracegen::TraceGenConfig {
        n_layers: scale.n_layers,
        n_tokens: tokens,
        seed,
        ..Default::default()
    };
    let trace = tracegen::generate(&cfg);
    println!(
        "synthetic trace: {} tokens × {} layers, locality {:.1}%",
        tokens,
        cfg.n_layers,
        100.0 * trace.temporal_locality()
    );
    let policies = [
        PolicyKind::Lru,
        PolicyKind::Lfu,
        PolicyKind::LfuAged,
        PolicyKind::Fifo,
        PolicyKind::Random,
        PolicyKind::Belady,
    ];
    let results = cachesim::compare(&trace, &policies, capacity, seed);
    let mut t = Table::new(&[
        "policy", "hit-rate", "precision", "recall", "misses/tok", "tok/s A100", "tok/s A6000",
    ]);
    for r in &results {
        let a100 = CostModel::new(hardware::by_name("A100").unwrap(), scale);
        let a6000 = CostModel::new(hardware::by_name("A6000").unwrap(), scale);
        t.row(&[
            r.policy.name().to_string(),
            format!("{:.1}%", 100.0 * r.stats.hit_rate()),
            format!("{:.1}%", 100.0 * r.pr.precision()),
            format!("{:.1}%", 100.0 * r.pr.recall()),
            format!("{:.1}", r.misses_per_token()),
            format!("{:.2}", a100.tokens_per_s(&r.events)),
            format!("{:.2}", a6000.tokens_per_s(&r.events)),
        ]);
    }
    print!("{}", t.render());

    // Learned eviction runs the honest protocol: fit the predictor on the
    // trace head, replay head-blind policies next to it on the tail.
    if tokens >= 16 {
        let mut train = trace.clone();
        let eval = train.split_off(tokens / 2);
        let trained = learned::train_on_trace(&train, &TrainConfig::default())?;
        let mut rows =
            vec![cachesim::replay_learned(&mut eval.clone(), &trained.predictor, capacity)];
        for p in [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::Belady] {
            rows.push(cachesim::replay(&mut eval.clone(), p, capacity, seed));
        }
        println!(
            "\nlearned eviction (predictor fit on first {} tokens, all policies replayed on last {}):",
            train.n_tokens(),
            eval.n_tokens()
        );
        let mut lt = Table::new(&["policy", "hit-rate", "misses/tok", "evictions"]);
        for r in &rows {
            lt.row(&[
                r.policy.name().to_string(),
                format!("{:.1}%", 100.0 * r.stats.hit_rate()),
                format!("{:.1}", r.misses_per_token()),
                format!("{}", r.stats.evictions),
            ]);
        }
        print!("{}", lt.render());
    }
    Ok(())
}

fn cmd_train_predictor(args: &Args) -> Result<()> {
    let mut trace = match args.get("trace") {
        Some(path) => {
            let trace = export::parse_trace_csv(&std::fs::read_to_string(path)?)?;
            println!(
                "trace {}: {} tokens x {} layers ({} experts, top-{})",
                path,
                trace.n_tokens(),
                trace.n_layers,
                trace.n_experts,
                trace.top_k
            );
            trace
        }
        None => {
            let cfg = tracegen::TraceGenConfig {
                n_layers: args.usize_or("layers", 12)?,
                n_tokens: args.usize_or("tokens", 1024)?,
                locality: args.f64_or("locality", 0.3)?,
                seed: args.usize_or("seed", 0)? as u64,
                ..Default::default()
            };
            println!(
                "synthetic trace: {} tokens x {} layers, locality {:.2}, seed {}",
                cfg.n_tokens, cfg.n_layers, cfg.locality, cfg.seed
            );
            tracegen::generate(&cfg)
        }
    };
    let holdout = args.f64_or("holdout", 0.5)?;
    if !(0.0..1.0).contains(&holdout) {
        bail!("--holdout must be in [0, 1)");
    }
    let eval_trace = if holdout > 0.0 {
        let split = ((trace.n_tokens() as f64) * (1.0 - holdout)).round() as usize;
        if split == 0 || split >= trace.n_tokens() {
            bail!("--holdout {holdout} leaves no tokens to train or evaluate on");
        }
        Some(trace.split_off(split))
    } else {
        None
    };
    let cfg = TrainConfig {
        epochs: args.usize_or("epochs", TrainConfig::default().epochs)?,
        lr: args.f64_or("lr", TrainConfig::default().lr as f64)? as f32,
    };
    let outcome = learned::train_on_trace(&trace, &cfg)?;
    println!(
        "trained on {} tokens: {} samples, {} malformed records skipped ({} epochs, lr {})",
        trace.n_tokens(),
        outcome.samples,
        outcome.skipped_records,
        cfg.epochs,
        cfg.lr
    );
    if let Some(eval_trace) = &eval_trace {
        let k = args.usize_or("k", eval_trace.top_k)?;
        let eval = learned::evaluate_on_trace(&outcome.predictor, eval_trace, k)?;
        println!(
            "holdout ({} tokens, top-{k}): precision {:.1}%  recall {:.1}%",
            eval_trace.n_tokens(),
            100.0 * eval.overall.precision(),
            100.0 * eval.overall.recall()
        );
        let mut t = Table::new(&["target layer", "precision", "recall"]);
        for (l, pr) in eval.per_layer.iter().enumerate() {
            t.row(&[
                format!("{l}"),
                format!("{:.1}%", 100.0 * pr.precision()),
                format!("{:.1}%", 100.0 * pr.recall()),
            ]);
        }
        print!("{}", t.render());
    }
    let out = args.str_or("out", learned::DEFAULT_WEIGHTS_PATH);
    outcome.predictor.save(Path::new(&out))?;
    println!("weights -> {out}");
    Ok(())
}
