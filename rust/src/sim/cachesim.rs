//! Trace-driven cache simulator: replay an activation trace under any
//! policy/capacity and measure exactly what the paper measures — hit rate,
//! precision/recall of the cached set, per-token miss counts (which the
//! cost model turns into tokens/s), and evictions.
//!
//! The replay *writes the cache snapshots back into the trace*
//! (`cached_before`), so a replayed trace renders directly as the paper's
//! Figures 1–6 / 8–12.

use crate::cache::{belady::Belady, LayerCache, Policy, PolicyKind};
use crate::metrics::{CacheStats, PrecisionRecall};
use crate::sim::costmodel::TokenEvents;
use crate::trace::Trace;

#[derive(Clone, Debug)]
pub struct ReplayResult {
    pub policy: PolicyKind,
    pub capacity: usize,
    pub stats: CacheStats,
    pub pr: PrecisionRecall,
    /// Per-token events for the cost model.
    pub events: Vec<TokenEvents>,
}

impl ReplayResult {
    pub fn misses_per_token(&self) -> f64 {
        if self.events.is_empty() {
            return 0.0;
        }
        self.events.iter().map(|e| e.misses as f64).sum::<f64>() / self.events.len() as f64
    }
}

/// Replay `trace` under `policy` with per-layer `capacity`, mutating the
/// trace's `cached_before` snapshots to reflect this policy's behavior.
pub fn replay(trace: &mut Trace, policy: PolicyKind, capacity: usize, seed: u64) -> ReplayResult {
    if policy == PolicyKind::Belady {
        return replay_belady(trace, capacity);
    }
    let n_layers = trace.n_layers;
    let mut caches: Vec<LayerCache<()>> = (0..n_layers)
        .map(|l| LayerCache::new(capacity, policy.build(seed.wrapping_add(l as u64), None)))
        .collect();

    let mut pr = PrecisionRecall::default();
    let mut events = Vec::with_capacity(trace.n_tokens());

    for t in 0..trace.n_tokens() {
        let mut ev = TokenEvents::default();
        for (l, cache) in caches.iter_mut().enumerate() {
            let activated = trace.at(t, l).activated.clone();
            ev.activations += activated.len();
            let snapshot = cache.resident();
            pr.record(&snapshot, &activated);
            trace.at_mut(t, l).cached_before = snapshot;

            for &e in &activated {
                if cache.access(e).is_none() {
                    ev.misses += 1;
                    cache.insert(e, ());
                }
            }
        }
        events.push(ev);
    }

    let mut stats = CacheStats::default();
    for c in &caches {
        stats.merge(&c.stats);
    }
    ReplayResult { policy, capacity, stats, pr, events }
}

/// Clairvoyant (Belady MIN) replay — the offline optimum. Kept separate
/// from the online path because the policy needs explicit per-token cursor
/// advancement over the future trace.
fn replay_belady(trace: &mut Trace, capacity: usize) -> ReplayResult {
    let n_layers = trace.n_layers;
    let mut policies: Vec<Belady> = (0..n_layers)
        .map(|l| Belady::new(&trace.layer_activations(l)))
        .collect();
    let mut resident: Vec<Vec<usize>> = vec![Vec::new(); n_layers];
    let mut stats = CacheStats::default();
    let mut pr = PrecisionRecall::default();
    let mut events = Vec::with_capacity(trace.n_tokens());

    for t in 0..trace.n_tokens() {
        let mut ev = TokenEvents::default();
        for l in 0..n_layers {
            policies[l].advance_token(t as u64);
            let activated = trace.at(t, l).activated.clone();
            ev.activations += activated.len();
            pr.record(&resident[l], &activated);
            trace.at_mut(t, l).cached_before = resident[l].clone();

            for &e in &activated {
                if resident[l].contains(&e) {
                    stats.hits += 1;
                } else {
                    stats.misses += 1;
                    ev.misses += 1;
                    if resident[l].len() >= capacity {
                        let victim = policies[l].victim(&resident[l], 0);
                        resident[l].retain(|&r| r != victim);
                        stats.evictions += 1;
                    }
                    resident[l].push(e);
                }
            }
        }
        events.push(ev);
    }
    ReplayResult { policy: PolicyKind::Belady, capacity, stats, pr, events }
}

/// Replay across a set of policies (fresh trace copies), for comparisons.
pub fn compare(
    trace: &Trace,
    policies: &[PolicyKind],
    capacity: usize,
    seed: u64,
) -> Vec<ReplayResult> {
    policies
        .iter()
        .map(|&p| {
            let mut t = trace.clone();
            replay(&mut t, p, capacity, seed)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::tracegen::{self, TraceGenConfig};

    fn mk_trace(tokens: usize, seed: u64) -> Trace {
        tracegen::generate(&TraceGenConfig { n_tokens: tokens, n_layers: 4, seed, ..Default::default() })
    }

    #[test]
    fn replay_fills_snapshots() {
        let mut t = mk_trace(30, 1);
        replay(&mut t, PolicyKind::Lru, 4, 0);
        // snapshots never exceed capacity and grow monotonically per layer
        for tok in 0..30 {
            for l in 0..4 {
                assert!(t.at(tok, l).cached_before.len() <= 4);
                if tok > 0 {
                    assert!(
                        t.at(tok, l).cached_before.len()
                            >= t.at(tok - 1, l).cached_before.len().min(4)
                    );
                }
            }
        }
        // by token 30 at least one layer has filled its cache
        assert!((0..4).any(|l| t.at(29, l).cached_before.len() == 4));
    }

    #[test]
    fn full_cache_never_misses_after_warmup() {
        let mut t = mk_trace(50, 2);
        let r = replay(&mut t, PolicyKind::Lru, 8, 0); // all 8 experts fit
        // at most one miss per (layer, expert) = 4*8 total
        assert!(r.stats.misses <= 32, "misses {}", r.stats.misses);
        assert_eq!(r.stats.evictions, 0);
    }

    #[test]
    fn recall_is_twice_precision_at_cap4_k2() {
        // |cached|=4, |activated|=2 per event => P = tp/4N, R = tp/2N
        let mut t = mk_trace(200, 3);
        let r = replay(&mut t, PolicyKind::Lru, 4, 0);
        let ratio = r.pr.recall() / r.pr.precision();
        assert!((ratio - 2.0).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn belady_beats_online_policies() {
        let t = mk_trace(300, 4);
        let cap = 3;
        let results = compare(
            &t,
            &[PolicyKind::Belady, PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::Random],
            cap,
            7,
        );
        let hr: Vec<f64> = results.iter().map(|r| r.stats.hit_rate()).collect();
        // Belady (index 0) must dominate every online policy
        for i in 1..hr.len() {
            assert!(
                hr[0] >= hr[i] - 1e-9,
                "belady {} < {} ({:?})",
                hr[0],
                hr[i],
                results[i].policy
            );
        }
    }

    #[test]
    fn belady_capacity_respected() {
        let mut t = mk_trace(80, 8);
        replay(&mut t, PolicyKind::Belady, 3, 0);
        for tok in 0..80 {
            for l in 0..4 {
                assert!(t.at(tok, l).cached_before.len() <= 3);
            }
        }
    }

    #[test]
    fn events_sum_matches_stats() {
        let mut t = mk_trace(60, 5);
        let r = replay(&mut t, PolicyKind::Lfu, 2, 0);
        let ev_misses: u64 = r.events.iter().map(|e| e.misses as u64).sum();
        assert_eq!(ev_misses, r.stats.misses);
    }

    #[test]
    fn deterministic_replay() {
        let t = mk_trace(40, 6);
        let a = compare(&t, &[PolicyKind::Random], 3, 42);
        let b = compare(&t, &[PolicyKind::Random], 3, 42);
        assert_eq!(a[0].stats.hits, b[0].stats.hits);
    }

    #[test]
    fn larger_capacity_never_hurts_lru() {
        // LRU is a stack algorithm: hit rate monotone in capacity
        let t = mk_trace(150, 9);
        let mut prev = -1.0;
        for cap in 1..=8 {
            let r = compare(&t, &[PolicyKind::Lru], cap, 0);
            let hr = r[0].stats.hit_rate();
            assert!(hr >= prev - 1e-9, "cap {cap}: {hr} < {prev}");
            prev = hr;
        }
    }
}
