//! Trace-driven cache simulator: replay an activation trace under any
//! policy/capacity and measure exactly what the paper measures — hit rate,
//! precision/recall of the cached set, per-token miss counts (which the
//! cost model turns into tokens/s), and evictions.
//!
//! The replay *writes the cache snapshots back into the trace*
//! (`cached_before`), so a replayed trace renders directly as the paper's
//! Figures 1–6 / 8–12.

use crate::cache::learned::{new_scoreboard, LearnedEviction};
use crate::cache::{belady::Belady, LayerCache, Policy, PolicyKind};
use crate::metrics::{CacheStats, HostTierStats, PrecisionRecall};
use crate::offload::learned::{LearnedContext, LearnedPredictor};
use crate::sim::costmodel::TokenEvents;
use crate::sim::hardware::DiskProfile;
use crate::trace::Trace;

#[derive(Clone, Debug)]
pub struct ReplayResult {
    pub policy: PolicyKind,
    pub capacity: usize,
    pub stats: CacheStats,
    pub pr: PrecisionRecall,
    /// Per-token events for the cost model.
    pub events: Vec<TokenEvents>,
}

impl ReplayResult {
    pub fn misses_per_token(&self) -> f64 {
        if self.events.is_empty() {
            return 0.0;
        }
        self.events.iter().map(|e| e.misses as f64).sum::<f64>() / self.events.len() as f64
    }
}

/// Replay `trace` under `policy` with per-layer `capacity`, mutating the
/// trace's `cached_before` snapshots to reflect this policy's behavior.
pub fn replay(trace: &mut Trace, policy: PolicyKind, capacity: usize, seed: u64) -> ReplayResult {
    if policy == PolicyKind::Belady {
        return replay_belady(trace, capacity);
    }
    let n_layers = trace.n_layers;
    let mut caches: Vec<LayerCache<()>> = (0..n_layers)
        .map(|l| LayerCache::new(capacity, policy.build(seed.wrapping_add(l as u64), None)))
        .collect();

    let mut pr = PrecisionRecall::default();
    let mut events = Vec::with_capacity(trace.n_tokens());

    for t in 0..trace.n_tokens() {
        let mut ev = TokenEvents::default();
        for (l, cache) in caches.iter_mut().enumerate() {
            let activated = trace.at(t, l).activated.clone();
            ev.activations += activated.len();
            let snapshot = cache.resident();
            pr.record(&snapshot, &activated);
            trace.at_mut(t, l).cached_before = snapshot;

            for &e in &activated {
                if cache.access(e).is_none() {
                    ev.misses += 1;
                    cache.insert(e, ());
                }
            }
        }
        events.push(ev);
    }

    let mut stats = CacheStats::default();
    for c in &caches {
        stats.merge(&c.stats);
    }
    ReplayResult { policy, capacity, stats, pr, events }
}

/// Clairvoyant (Belady MIN) replay — the offline optimum. Kept separate
/// from the online path because the policy needs explicit per-token cursor
/// advancement over the future trace.
fn replay_belady(trace: &mut Trace, capacity: usize) -> ReplayResult {
    let n_layers = trace.n_layers;
    let mut policies: Vec<Belady> = (0..n_layers)
        .map(|l| Belady::new(&trace.layer_activations(l)))
        .collect();
    let mut resident: Vec<Vec<usize>> = vec![Vec::new(); n_layers];
    let mut stats = CacheStats::default();
    let mut pr = PrecisionRecall::default();
    let mut events = Vec::with_capacity(trace.n_tokens());

    for t in 0..trace.n_tokens() {
        let mut ev = TokenEvents::default();
        for l in 0..n_layers {
            policies[l].advance_token(t as u64);
            let activated = trace.at(t, l).activated.clone();
            ev.activations += activated.len();
            pr.record(&resident[l], &activated);
            trace.at_mut(t, l).cached_before = resident[l].clone();

            for &e in &activated {
                if resident[l].contains(&e) {
                    stats.hits += 1;
                } else {
                    stats.misses += 1;
                    ev.misses += 1;
                    if resident[l].len() >= capacity {
                        let victim = policies[l].victim(&resident[l], 0);
                        resident[l].retain(|&r| r != victim);
                        stats.evictions += 1;
                    }
                    resident[l].push(e);
                }
            }
        }
        events.push(ev);
    }
    ReplayResult { policy: PolicyKind::Belady, capacity, stats, pr, events }
}

/// Result of a two-tier (GPU cache over budgeted host RAM over disk)
/// trace replay — the offline arm of the RAM-budget sweeps
/// (EXPERIMENTS.md): every GPU miss probes the host tier, and host misses
/// pay a simulated disk read.
#[derive(Clone, Debug)]
pub struct TierReplayResult {
    pub gpu_policy: PolicyKind,
    pub gpu_capacity: usize,
    pub host_policy: PolicyKind,
    /// Host RAM budget in entries (a `--host-cache-mb` budget divided by
    /// the per-expert byte size).
    pub host_capacity: usize,
    pub gpu_stats: CacheStats,
    /// Host-tier counters with the same semantics as the live store's
    /// (`ram_hits + disk_promotions == host_accesses == gpu misses`).
    pub host: HostTierStats,
    /// Simulated seconds spent on disk reads across the whole replay.
    pub disk_s: f64,
}

/// Replay `trace` through a per-layer GPU cache AND a single flattened
/// host RAM cache (key `layer * n_experts + expert`, mirroring the live
/// tiered store) bounded at `host_capacity` entries. Each GPU miss
/// becomes one host access; each host miss charges one
/// `disk.read_time(entry_bytes)` promotion. Online policies only — the
/// host tier has no future trace (and the GPU tier here is the online
/// replay's counterpart, not the Belady oracle).
#[allow(clippy::too_many_arguments)]
pub fn replay_host_tier(
    trace: &Trace,
    gpu_policy: PolicyKind,
    gpu_capacity: usize,
    host_policy: PolicyKind,
    host_capacity: usize,
    seed: u64,
    disk: DiskProfile,
    entry_bytes: usize,
) -> TierReplayResult {
    assert!(
        gpu_policy != PolicyKind::Belady && host_policy != PolicyKind::Belady,
        "replay_host_tier is online-only"
    );
    let n_layers = trace.n_layers;
    let n_experts = trace.n_experts;
    let mut gpu: Vec<LayerCache<()>> = (0..n_layers)
        .map(|l| LayerCache::new(gpu_capacity, gpu_policy.build(seed.wrapping_add(l as u64), None)))
        .collect();
    let mut host: LayerCache<()> = LayerCache::new(
        host_capacity.max(1),
        host_policy.build(seed.wrapping_mul(0x9e37_79b9).wrapping_add(1), None),
    );
    let mut tier = HostTierStats::default();
    let read_s = disk.read_time(entry_bytes);
    for t in 0..trace.n_tokens() {
        for l in 0..n_layers {
            for &e in &trace.at(t, l).activated {
                if gpu[l].access(e).is_some() {
                    continue; // resident on device: host tier untouched
                }
                gpu[l].insert(e, ());
                tier.host_accesses += 1;
                let key = l * n_experts + e;
                if host.access(key).is_some() {
                    tier.ram_hits += 1;
                } else {
                    tier.disk_promotions += 1;
                    tier.disk_read_ns += (read_s * 1e9) as u64;
                    if host.insert(key, ()).is_some() {
                        tier.ram_evictions += 1;
                    }
                }
            }
        }
    }
    // fixed-size reads: the bucketed p99 of the live store degenerates to
    // the single read time here
    tier.disk_read_p99_ns = (read_s * 1e9) as u64;
    let mut gpu_stats = CacheStats::default();
    for c in &gpu {
        gpu_stats.merge(&c.stats);
    }
    TierReplayResult {
        gpu_policy,
        gpu_capacity,
        host_policy,
        host_capacity: host_capacity.max(1),
        gpu_stats,
        host: tier,
        disk_s: tier.disk_promotions as f64 * read_s,
    }
}

/// Replay across a set of policies (fresh trace copies), for comparisons.
pub fn compare(
    trace: &Trace,
    policies: &[PolicyKind],
    capacity: usize,
    seed: u64,
) -> Vec<ReplayResult> {
    policies
        .iter()
        .map(|&p| {
            let mut t = trace.clone();
            replay(&mut t, p, capacity, seed)
        })
        .collect()
}

/// Replay `trace` under the learned eviction policy, mirroring the live
/// engine's predict → publish → observe loop: right after layer `l`'s
/// accesses, the predictor's probabilities for layer `(l+1) % L` are
/// written into the shared scoreboard, so by the time any layer evicts,
/// its row reflects the prediction made one boundary earlier (for layer 0,
/// at the previous token's last layer). The context resets at sequence
/// boundaries, matching training.
///
/// `predictor` dims must match the trace (callers validate loudly; the
/// CLI bails before getting here).
pub fn replay_learned(
    trace: &mut Trace,
    predictor: &LearnedPredictor,
    capacity: usize,
) -> ReplayResult {
    assert_eq!(predictor.n_layers(), trace.n_layers, "predictor/trace layer mismatch");
    assert_eq!(predictor.n_experts(), trace.n_experts, "predictor/trace expert mismatch");
    let n_layers = trace.n_layers;
    let board = new_scoreboard(n_layers, trace.n_experts);
    let mut caches: Vec<LayerCache<()>> = (0..n_layers)
        .map(|l| {
            LayerCache::new(capacity, Box::new(LearnedEviction::new(l, Some(board.clone()))))
        })
        .collect();
    let mut ctx = LearnedContext::new(n_layers, trace.n_experts);
    let mut feat = Vec::new();
    let mut probs = Vec::new();

    let mut pr = PrecisionRecall::default();
    let mut events = Vec::with_capacity(trace.n_tokens());
    for t in 0..trace.n_tokens() {
        if trace.is_sequence_start(t) {
            ctx.reset();
        }
        let mut ev = TokenEvents::default();
        for l in 0..n_layers {
            let activated = trace.at(t, l).activated.clone();
            ev.activations += activated.len();
            let snapshot = caches[l].resident();
            pr.record(&snapshot, &activated);
            trace.at_mut(t, l).cached_before = snapshot;
            for &e in &activated {
                if caches[l].access(e).is_none() {
                    ev.misses += 1;
                    caches[l].insert(e, ());
                }
            }
            // boundary out of layer l: publish the target layer's row,
            // then fold l's activations into the context (same order as
            // training and the live engine)
            let gates = &trace.at(t, l).weights;
            predictor.features_into(&ctx, l, &activated, gates, &mut feat);
            predictor.forward_into(l, &feat, &mut probs);
            board.lock().expect("scoreboard poisoned")[predictor.target_layer(l)]
                .copy_from_slice(&probs);
            ctx.observe(l, &activated);
        }
        events.push(ev);
    }
    let mut stats = CacheStats::default();
    for c in &caches {
        stats.merge(&c.stats);
    }
    ReplayResult { policy: PolicyKind::Learned, capacity, stats, pr, events }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::tracegen::{self, TraceGenConfig};

    fn mk_trace(tokens: usize, seed: u64) -> Trace {
        tracegen::generate(&TraceGenConfig { n_tokens: tokens, n_layers: 4, seed, ..Default::default() })
    }

    #[test]
    fn replay_fills_snapshots() {
        let mut t = mk_trace(30, 1);
        replay(&mut t, PolicyKind::Lru, 4, 0);
        // snapshots never exceed capacity and grow monotonically per layer
        for tok in 0..30 {
            for l in 0..4 {
                assert!(t.at(tok, l).cached_before.len() <= 4);
                if tok > 0 {
                    assert!(
                        t.at(tok, l).cached_before.len()
                            >= t.at(tok - 1, l).cached_before.len().min(4)
                    );
                }
            }
        }
        // by token 30 at least one layer has filled its cache
        assert!((0..4).any(|l| t.at(29, l).cached_before.len() == 4));
    }

    #[test]
    fn full_cache_never_misses_after_warmup() {
        let mut t = mk_trace(50, 2);
        let r = replay(&mut t, PolicyKind::Lru, 8, 0); // all 8 experts fit
        // at most one miss per (layer, expert) = 4*8 total
        assert!(r.stats.misses <= 32, "misses {}", r.stats.misses);
        assert_eq!(r.stats.evictions, 0);
    }

    #[test]
    fn recall_is_twice_precision_at_cap4_k2() {
        // |cached|=4, |activated|=2 per event => P = tp/4N, R = tp/2N
        let mut t = mk_trace(200, 3);
        let r = replay(&mut t, PolicyKind::Lru, 4, 0);
        let ratio = r.pr.recall() / r.pr.precision();
        assert!((ratio - 2.0).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn belady_beats_online_policies() {
        let t = mk_trace(300, 4);
        let cap = 3;
        let results = compare(
            &t,
            &[PolicyKind::Belady, PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::Random],
            cap,
            7,
        );
        let hr: Vec<f64> = results.iter().map(|r| r.stats.hit_rate()).collect();
        // Belady (index 0) must dominate every online policy
        for i in 1..hr.len() {
            assert!(
                hr[0] >= hr[i] - 1e-9,
                "belady {} < {} ({:?})",
                hr[0],
                hr[i],
                results[i].policy
            );
        }
    }

    #[test]
    fn belady_capacity_respected() {
        let mut t = mk_trace(80, 8);
        replay(&mut t, PolicyKind::Belady, 3, 0);
        for tok in 0..80 {
            for l in 0..4 {
                assert!(t.at(tok, l).cached_before.len() <= 3);
            }
        }
    }

    #[test]
    fn events_sum_matches_stats() {
        let mut t = mk_trace(60, 5);
        let r = replay(&mut t, PolicyKind::Lfu, 2, 0);
        let ev_misses: u64 = r.events.iter().map(|e| e.misses as u64).sum();
        assert_eq!(ev_misses, r.stats.misses);
    }

    #[test]
    fn deterministic_replay() {
        let t = mk_trace(40, 6);
        let a = compare(&t, &[PolicyKind::Random], 3, 42);
        let b = compare(&t, &[PolicyKind::Random], 3, 42);
        assert_eq!(a[0].stats.hits, b[0].stats.hits);
    }

    #[test]
    fn host_tier_replay_invariant_and_budget_sweep() {
        let t = mk_trace(200, 11);
        let entry_bytes = 512 << 10;
        let mut prev_hit_rate = -1.0;
        for host_cap in [1usize, 4, 8, 16, 32] {
            let r = replay_host_tier(
                &t,
                PolicyKind::Lru,
                2,
                PolicyKind::Lru,
                host_cap,
                0,
                crate::sim::hardware::DiskProfile::default(),
                entry_bytes,
            );
            // every GPU miss is exactly one host access, split exhaustively
            assert_eq!(r.host.host_accesses, r.gpu_stats.misses);
            assert_eq!(r.host.ram_hits + r.host.disk_promotions, r.host.host_accesses);
            // disk seconds are promotions × the fixed read time
            let read_s =
                crate::sim::hardware::DiskProfile::default().read_time(entry_bytes);
            assert!((r.disk_s - r.host.disk_promotions as f64 * read_s).abs() < 1e-9);
            // LRU host tier over a fixed access stream: hit rate monotone
            // in the RAM budget (stack property)
            let hr = r.host.ram_hit_rate();
            assert!(hr >= prev_hit_rate - 1e-9, "cap {host_cap}: {hr} < {prev_hit_rate}");
            prev_hit_rate = hr;
        }
        // budget covering the whole 4-layer × 8-expert corpus: each entry
        // promoted at most once, never evicted
        let r = replay_host_tier(
            &t,
            PolicyKind::Lru,
            2,
            PolicyKind::Lru,
            32,
            0,
            crate::sim::hardware::DiskProfile::default(),
            entry_bytes,
        );
        assert!(r.host.disk_promotions <= 32);
        assert_eq!(r.host.ram_evictions, 0);
    }

    #[test]
    fn learned_replay_with_zero_weights_matches_lfu() {
        // 0.5-everywhere predictions are the LFU-degenerate state; the
        // whole replay must then be bit-identical to the LFU replay,
        // snapshots included.
        let trace = mk_trace(60, 3);
        let pred = LearnedPredictor::new_zeroed(4, trace.n_experts).unwrap();
        let mut t1 = trace.clone();
        let mut t2 = trace.clone();
        let learned = replay_learned(&mut t1, &pred, 4);
        let lfu = replay(&mut t2, PolicyKind::Lfu, 4, 0);
        assert_eq!(learned.stats.hits, lfu.stats.hits);
        assert_eq!(learned.stats.misses, lfu.stats.misses);
        assert_eq!(learned.stats.evictions, lfu.stats.evictions);
        for tok in 0..60 {
            for l in 0..4 {
                assert_eq!(t1.at(tok, l).cached_before, t2.at(tok, l).cached_before);
            }
        }
    }

    #[test]
    fn learned_replay_with_trained_weights_beats_lru_and_lfu() {
        // the frozen validation protocol in miniature: train on the first
        // half, replay policies on the second half
        let mut full = tracegen::generate(&TraceGenConfig {
            n_tokens: 1024,
            n_layers: 12,
            seed: 0,
            ..Default::default()
        });
        let eval = full.split_off(512);
        let trained = crate::offload::learned::train_on_trace(
            &full,
            &crate::offload::learned::TrainConfig::default(),
        )
        .unwrap();
        let learned = replay_learned(&mut eval.clone(), &trained.predictor, 4);
        let lru = replay(&mut eval.clone(), PolicyKind::Lru, 4, 0);
        let lfu = replay(&mut eval.clone(), PolicyKind::Lfu, 4, 0);
        assert!(
            learned.stats.hit_rate() > lru.stats.hit_rate(),
            "learned {:.4} <= lru {:.4}",
            learned.stats.hit_rate(),
            lru.stats.hit_rate()
        );
        assert!(
            learned.stats.hit_rate() > lfu.stats.hit_rate(),
            "learned {:.4} <= lfu {:.4}",
            learned.stats.hit_rate(),
            lfu.stats.hit_rate()
        );
    }

    #[test]
    fn larger_capacity_never_hurts_lru() {
        // LRU is a stack algorithm: hit rate monotone in capacity
        let t = mk_trace(150, 9);
        let mut prev = -1.0;
        for cap in 1..=8 {
            let r = compare(&t, &[PolicyKind::Lru], cap, 0);
            let hr = r[0].stats.hit_rate();
            assert!(hr >= prev - 1e-9, "cap {cap}: {hr} < {prev}");
            prev = hr;
        }
    }
}
