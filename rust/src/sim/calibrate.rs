//! Calibration: solve effective (bandwidth, compute) per GPU from the
//! paper's own Table 2 measurements.
//!
//! Model: `1/tps = C + M·T` where `C` is per-token compute time, `T` is
//! per-miss transfer time, and `M` is misses/token (from replaying the
//! trace under the policy). Two measurements per GPU — LRU and LFU
//! tokens/s — give two equations in two unknowns:
//!
//!   T = (1/tps_lru − 1/tps_lfu) / (M_lru − M_lfu)
//!   C = 1/tps_lru − M_lru·T
//!
//! This both *reproduces the paper's absolute Table 2 numbers by
//! construction* and exposes an internal-consistency finding: the paper's
//! 84.6 % A6000 speedup from a 1.6-point recall gain implies an effective
//! bandwidth far below PCIe — i.e., hit-rate alone cannot explain the
//! speedup under a linear transfer model (see EXPERIMENTS.md).

use crate::sim::hardware::{HwProfile, ModelScale};

/// Paper Table 2, tokens/s.
pub const PAPER_TABLE2: [(&str, f64, f64); 4] = [
    // (gpu, LRU t/s, LFU t/s)
    ("A100", 3.33, 3.64),
    ("A6000", 2.34, 4.32),
    ("L40", 4.17, 4.65),
    ("RTX3090", 3.07, 3.09),
];

/// Paper Table 2, cache precision/recall (%), shared across GPUs.
pub const PAPER_PR: [(f64, f64); 2] = [(29.1, 58.2), (29.9, 59.8)]; // LRU, LFU

#[derive(Clone, Copy, Debug)]
pub struct Fit {
    pub gpu: &'static str,
    /// Per-token compute seconds.
    pub compute_s: f64,
    /// Per-miss transfer seconds.
    pub transfer_s: f64,
    /// Effective bandwidth implied by `transfer_s` for `expert_bytes`.
    pub implied_bw_bps: f64,
    /// Whether the fit is physically plausible (positive C/T, bandwidth in
    /// a sane PCIe range).
    pub plausible: bool,
}

/// Fit one GPU given the two measurements and the miss rates/token.
pub fn fit(
    gpu: &'static str,
    tps_lru: f64,
    tps_lfu: f64,
    misses_lru: f64,
    misses_lfu: f64,
    scale: &ModelScale,
) -> Fit {
    let dt = 1.0 / tps_lru - 1.0 / tps_lfu;
    let dm = misses_lru - misses_lfu;
    let transfer_s = if dm.abs() < 1e-12 { f64::INFINITY } else { dt / dm };
    let compute_s = 1.0 / tps_lru - misses_lru * transfer_s;
    let implied_bw_bps = scale.expert_bytes as f64 / transfer_s.max(1e-12);
    let plausible = transfer_s > 0.0
        && compute_s > 0.0
        && (1.0e9..64.0e9).contains(&implied_bw_bps);
    Fit { gpu, compute_s, transfer_s, implied_bw_bps, plausible }
}

impl Fit {
    /// Predicted tokens/s for a policy with `misses` per token.
    pub fn predict_tps(&self, misses: f64) -> f64 {
        1.0 / (self.compute_s + misses * self.transfer_s)
    }

    /// Turn the fit into an HwProfile usable by the cost model.
    pub fn to_profile(&self, scale: &ModelScale) -> HwProfile {
        HwProfile {
            name: self.gpu,
            pcie_bps: self.implied_bw_bps,
            transfer_latency_s: 0.0,
            flops: (scale.dense_flops_per_token()
                + scale.n_layers as f64 * scale.top_k as f64 * scale.expert_flops())
                / self.compute_s.max(1e-12),
        }
    }
}

/// Misses/token implied by the paper's recall figures: every activated
/// expert that is not cached is one miss; activations/token = L·k.
pub fn misses_per_token_from_recall(recall: f64, n_layers: usize, top_k: usize) -> f64 {
    (1.0 - recall) * (n_layers * top_k) as f64
}

/// Fit all four GPUs from the paper's published numbers.
pub fn fit_paper_table2(scale: &ModelScale) -> Vec<Fit> {
    let m_lru = misses_per_token_from_recall(PAPER_PR[0].1 / 100.0, scale.n_layers, scale.top_k);
    let m_lfu = misses_per_token_from_recall(PAPER_PR[1].1 / 100.0, scale.n_layers, scale.top_k);
    PAPER_TABLE2
        .iter()
        .map(|&(gpu, lru, lfu)| fit(gpu, lru, lfu, m_lru, m_lfu, scale))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_reproduces_inputs_exactly() {
        let scale = ModelScale::mixtral_8x7b();
        for f in fit_paper_table2(&scale) {
            let m_lru = misses_per_token_from_recall(0.582, 32, 2);
            let m_lfu = misses_per_token_from_recall(0.598, 32, 2);
            let (_, lru, lfu) = *PAPER_TABLE2.iter().find(|(g, _, _)| *g == f.gpu).unwrap();
            assert!((f.predict_tps(m_lru) - lru).abs() < 1e-9, "{}", f.gpu);
            assert!((f.predict_tps(m_lfu) - lfu).abs() < 1e-9, "{}", f.gpu);
        }
    }

    #[test]
    fn misses_from_recall() {
        // recall 0.582 at 32 layers * 2 -> 26.75 misses/token
        let m = misses_per_token_from_recall(0.582, 32, 2);
        assert!((m - 26.752).abs() < 1e-3);
    }

    #[test]
    fn a6000_fit_is_physically_implausible() {
        // The reproduction finding: the paper's A6000 speedup implies an
        // effective bandwidth far below any PCIe generation.
        let scale = ModelScale::mixtral_8x7b();
        let fits = fit_paper_table2(&scale);
        let a6000 = fits.iter().find(|f| f.gpu == "A6000").unwrap();
        assert!(!a6000.plausible, "bw {:.2} GB/s", a6000.implied_bw_bps / 1e9);
        assert!(a6000.implied_bw_bps < 1.0e9);
    }

    #[test]
    fn predict_monotone_in_misses() {
        let scale = ModelScale::mixtral_8x7b();
        let f = fit("X", 3.0, 4.0, 27.0, 26.0, &scale);
        assert!(f.predict_tps(10.0) > f.predict_tps(20.0));
    }
}
