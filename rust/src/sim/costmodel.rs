//! Cost model: trace statistics -> simulated tokens/s and peak memory.
//!
//! The paper's timing structure is
//!
//!   token_time = dense_compute + k·expert_compute + misses·expert_transfer
//!
//! with transfers on the critical path unless hidden by overlap (§6.1).
//! All terms are deterministic functions of a hardware profile, a model
//! scale, and the per-token miss counts from a trace replay — which is why
//! the replay + cost model reproduces Table 1/2's *shape* exactly even
//! though the physical testbed differs (DESIGN.md §3).

use super::hardware::{HwProfile, ModelScale};

#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub profile: HwProfile,
    pub scale: ModelScale,
}

/// Per-token event counts from a replay or a live run.
#[derive(Clone, Copy, Debug, Default)]
pub struct TokenEvents {
    /// Expert activations (= layers × top_k).
    pub activations: usize,
    /// Cache misses that stalled the token (transfer on critical path).
    pub misses: usize,
    /// Transfers issued but fully hidden by overlap/prefetch.
    pub hidden_transfers: usize,
    /// Wasted speculative transfers (wrong guesses) competing for the bus;
    /// they add bandwidth pressure even when issued early (paper §6.1).
    pub wasted_prefetches: usize,
}

impl CostModel {
    pub fn new(profile: HwProfile, scale: ModelScale) -> Self {
        CostModel { profile, scale }
    }

    /// Simulated seconds for one token step.
    pub fn token_time(&self, ev: &TokenEvents) -> f64 {
        let compute = self.profile.compute_time(
            self.scale.dense_flops_per_token()
                + ev.activations as f64 * self.scale.expert_flops(),
        );
        let stalled = ev.misses as f64 * self.profile.transfer_time(self.scale.expert_bytes);
        // hidden transfers still consume bus time; model their interference
        // as half a transfer each beyond what compute can absorb — they are
        // off the critical path but share bandwidth with stalled misses.
        let interference = 0.5
            * ev.wasted_prefetches as f64
            * self.profile.transfer_time(self.scale.expert_bytes);
        compute + stalled + interference
    }

    pub fn tokens_per_s(&self, events: &[TokenEvents]) -> f64 {
        if events.is_empty() {
            return 0.0;
        }
        let total: f64 = events.iter().map(|e| self.token_time(e)).sum();
        events.len() as f64 / total
    }

    /// Peak device memory for a per-layer cache of `capacity` experts
    /// (Table 1's memory column).
    pub fn peak_memory_bytes(&self, capacity: usize) -> usize {
        self.scale.static_bytes
            + self.scale.n_layers * capacity * self.scale.expert_bytes_resident
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::hardware::physical;

    fn cm() -> CostModel {
        CostModel::new(physical()[0], ModelScale::mixtral_8x7b())
    }

    #[test]
    fn more_misses_is_slower() {
        let m = cm();
        let fast = TokenEvents { activations: 64, misses: 10, ..Default::default() };
        let slow = TokenEvents { activations: 64, misses: 40, ..Default::default() };
        assert!(m.token_time(&slow) > m.token_time(&fast));
    }

    #[test]
    fn zero_miss_time_is_compute_bound() {
        let m = cm();
        let ev = TokenEvents { activations: 64, ..Default::default() };
        let t = m.token_time(&ev);
        let compute = m.profile.compute_time(
            m.scale.dense_flops_per_token() + 64.0 * m.scale.expert_flops(),
        );
        assert!((t - compute).abs() < 1e-12);
    }

    #[test]
    fn wasted_prefetch_costs_something_but_less_than_miss() {
        let m = cm();
        let base = TokenEvents { activations: 64, misses: 5, ..Default::default() };
        let wasted =
            TokenEvents { activations: 64, misses: 5, wasted_prefetches: 4, ..Default::default() };
        let missier = TokenEvents { activations: 64, misses: 9, ..Default::default() };
        assert!(m.token_time(&wasted) > m.token_time(&base));
        assert!(m.token_time(&wasted) < m.token_time(&missier));
    }

    #[test]
    fn memory_linear_in_capacity() {
        let m = cm();
        let m4 = m.peak_memory_bytes(4);
        let m3 = m.peak_memory_bytes(3);
        let m2 = m.peak_memory_bytes(2);
        assert_eq!(m4 - m3, m3 - m2);
        // paper: ~2 GB per offload step
        let step_mb = (m4 - m3) as f64 / (1 << 20) as f64;
        assert!((1800.0..2200.0).contains(&step_mb), "{step_mb} MB/offload");
    }

    #[test]
    fn tokens_per_s_inverse_of_mean_time() {
        let m = cm();
        let ev = TokenEvents { activations: 64, misses: 20, ..Default::default() };
        let tps = m.tokens_per_s(&[ev; 10]);
        assert!((tps - 1.0 / m.token_time(&ev)).abs() < 1e-9);
    }
}
