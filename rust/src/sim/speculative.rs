//! Speculative expert-loading simulation (paper §3.2 / §5.4).
//!
//! Two sources of speculative guesses:
//! * **live** — the engine records actual next-layer-gate-on-current-hidden
//!   guesses into the trace (`spec_guess`); this module just scores them.
//! * **synthetic** — for trace-generator workloads there are no hidden
//!   states, so guesses are synthesized with a target accuracy `q`: each
//!   activated expert is guessed correctly with probability `q`, otherwise
//!   replaced by a distinct wrong expert. The paper measures q ≈ 0.846.
//!
//! Also computes the §6.1 bandwidth consequences: every wrong guess means
//! one extra expert transferred (the wrong one) *and* the right one still
//! missing — total traffic strictly increases with any mistake.

use crate::metrics::PrecisionRecall;
use crate::trace::Trace;
use crate::util::rng::Rng;

/// Fill `spec_guess` for layers 1.. with synthetic guesses of accuracy `q`.
pub fn synthesize_guesses(trace: &mut Trace, q: f64, seed: u64) {
    let mut rng = Rng::new(seed);
    let n_experts = trace.n_experts;
    for t in 0..trace.n_tokens() {
        for l in 1..trace.n_layers {
            let activated = trace.at(t, l).activated.clone();
            let mut guess: Vec<usize> = Vec::with_capacity(activated.len());
            for &e in &activated {
                if rng.f64() < q {
                    guess.push(e);
                } else {
                    // wrong guess: any expert not activated and not guessed
                    let mut cand = rng.below(n_experts);
                    while activated.contains(&cand) || guess.contains(&cand) {
                        cand = rng.below(n_experts);
                    }
                    guess.push(cand);
                }
            }
            trace.at_mut(t, l).spec_guess = Some(guess);
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SpecReport {
    pub pr: PrecisionRecall,
    /// Extra experts transferred due to wrong guesses (the §6.1 cost).
    pub extra_transfers: u64,
    /// Transfers fully avoided (correct guesses issued a layer early).
    pub hidden_transfers: u64,
}

/// Score the speculative guesses recorded in a trace.
pub fn score(trace: &Trace) -> SpecReport {
    let pr = trace.spec_precision_recall();
    SpecReport {
        pr,
        extra_transfers: pr.fp,
        hidden_transfers: pr.tp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::tracegen::{self, TraceGenConfig};

    fn mk(tokens: usize) -> Trace {
        tracegen::generate(&TraceGenConfig { n_tokens: tokens, n_layers: 6, seed: 3, ..Default::default() })
    }

    #[test]
    fn perfect_guessing_is_perfect() {
        let mut t = mk(40);
        synthesize_guesses(&mut t, 1.0, 0);
        let rep = score(&t);
        assert_eq!(rep.pr.precision(), 1.0);
        assert_eq!(rep.pr.recall(), 1.0);
        assert_eq!(rep.extra_transfers, 0);
    }

    #[test]
    fn precision_equals_recall_always() {
        // paper §5.4's structural identity: |guess| == |activated| => P == R
        for q in [0.0, 0.3, 0.846, 0.95] {
            let mut t = mk(60);
            synthesize_guesses(&mut t, q, 1);
            let rep = score(&t);
            assert_eq!(rep.pr.fp, rep.pr.fn_, "q={q}");
            assert!((rep.pr.precision() - rep.pr.recall()).abs() < 1e-12, "q={q}");
        }
    }

    #[test]
    fn accuracy_tracks_q() {
        let mut t = mk(400);
        synthesize_guesses(&mut t, 0.846, 2);
        let p = score(&t).pr.precision();
        assert!((p - 0.846).abs() < 0.03, "precision {p}");
    }

    #[test]
    fn layer_zero_never_guessed() {
        let mut t = mk(10);
        synthesize_guesses(&mut t, 0.9, 3);
        for tok in 0..10 {
            assert!(t.at(tok, 0).spec_guess.is_none());
            assert!(t.at(tok, 1).spec_guess.is_some());
        }
    }

    #[test]
    fn guesses_are_distinct_experts() {
        let mut t = mk(50);
        synthesize_guesses(&mut t, 0.5, 4);
        for tok in 0..50 {
            for l in 1..6 {
                let g = t.at(tok, l).spec_guess.as_ref().unwrap();
                assert_eq!(g.len(), 2);
                assert_ne!(g[0], g[1]);
            }
        }
    }
}
