//! Parallel parameter sweeps over the cache simulator (thread-pool
//! backed) — the ablation engine behind the cache explorer and the
//! sensitivity figures.

use crate::cache::PolicyKind;
use crate::sim::cachesim::{self, ReplayResult};
use crate::sim::tracegen::{self, TraceGenConfig};
use crate::util::threadpool::ThreadPool;

#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    pub policy: PolicyKind,
    pub capacity: usize,
    pub locality: f64,
    pub skew_mid: f64,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub point: SweepPoint,
    pub hit_rate: f64,
    pub precision: f64,
    pub recall: f64,
    pub misses_per_token: f64,
}

/// Run every point (trace generation + replay) across the pool.
pub fn run(points: Vec<SweepPoint>, n_tokens: usize, threads: usize) -> Vec<SweepOutcome> {
    let pool = ThreadPool::new(threads.max(1));
    pool.map(points, move |p| {
        let cfg = TraceGenConfig {
            n_tokens,
            locality: p.locality,
            skew_mid: p.skew_mid,
            skew_edge: p.skew_mid * 0.4,
            seed: p.seed,
            ..Default::default()
        };
        let trace = tracegen::generate(&cfg);
        let r: ReplayResult = {
            let mut t = trace;
            cachesim::replay(&mut t, p.policy, p.capacity, p.seed)
        };
        SweepOutcome {
            point: p,
            hit_rate: r.stats.hit_rate(),
            precision: r.pr.precision(),
            recall: r.pr.recall(),
            misses_per_token: r.misses_per_token(),
        }
    })
}

/// Seed-averaged comparison of two policies at one operating point.
pub fn policy_delta(
    a: PolicyKind,
    b: PolicyKind,
    capacity: usize,
    locality: f64,
    skew_mid: f64,
    n_tokens: usize,
    seeds: &[u64],
) -> f64 {
    let mk = |policy| {
        seeds
            .iter()
            .map(|&seed| SweepPoint { policy, capacity, locality, skew_mid, seed })
            .collect::<Vec<_>>()
    };
    let pool_threads = std::thread::available_parallelism().map_or(4, |n| n.get());
    let ra = run(mk(a), n_tokens, pool_threads);
    let rb = run(mk(b), n_tokens, pool_threads);
    let mean = |rs: &[SweepOutcome]| rs.iter().map(|r| r.hit_rate).sum::<f64>() / rs.len() as f64;
    mean(&ra) - mean(&rb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_runs_all_points() {
        let points: Vec<SweepPoint> = (0..12)
            .map(|i| SweepPoint {
                policy: if i % 2 == 0 { PolicyKind::Lru } else { PolicyKind::Lfu },
                capacity: 2 + i % 4,
                locality: 0.2,
                skew_mid: 1.0,
                seed: i as u64,
            })
            .collect();
        let out = run(points.clone(), 40, 4);
        assert_eq!(out.len(), 12);
        for (o, p) in out.iter().zip(&points) {
            assert_eq!(o.point.capacity, p.capacity); // order preserved
            assert!((0.0..=1.0).contains(&o.hit_rate));
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let points: Vec<SweepPoint> = (0..6)
            .map(|i| SweepPoint {
                policy: PolicyKind::Lfu,
                capacity: 3,
                locality: 0.3,
                skew_mid: 1.1,
                seed: i,
            })
            .collect();
        let par = run(points.clone(), 30, 4);
        let ser = run(points, 30, 1);
        for (a, b) in par.iter().zip(&ser) {
            assert_eq!(a.hit_rate, b.hit_rate);
        }
    }

    #[test]
    fn lfu_beats_lru_under_skew_on_average() {
        let d = policy_delta(PolicyKind::Lfu, PolicyKind::Lru, 4, 0.1, 1.6, 80, &[1, 2, 3, 4]);
        assert!(d > 0.0, "delta {d}");
    }
}
