//! Simulation layer: synthetic trace generation, trace-driven cache
//! replay, the hardware cost model, speculative-loading analysis and the
//! Table-2 calibration — everything needed to regenerate the paper's
//! evaluation on hardware we do not have (DESIGN.md §3).

pub mod cachesim;
pub mod calibrate;
pub mod costmodel;
pub mod hardware;
pub mod speculative;
pub mod sweep;
pub mod tracegen;
