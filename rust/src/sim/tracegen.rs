//! Synthetic expert-activation trace generator.
//!
//! Calibrated to the two phenomena the paper measures on Mixtral-8x7B:
//!
//! * **Temporal locality** (§3.1, via Jiang et al. 2024): P(a token reuses
//!   the previous token's expert) ≈ 0.3 vs 0.125 for uniform top-2-of-8.
//! * **Expert imbalance** (§5.2): per-layer activation distributions are
//!   Zipf-skewed, most strongly in the *middle* layers; some experts are
//!   almost never activated.
//!
//! Per layer the generator is a Markov process: each of the previous
//! token's experts is kept with probability `locality`; remaining top-k
//! slots are filled without replacement from a per-layer Zipf stationary
//! distribution whose exponent follows a sine bump over depth.

use crate::trace::Trace;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct TraceGenConfig {
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub n_tokens: usize,
    /// P(an expert activated at t-1 stays activated at t). Paper ≈ 0.3.
    pub locality: f64,
    /// Zipf exponent at the network edges / the mid-network peak.
    pub skew_edge: f64,
    pub skew_mid: f64,
    pub seed: u64,
}

impl Default for TraceGenConfig {
    fn default() -> Self {
        TraceGenConfig {
            n_layers: 32,
            n_experts: 8,
            top_k: 2,
            n_tokens: 64,
            locality: 0.3,
            skew_edge: 0.4,
            skew_mid: 1.1,
            seed: 0,
        }
    }
}

impl TraceGenConfig {
    /// Mixtral-shaped defaults (paper testbed). The Markov keep-probability
    /// is set below the *measured* repeat-probability target because the
    /// skewed stationary refill re-picks hot experts: keep=0.12 lands the
    /// measured temporal locality at the paper's ≈30% (asserted in tests).
    pub fn mixtral(n_tokens: usize, seed: u64) -> Self {
        TraceGenConfig { n_tokens, seed, locality: 0.12, ..Default::default() }
    }
    pub fn mini(n_tokens: usize, seed: u64) -> Self {
        TraceGenConfig { n_layers: 12, n_tokens, seed, ..Default::default() }
    }
}

/// Per-layer Zipf exponent: sine bump peaking mid-network (§5.2).
fn layer_skew(cfg: &TraceGenConfig, layer: usize) -> f64 {
    let depth = layer as f64 / (cfg.n_layers.max(2) - 1) as f64;
    cfg.skew_edge + (cfg.skew_mid - cfg.skew_edge) * (std::f64::consts::PI * depth).sin()
}

pub fn generate(cfg: &TraceGenConfig) -> Trace {
    let mut rng = Rng::new(cfg.seed);
    let mut trace = Trace::new(cfg.n_layers, cfg.n_experts, cfg.top_k);

    // per-layer stationary weights over a per-layer random expert ranking
    let stationary: Vec<Vec<f64>> = (0..cfg.n_layers)
        .map(|l| {
            let zipf = Rng::zipf_weights(cfg.n_experts, layer_skew(cfg, l));
            let perm = rng.permutation(cfg.n_experts);
            let mut w = vec![0.0; cfg.n_experts];
            for (rank, &e) in perm.iter().enumerate() {
                w[e] = zipf[rank];
            }
            w
        })
        .collect();

    let mut prev: Vec<Vec<usize>> = vec![Vec::new(); cfg.n_layers];
    for t in 0..cfg.n_tokens {
        trace.push_token(t as u32);
        for l in 0..cfg.n_layers {
            let mut selected: Vec<usize> = Vec::with_capacity(cfg.top_k);
            // keep previous experts with prob locality
            for &e in &prev[l] {
                if selected.len() < cfg.top_k && rng.f64() < cfg.locality {
                    selected.push(e);
                }
            }
            // fill remaining slots from the stationary distribution
            while selected.len() < cfg.top_k {
                let mut w = stationary[l].clone();
                for &e in &selected {
                    w[e] = 0.0;
                }
                selected.push(rng.categorical(&w));
            }
            selected.sort_unstable();
            // gating weights: random split that sums to 1 (rendering only)
            let split = 0.5 + 0.4 * rng.f64();
            let mut weights = vec![split as f32];
            let rest = (1.0 - split) / (cfg.top_k - 1).max(1) as f64;
            for _ in 1..cfg.top_k {
                weights.push(rest as f32);
            }
            let rec = trace.at_mut(t, l);
            rec.activated = selected.clone();
            rec.weights = weights;
            prev[l] = selected;
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_validity() {
        let cfg = TraceGenConfig { n_tokens: 20, ..Default::default() };
        let t = generate(&cfg);
        assert_eq!(t.n_tokens(), 20);
        for tok in 0..20 {
            for l in 0..cfg.n_layers {
                let a = &t.at(tok, l).activated;
                assert_eq!(a.len(), 2);
                assert_ne!(a[0], a[1]);
                assert!(a.iter().all(|&e| e < 8));
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = TraceGenConfig { n_tokens: 10, seed: 9, ..Default::default() };
        let a = generate(&cfg);
        let b = generate(&cfg);
        for t in 0..10 {
            for l in 0..cfg.n_layers {
                assert_eq!(a.at(t, l).activated, b.at(t, l).activated);
            }
        }
    }

    #[test]
    fn locality_calibration() {
        // with locality 0.3, measured repeat-prob should be well above the
        // uniform 0.125 baseline and in the paper's "sometimes near 30%" zone
        let cfg = TraceGenConfig { n_tokens: 400, locality: 0.3, ..Default::default() };
        let t = generate(&cfg);
        let loc = t.temporal_locality();
        assert!((0.25..0.55).contains(&loc), "locality {loc}");
    }

    #[test]
    fn zero_locality_approaches_stationary_sampling() {
        let cfg = TraceGenConfig { n_tokens: 400, locality: 0.0, skew_edge: 0.0, skew_mid: 0.0, ..Default::default() };
        let t = generate(&cfg);
        // uniform top-2-of-8 -> repeat prob 2/8 = 0.25 per slot
        let loc = t.temporal_locality();
        assert!((0.18..0.32).contains(&loc), "locality {loc}");
    }

    #[test]
    fn mid_layers_more_skewed() {
        let cfg = TraceGenConfig { n_tokens: 600, ..Default::default() };
        let t = generate(&cfg);
        let mid = t.layer_imbalance(cfg.n_layers / 2);
        let edge = t.layer_imbalance(0);
        assert!(mid > edge, "mid {mid} vs edge {edge}");
    }
}

#[cfg(test)]
mod mixtral_calibration_tests {
    use super::*;

    #[test]
    fn mixtral_preset_lands_paper_locality() {
        let t = generate(&TraceGenConfig::mixtral(400, 0));
        let loc = t.temporal_locality();
        // paper (via Jiang et al.): above the 0.125 uniform baseline,
        // "sometimes near 30%"
        assert!((0.22..0.42).contains(&loc), "measured locality {loc}");
    }
}
