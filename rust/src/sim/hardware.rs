//! Hardware profiles for the simulated device (DESIGN.md §3).
//!
//! The paper measures tokens/s on four GPUs (A100, A6000, L40, RTX 3090)
//! whose *host systems* differ in undocumented ways; our substrate is CPU
//! PJRT, so device time is simulated. Each profile carries an effective
//! host->device bandwidth and an effective compute throughput.
//!
//! Two profile sets are provided:
//! * `physical()` — datasheet-plausible numbers (PCIe gen3/gen4 x16
//!   effective bandwidth, sustained TFLOP/s), used for the
//!   conventional-expectation variants of the figures;
//! * `fitted()` — per-GPU (bandwidth, compute) solved from the paper's own
//!   Table 2 via `sim::calibrate` (two measurements LRU/LFU tokens/s, two
//!   unknowns), reproducing the paper's absolute numbers and exposing where
//!   they imply physically surprising effective bandwidths.

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HwProfile {
    pub name: &'static str,
    /// Effective host->device bandwidth, bytes/second.
    pub pcie_bps: f64,
    /// Per-transfer fixed latency, seconds (driver + DMA setup).
    pub transfer_latency_s: f64,
    /// Effective compute throughput, FLOP/s.
    pub flops: f64,
}

impl HwProfile {
    /// Time to move `bytes` host->device.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.transfer_latency_s + bytes as f64 / self.pcie_bps
    }
    /// Time to execute `flops` floating-point ops.
    pub fn compute_time(&self, flops: f64) -> f64 {
        flops / self.flops
    }
}

/// The disk tier under host RAM (DESIGN.md §10): the second, ~100×-worse
/// cliff of the tiered expert store. Same shape as the PCIe model —
/// fixed per-read latency plus bytes over bandwidth — so the cost model
/// composes the two cliffs additively: a RAM-missing demand fetch costs
/// `read_time(bytes) + transfer_time(bytes)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DiskProfile {
    /// Sequential-read bandwidth, bytes/second.
    pub read_bps: f64,
    /// Per-read fixed latency, seconds (queue + seek/flash lookup).
    pub read_latency_s: f64,
}

impl DiskProfile {
    /// Time to read `bytes` from disk into host RAM.
    pub fn read_time(&self, bytes: usize) -> f64 {
        self.read_latency_s + bytes as f64 / self.read_bps
    }

    /// Profile from a `--disk-read-mbps` style flag (latency left at the
    /// NVMe-class default).
    pub fn from_mbps(mbps: f64) -> DiskProfile {
        DiskProfile { read_bps: mbps * 1e6, ..DiskProfile::default() }
    }
}

impl Default for DiskProfile {
    /// Edge/consumer SSD defaults: 500 MB/s, 150 µs per read — ~40× worse
    /// bandwidth and ~7× worse fixed latency than the PCIe profiles above,
    /// putting a sub-MB expert read one-to-two orders of magnitude past
    /// its PCIe hop (the tiered store's second cliff). Faster NVMe is one
    /// `--disk-read-mbps` flag away via [`DiskProfile::from_mbps`].
    fn default() -> DiskProfile {
        DiskProfile { read_bps: 0.5e9, read_latency_s: 150e-6 }
    }
}

/// Datasheet-plausible profiles (effective, not peak).
pub fn physical() -> [HwProfile; 4] {
    [
        HwProfile {
            name: "A100",
            pcie_bps: 20.0e9, // gen4 x16 effective
            transfer_latency_s: 20e-6,
            flops: 120.0e12,
        },
        HwProfile {
            name: "A6000",
            pcie_bps: 18.0e9,
            transfer_latency_s: 20e-6,
            flops: 75.0e12,
        },
        HwProfile {
            name: "L40",
            pcie_bps: 20.0e9,
            transfer_latency_s: 20e-6,
            flops: 90.0e12,
        },
        HwProfile {
            name: "RTX3090",
            pcie_bps: 12.0e9, // gen3-class effective in many hosts
            transfer_latency_s: 25e-6,
            flops: 35.0e12,
        },
    ]
}

pub fn by_name(name: &str) -> Option<HwProfile> {
    physical()
        .into_iter()
        .find(|p| p.name.eq_ignore_ascii_case(name))
}

/// The paper's testbed model (Mixtral-8x7B) dimensions, used by the cost
/// model so simulated tokens/s are on the paper's scale rather than
/// MiniMixtral's.
#[derive(Clone, Copy, Debug)]
pub struct ModelScale {
    pub name: &'static str,
    pub n_layers: usize,
    pub hidden: usize,
    pub ffn: usize,
    pub n_experts: usize,
    pub top_k: usize,
    /// Bytes of ONE expert as stored/transferred (quantized) incl. metadata.
    pub expert_bytes: usize,
    /// Bytes of one expert resident on device after dequant (fp16).
    pub expert_bytes_resident: usize,
    /// Device bytes of everything that is always resident (attention,
    /// norms, embeddings, KV) — the paper's 4-bit shared layers.
    pub static_bytes: usize,
}

impl ModelScale {
    /// Mixtral-8x7B with the paper's quantization (2-bit HQQ experts,
    /// group 16 -> ~62 MB/expert incl. metadata, matching the paper's
    /// "~2000 MB per offload across 32 layers" observation).
    pub fn mixtral_8x7b() -> ModelScale {
        let h = 4096;
        let f = 14336;
        let expert_params = 3 * h * f; // 176M
        ModelScale {
            name: "mixtral-8x7b-2bit",
            n_layers: 32,
            hidden: h,
            ffn: f,
            n_experts: 8,
            top_k: 2,
            // 2 bits/param + (scale+zero fp16 per group of 16) ≈ 0.375 B/param
            expert_bytes: expert_params * 3 / 8,
            expert_bytes_resident: expert_params * 3 / 8,
            static_bytes: 3_000 << 20, // ~3 GB: 4-bit attention + embeddings + KV
        }
    }

    /// Our MiniMixtral artifact with int4 experts.
    pub fn mini_mixtral_int4() -> ModelScale {
        let h = 256;
        let f = 1024;
        let expert_params = 3 * h * f;
        ModelScale {
            name: "mini-mixtral-int4",
            n_layers: 12,
            hidden: h,
            ffn: f,
            n_experts: 8,
            top_k: 2,
            expert_bytes: expert_params / 2 + (expert_params / 16) * 8,
            expert_bytes_resident: expert_params * 4,
            static_bytes: (4 * h * h * 12 + 2 * 1024 * h) * 4,
        }
    }

    /// FLOPs of the dense (non-expert) part of one token step.
    pub fn dense_flops_per_token(&self) -> f64 {
        // qkv + out projections: 4 * 2*H^2 per layer; logits: 2*H*V-ish
        // (attention over the context is small at short sequences; folded
        // into a 1.2 fudge factor)
        1.2 * (self.n_layers as f64) * 8.0 * (self.hidden as f64).powi(2)
    }

    /// FLOPs of one expert application for one token.
    pub fn expert_flops(&self) -> f64 {
        2.0 * 3.0 * self.hidden as f64 * self.ffn as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales_with_bytes() {
        let p = physical()[0];
        let t1 = p.transfer_time(1 << 20);
        let t2 = p.transfer_time(2 << 20);
        assert!(t2 > t1);
        assert!(t1 > p.transfer_latency_s);
    }

    #[test]
    fn mixtral_expert_bytes_match_paper_slope() {
        let m = ModelScale::mixtral_8x7b();
        // paper: ~2000 MB per offload per 32 layers => ~62 MB/expert
        let mb = m.expert_bytes as f64 / (1 << 20) as f64;
        assert!((55.0..70.0).contains(&mb), "{mb} MB");
    }

    #[test]
    fn disk_is_a_worse_cliff_than_pcie() {
        let d = DiskProfile::default();
        let p = physical()[0];
        // per small read (one int4 mini expert ≈ 0.5 MB), disk must cost
        // at least an order of magnitude more than PCIe
        let bytes = 512 << 10;
        assert!(d.read_time(bytes) > 10.0 * p.transfer_time(bytes));
        assert!(d.read_time(2 * bytes) > d.read_time(bytes));
        let slow = DiskProfile::from_mbps(100.0);
        assert_eq!(slow.read_bps, 100.0e6);
        assert!(slow.read_time(bytes) > d.read_time(bytes));
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(by_name("a100").unwrap().name, "A100");
        assert!(by_name("H100").is_none());
    }

    #[test]
    fn flops_positive() {
        for m in [ModelScale::mixtral_8x7b(), ModelScale::mini_mixtral_int4()] {
            assert!(m.dense_flops_per_token() > 0.0);
            assert!(m.expert_flops() > 0.0);
        }
    }
}
