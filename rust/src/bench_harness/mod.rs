//! Benchmark harness (criterion is unavailable offline): warmup + timed
//! iterations with mean/p50/p99 reporting, used by every `benches/*.rs`
//! target (`harness = false` in Cargo.toml).

use crate::util::stats::{Summary, Table};
use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub summary: Summary,
    /// Optional work units per iteration (tokens, lookups, bytes...).
    pub units_per_iter: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn per_second(&self) -> Option<f64> {
        self.units_per_iter
            .map(|(u, _)| u / self.summary.mean().max(1e-12))
    }
}

pub struct Bencher {
    pub results: Vec<BenchResult>,
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { results: Vec::new(), warmup: 2, iters: 10 }
    }
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bencher { results: Vec::new(), warmup, iters }
    }

    /// Time `f` (whose return value is consumed to prevent DCE).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        self.bench_units(name, None, &mut f);
    }

    pub fn bench_units<T>(
        &mut self,
        name: &str,
        units: Option<(f64, &'static str)>,
        f: &mut impl FnMut() -> T,
    ) {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            s.add(t0.elapsed().as_secs_f64());
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: self.iters,
            summary: s,
            units_per_iter: units,
        });
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(&["bench", "iters", "mean", "p50", "p99", "rate"]);
        for r in &self.results {
            let rate = match (r.per_second(), r.units_per_iter) {
                (Some(v), Some((_, unit))) => format!("{v:.1} {unit}/s"),
                _ => "-".to_string(),
            };
            t.row(&[
                r.name.clone(),
                r.iters.to_string(),
                format_secs(r.summary.mean()),
                format_secs(r.summary.p50()),
                format_secs(r.summary.p99()),
                rate,
            ]);
        }
        t.render()
    }
}

pub fn format_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_iters() {
        let mut b = Bencher::new(1, 5);
        b.bench("noop", || 42);
        assert_eq!(b.results.len(), 1);
        assert_eq!(b.results[0].summary.n(), 5);
        assert!(b.render().contains("noop"));
    }

    #[test]
    fn units_give_rate() {
        let mut b = Bencher::new(0, 3);
        b.bench_units("sleepy", Some((100.0, "tok")), &mut || {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        let rate = b.results[0].per_second().unwrap();
        assert!(rate > 1000.0 && rate < 60_000.0, "{rate}");
        assert!(b.render().contains("tok/s"));
    }

    #[test]
    fn format_secs_ranges() {
        assert_eq!(format_secs(2.5), "2.500 s");
        assert!(format_secs(0.002).ends_with("ms"));
        assert!(format_secs(2e-6).ends_with("µs"));
        assert!(format_secs(5e-9).ends_with("ns"));
    }
}
