//! Block-wise affine quantization for the host expert store.
//!
//! The paper stores offloaded experts HQQ-quantized (2-bit experts, group
//! size 16; 4-bit attention, group size 64) to shrink both host memory and
//! the PCIe transfer volume. HQQ itself is proprietary-complex; we build the
//! standard block-wise affine scheme which preserves the two properties the
//! evaluation depends on (DESIGN.md §3): bytes-per-expert ∝ bit-width, and
//! dequantize-on-transfer cost.
//!
//! Layout per block of `block` values: `scale` f32, `zero` f32 (min), then
//! `block` codes of `bits` each (int4 packed two per byte, low nibble first).

/// Storage scheme for one tensor in the host store.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    F32,
    /// 8-bit affine, per-`block` scale/zero.
    Int8 { block: usize },
    /// 4-bit affine, per-`block` scale/zero (the paper's 2-bit analogue —
    /// int4 keeps MiniMixtral's gating numerically meaningful).
    Int4 { block: usize },
}

impl Scheme {
    pub fn bits(&self) -> usize {
        match self {
            Scheme::F32 => 32,
            Scheme::Int8 { .. } => 8,
            Scheme::Int4 { .. } => 4,
        }
    }
    pub fn block(&self) -> usize {
        match self {
            Scheme::F32 => usize::MAX,
            Scheme::Int8 { block } | Scheme::Int4 { block } => *block,
        }
    }
    /// Storage bytes for `n` values (codes + per-block scale/zero).
    pub fn storage_bytes(&self, n: usize) -> usize {
        match self {
            Scheme::F32 => n * 4,
            Scheme::Int8 { block } => {
                let nblocks = n.div_ceil(*block);
                n + nblocks * 8
            }
            Scheme::Int4 { block } => {
                let nblocks = n.div_ceil(*block);
                n.div_ceil(2) + nblocks * 8
            }
        }
    }
    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "f32" | "fp32" => Some(Scheme::F32),
            "int8" => Some(Scheme::Int8 { block: 64 }),
            "int4" => Some(Scheme::Int4 { block: 16 }),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::F32 => "f32",
            Scheme::Int8 { .. } => "int8",
            Scheme::Int4 { .. } => "int4",
        }
    }
}

/// A quantized tensor (or a plain f32 copy for `Scheme::F32`).
#[derive(Clone, Debug)]
pub struct QTensor {
    pub scheme: Scheme,
    pub len: usize,
    codes: Vec<u8>,
    /// (scale, zero) per block; empty for F32.
    params: Vec<(f32, f32)>,
    raw: Vec<f32>, // only for F32
}

impl QTensor {
    pub fn quantize(data: &[f32], scheme: Scheme) -> QTensor {
        match scheme {
            Scheme::F32 => QTensor {
                scheme,
                len: data.len(),
                codes: vec![],
                params: vec![],
                raw: data.to_vec(),
            },
            Scheme::Int8 { block } => Self::quantize_bits(data, scheme, block, 255),
            Scheme::Int4 { block } => Self::quantize_bits(data, scheme, block, 15),
        }
    }

    fn quantize_bits(data: &[f32], scheme: Scheme, block: usize, levels: u32) -> QTensor {
        assert!(block > 0);
        // int4 blocks must be byte-aligned so dequant can slice the packed
        // stream per block
        assert!(levels != 15 || block % 2 == 0, "int4 block must be even");
        let mut params = Vec::with_capacity(data.len().div_ceil(block));
        let mut codes_u8: Vec<u8> = Vec::with_capacity(data.len());
        for chunk in data.chunks(block) {
            let lo = chunk.iter().copied().fold(f32::INFINITY, f32::min);
            let hi = chunk.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let scale = if hi > lo { (hi - lo) / levels as f32 } else { 1.0 };
            params.push((scale, lo));
            for &x in chunk {
                let q = ((x - lo) / scale).round().clamp(0.0, levels as f32) as u8;
                codes_u8.push(q);
            }
        }
        let codes = if levels == 15 {
            // pack two nibbles per byte, low nibble first
            let mut packed = Vec::with_capacity(codes_u8.len().div_ceil(2));
            for pair in codes_u8.chunks(2) {
                let lo = pair[0] & 0xF;
                let hi = if pair.len() > 1 { pair[1] & 0xF } else { 0 };
                packed.push(lo | (hi << 4));
            }
            packed
        } else {
            codes_u8
        };
        QTensor { scheme, len: data.len(), codes, params, raw: vec![] }
    }

    /// Dequantize into `out` (must be `len` long). This is the real CPU work
    /// the transfer engine performs on a cache miss.
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        match self.scheme {
            Scheme::F32 => out.copy_from_slice(&self.raw),
            Scheme::Int8 { block } => {
                // zip over the code slice: no per-element bounds checks,
                // vectorizes (see EXPERIMENTS.md §Perf)
                for (bi, chunk) in out.chunks_mut(block).enumerate() {
                    let (scale, zero) = self.params[bi];
                    let base = bi * block;
                    let codes = &self.codes[base..base + chunk.len()];
                    for (o, &c) in chunk.iter_mut().zip(codes) {
                        *o = c as f32 * scale + zero;
                    }
                }
            }
            Scheme::Int4 { block } => {
                // `block` is even in practice: unpack byte -> 2 outputs with
                // no per-element branch. (Odd tails handled at the end.)
                for (bi, chunk) in out.chunks_mut(block).enumerate() {
                    let (scale, zero) = self.params[bi];
                    let base = bi * block;
                    let bytes = &self.codes[base / 2..(base + chunk.len()).div_ceil(2)];
                    let (pairs, tail) = chunk.split_at_mut(chunk.len() & !1);
                    for (o2, &b) in pairs.chunks_exact_mut(2).zip(bytes) {
                        o2[0] = (b & 0xF) as f32 * scale + zero;
                        o2[1] = (b >> 4) as f32 * scale + zero;
                    }
                    if let Some(t) = tail.first_mut() {
                        *t = (bytes[bytes.len() - 1] & 0xF) as f32 * scale + zero;
                    }
                }
            }
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.len];
        self.dequantize_into(&mut out);
        out
    }

    /// Dequantize into a reusable buffer, resizing it to `len` first. With
    /// a recycled buffer of the right capacity the resize is free, so the
    /// steady-state transfer path performs no allocation.
    pub fn dequantize_resize(&self, out: &mut Vec<f32>) {
        out.resize(self.len, 0.0);
        self.dequantize_into(out);
    }

    /// Actual storage footprint in bytes (codes + params + raw).
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + self.params.len() * 8 + self.raw.len() * 4
    }

    /// Worst-case absolute reconstruction error: scale/2 per block max.
    pub fn max_abs_error_bound(&self) -> f32 {
        self.params.iter().map(|(s, _)| s / 2.0).fold(0.0, f32::max)
    }

    /// Serialize to the flat on-disk layout used by the tiered store's
    /// spill file: `codes ++ params (le f32 pairs) ++ raw (le f32s)`.
    /// Exactly [`QTensor::storage_bytes`] long, and — because f32 bits pass
    /// through untouched — [`QTensor::from_bytes`] reconstructs a tensor
    /// whose dequantization is bit-identical to this one's.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.storage_bytes());
        out.extend_from_slice(&self.codes);
        for &(scale, zero) in &self.params {
            out.extend_from_slice(&scale.to_le_bytes());
            out.extend_from_slice(&zero.to_le_bytes());
        }
        for &x in &self.raw {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    /// Rebuild a tensor from [`QTensor::to_bytes`] output. The section
    /// splits are fully determined by `(scheme, len)`, so no header is
    /// stored. Panics if `bytes` has the wrong length for the pair.
    pub fn from_bytes(scheme: Scheme, len: usize, bytes: &[u8]) -> QTensor {
        let (codes_len, nblocks, raw_len) = match scheme {
            Scheme::F32 => (0, 0, len),
            Scheme::Int8 { block } => (len, len.div_ceil(block), 0),
            Scheme::Int4 { block } => (len.div_ceil(2), len.div_ceil(block), 0),
        };
        assert_eq!(
            bytes.len(),
            codes_len + nblocks * 8 + raw_len * 4,
            "byte length does not match scheme {scheme:?} len {len}"
        );
        let codes = bytes[..codes_len].to_vec();
        let mut params = Vec::with_capacity(nblocks);
        let mut off = codes_len;
        for _ in 0..nblocks {
            let scale = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
            let zero = f32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap());
            params.push((scale, zero));
            off += 8;
        }
        let mut raw = Vec::with_capacity(raw_len);
        for _ in 0..raw_len {
            raw.push(f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
            off += 4;
        }
        QTensor { scheme, len, codes, params, raw }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| (r.normal() * 0.02) as f32).collect()
    }

    #[test]
    fn f32_roundtrip_exact() {
        let d = data(100, 1);
        let q = QTensor::quantize(&d, Scheme::F32);
        assert_eq!(q.dequantize(), d);
        assert_eq!(q.storage_bytes(), 400);
    }

    #[test]
    fn int8_error_within_bound() {
        let d = data(1024, 2);
        let q = QTensor::quantize(&d, Scheme::Int8 { block: 64 });
        let r = q.dequantize();
        let bound = q.max_abs_error_bound();
        for (a, b) in d.iter().zip(&r) {
            assert!((a - b).abs() <= bound * 1.001, "{a} vs {b}, bound {bound}");
        }
    }

    #[test]
    fn int4_error_within_bound() {
        let d = data(1000, 3); // odd-ish length exercises nibble tail
        let q = QTensor::quantize(&d, Scheme::Int4 { block: 16 });
        let r = q.dequantize();
        let bound = q.max_abs_error_bound();
        for (a, b) in d.iter().zip(&r) {
            assert!((a - b).abs() <= bound * 1.001);
        }
    }

    #[test]
    fn int4_odd_length() {
        let d = data(17, 4);
        let q = QTensor::quantize(&d, Scheme::Int4 { block: 16 });
        assert_eq!(q.dequantize().len(), 17);
    }

    #[test]
    fn dequantize_resize_matches_and_reuses_capacity() {
        let d = data(128, 6);
        let q = QTensor::quantize(&d, Scheme::Int8 { block: 16 });
        let mut buf = vec![9.0f32; 7];
        q.dequantize_resize(&mut buf);
        assert_eq!(buf, q.dequantize());
        let cap = buf.capacity();
        q.dequantize_resize(&mut buf);
        assert_eq!(buf.capacity(), cap, "same-size refill must not grow");
    }

    #[test]
    fn constant_block_is_exact() {
        let d = vec![0.5f32; 64];
        for scheme in [Scheme::Int8 { block: 16 }, Scheme::Int4 { block: 16 }] {
            let q = QTensor::quantize(&d, scheme);
            for x in q.dequantize() {
                assert_eq!(x, 0.5);
            }
        }
    }

    #[test]
    fn storage_shrinks_with_bits() {
        let d = data(4096, 5);
        let f32b = QTensor::quantize(&d, Scheme::F32).storage_bytes();
        let i8b = QTensor::quantize(&d, Scheme::Int8 { block: 64 }).storage_bytes();
        let i4b = QTensor::quantize(&d, Scheme::Int4 { block: 16 }).storage_bytes();
        assert!(i8b < f32b / 3, "{i8b} vs {f32b}");
        assert!(i4b < i8b, "{i4b} vs {i8b}");
        // predicted == actual
        assert_eq!(i8b, Scheme::Int8 { block: 64 }.storage_bytes(4096));
        assert_eq!(i4b, Scheme::Int4 { block: 16 }.storage_bytes(4096));
    }

    #[test]
    fn byte_roundtrip_is_bit_identical_per_scheme() {
        // odd length exercises the int4 nibble tail and a ragged last block
        for n in [17usize, 1000, 4096] {
            let d = data(n, 9);
            for scheme in
                [Scheme::F32, Scheme::Int8 { block: 64 }, Scheme::Int4 { block: 16 }]
            {
                let q = QTensor::quantize(&d, scheme);
                let bytes = q.to_bytes();
                assert_eq!(bytes.len(), q.storage_bytes(), "{scheme:?} n={n}");
                let back = QTensor::from_bytes(scheme, n, &bytes);
                // bit-identical reconstruction, not merely close: the tiered
                // store's transparency guarantee rests on this
                let (a, b) = (q.dequantize(), back.dequantize());
                assert!(
                    a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{scheme:?} n={n} roundtrip changed bits"
                );
            }
        }
    }

    #[test]
    fn scheme_parse() {
        assert_eq!(Scheme::parse("int4"), Some(Scheme::Int4 { block: 16 }));
        assert_eq!(Scheme::parse("int8"), Some(Scheme::Int8 { block: 64 }));
        assert_eq!(Scheme::parse("f32"), Some(Scheme::F32));
        assert_eq!(Scheme::parse("bf16"), None);
    }
}
