//! Fixed-size thread pool over `std::sync::mpsc` — the execution substrate
//! for the HTTP server workers and parallel simulation sweeps.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = match rx.lock().unwrap().recv() {
                            Ok(job) => job,
                            Err(_) => break, // sender dropped: shut down
                        };
                        job();
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, tx: Some(tx) }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool shut down").send(Box::new(f)).unwrap();
    }

    /// Run `f` over `items` in parallel, preserving order of results.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("worker panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }
}
