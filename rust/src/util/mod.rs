//! From-scratch substrates the coordinator is built on.
//!
//! The offline build environment provides no crates beyond `xla` and
//! `anyhow`, so the usual ecosystem pieces are implemented here:
//! deterministic PRNG ([`rng`]), JSON ([`json`]), a thread pool
//! ([`threadpool`]), a mini property-testing framework ([`quickcheck`]),
//! summary statistics ([`stats`]) and the simulated clock ([`simclock`]).

pub mod cliargs;
pub mod json;
pub mod quickcheck;
pub mod rng;
pub mod simclock;
pub mod stats;
pub mod threadpool;
