//! Deterministic PRNG: SplitMix64 seeding + Xoshiro256** core.
//!
//! Every stochastic component in the system (sampler, trace generator,
//! property tests, random cache policy) draws from this so that runs are
//! bit-reproducible from a single seed — a requirement the paper's §6.2
//! "Consistency in Generated Outputs" limitation motivates directly.

/// SplitMix64: used to expand a single `u64` seed into the Xoshiro state.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`; unbiased via rejection.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: zero mass");
        let mut r = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Zipf-like weights `1/(rank+1)^alpha`, normalized to sum 1.
    pub fn zipf_weights(n: usize, alpha: f64) -> Vec<f64> {
        let raw: Vec<f64> = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(alpha)).collect();
        let s: f64 = raw.iter().sum();
        raw.into_iter().map(|w| w / s).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            // each bucket should be ~10k; allow wide slack
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(6);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.6..3.4).contains(&ratio), "{counts:?}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(8);
        let p = r.permutation(17);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..17).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_normalized_and_decreasing() {
        let w = Rng::zipf_weights(8, 0.9);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        for i in 1..w.len() {
            assert!(w[i] <= w[i - 1]);
        }
    }
}
