//! Mini property-testing framework (no external crates available offline).
//!
//! Usage:
//! ```ignore
//! use crate::util::quickcheck::{forall, Gen};
//! forall(100, |g: &mut Gen| {
//!     let xs = g.vec_f32(0..=64, -1.0..=1.0);
//!     let cap = g.usize(1..=8);
//!     // ... return Ok(()) or Err(description)
//!     Ok(())
//! });
//! ```
//!
//! On failure the runner retries with progressively smaller size hints
//! (a pragmatic shrink: generators consult `g.size` so re-running with a
//! smaller budget tends to produce smaller counterexamples) and reports
//! the failing seed so the case is exactly reproducible.

use super::rng::Rng;
use std::ops::RangeInclusive;

pub struct Gen {
    rng: Rng,
    /// Size hint in [0,1]; generators scale their output size by it.
    pub size: f64,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Rng::new(seed), size, seed }
    }

    pub fn usize(&mut self, r: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*r.start(), *r.end());
        let span = hi - lo;
        let scaled = ((span as f64 * self.size).ceil() as usize).min(span);
        lo + if scaled == 0 { 0 } else { self.rng.below(scaled + 1) }
    }

    pub fn i64(&mut self, r: RangeInclusive<i64>) -> i64 {
        let (lo, hi) = (*r.start(), *r.end());
        lo + self.rng.below((hi - lo + 1) as usize) as i64
    }

    pub fn f32(&mut self, r: RangeInclusive<f32>) -> f32 {
        let (lo, hi) = (*r.start(), *r.end());
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn f64(&mut self, r: RangeInclusive<f64>) -> f64 {
        let (lo, hi) = (*r.start(), *r.end());
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: RangeInclusive<usize>, vals: RangeInclusive<f32>) -> Vec<f32> {
        let n = self.usize(len);
        (0..n).map(|_| self.f32(vals.clone())).collect()
    }

    pub fn vec_usize(&mut self, len: RangeInclusive<usize>, vals: RangeInclusive<usize>) -> Vec<usize> {
        let n = self.usize(len);
        (0..n).map(|_| self.usize(vals.clone())).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `n` random cases. Panics with the seed + message of the
/// smallest failing case found.
pub fn forall<F>(n: usize, prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    forall_seeded(0xC0FFEE, n, prop)
}

pub fn forall_seeded<F>(base_seed: u64, n: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut meta = Rng::new(base_seed);
    for case in 0..n {
        let seed = meta.next_u64();
        // grow the size budget over the run: small cases first
        let size = ((case + 1) as f64 / n as f64).min(1.0);
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // "shrink": retry same seed with smaller size hints
            let mut best = (size, msg);
            let mut s = size / 2.0;
            while s > 0.01 {
                let mut g = Gen::new(seed, s);
                match prop(&mut g) {
                    Err(m) => {
                        best = (s, m);
                        s /= 2.0;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property failed (case {case}, seed {seed:#x}, size {:.3}): {}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(200, |g| {
            let a = g.i64(-100..=100);
            let b = g.i64(-100..=100);
            if a + b == b + a {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(200, |g| {
            let v = g.vec_f32(0..=32, -1.0..=1.0);
            if v.len() < 30 {
                Ok(())
            } else {
                Err(format!("len {}", v.len()))
            }
        });
    }

    #[test]
    fn sizes_grow() {
        let mut max_len = 0;
        forall(100, |g| {
            max_len = max_len.max(g.vec_f32(0..=64, 0.0..=1.0).len());
            Ok(())
        });
        assert!(max_len > 32, "size budget never grew: {max_len}");
    }

    #[test]
    fn usize_respects_bounds() {
        forall(300, |g| {
            let x = g.usize(3..=9);
            if (3..=9).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }
}
