//! Summary statistics + text tables for benches and metrics reporting.

/// Online summary of a sample (latencies, throughputs, ...).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    xs: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
    }
    pub fn n(&self) -> usize {
        self.xs.len()
    }
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }
    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.xs.len() - 1) as f64)
            .sqrt()
    }
    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }
    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
    /// Percentile by linear interpolation, q in [0,100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = q / 100.0 * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }
    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Right-padded fixed-width text table (figure/bench output).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join(" | ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("-+-"));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.p50(), 2.5);
        assert!((s.std() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        for x in 0..101 {
            s.add(x as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.p99(), 99.0);
    }

    #[test]
    fn empty_summary_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.p50().is_nan());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "val"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "2.5".into()]);
        let r = t.render();
        assert!(r.contains("name   | val"));
        assert!(r.contains("longer | 2.5"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".into()]);
    }
}
