//! Minimal but complete JSON: recursive-descent parser + writer.
//!
//! Used for the AOT `manifest.json` / `testvec.json`, the MOEW weights
//! header, the HTTP API, and metrics/figure export. Supports the full JSON
//! grammar (nested containers, escapes incl. `\uXXXX` with surrogate
//! pairs, scientific notation); numbers are `f64` (adequate: every integer
//! we exchange is ≤ 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Value::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    /// Array indexing; `Value::Null` out of range.
    pub fn idx(&self, i: usize) -> &Value {
        static NULL: Value = Value::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }
    /// Convenience: `[1,2,3]` -> `vec![1.0,2.0,3.0]`.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr().map(|a| a.iter().filter_map(Value::as_f64).collect())
    }
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64().map(|x| x as f32)).collect())
    }
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr().map(|a| a.iter().filter_map(Value::as_usize).collect())
    }

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }
    pub fn arr_usize(xs: &[usize]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::Num(x)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Self {
        Value::Num(x as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), pos: self.pos }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    let len = match c {
                        0x00..=0x7F => 0,
                        0xC0..=0xDF => 1,
                        0xE0..=0xEF => 2,
                        0xF0..=0xF7 => 3,
                        _ => return Err(self.err("bad utf8")),
                    };
                    let start = self.pos - 1;
                    for _ in 0..len {
                        self.bump().ok_or_else(|| self.err("truncated utf8"))?;
                    }
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").idx(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").idx(2).get("b"), &Value::Null);
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = parse("\"héllo😀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo😀"));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "tru", "1 2", "\"\\q\"", "nul"] {
            assert!(parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"s":"a\"b","t":true},"z":null}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(to_string(&Value::Num(5.0)), "5");
        assert_eq!(to_string(&Value::Num(5.25)), "5.25");
    }

    #[test]
    fn missing_key_is_null() {
        let v = parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Value::Null);
        assert_eq!(v.get("nope").get("deep"), &Value::Null);
    }
}
