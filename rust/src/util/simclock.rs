//! Simulated clock for the offloading cost model.
//!
//! The paper measures tokens/s on four data-center GPUs; here the substrate
//! is CPU PJRT (DESIGN.md §3), so wallclock is not comparable. Instead the
//! transfer engine and cost model charge *simulated seconds* to this clock
//! (`bytes / bandwidth` per transfer, `flops / throughput` per stage), with
//! an explicit overlap primitive: time charged in an `overlap` scope only
//! advances the clock by the amount exceeding the concurrently running
//! compute (modeling copy/compute overlap, paper §6.1).

#[derive(Clone, Debug, Default)]
pub struct SimClock {
    now: f64,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }
    /// Current simulated time, seconds.
    pub fn now(&self) -> f64 {
        self.now
    }
    /// Advance unconditionally (serial work on the critical path).
    pub fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative dt");
        self.now += dt.max(0.0);
    }
    /// Charge two activities that run concurrently (e.g. expert transfer
    /// overlapped with attention compute): the clock advances by the max.
    pub fn advance_overlapped(&mut self, a: f64, b: f64) {
        self.advance(a.max(b));
    }
    /// Charge a transfer of which `hidden` seconds were already overlapped
    /// with earlier compute (prefetch issued ahead of time): only the
    /// remainder lands on the critical path.
    pub fn advance_residual(&mut self, cost: f64, hidden: f64) {
        self.advance((cost - hidden).max(0.0));
    }
    pub fn reset(&mut self) {
        self.now = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances() {
        let mut c = SimClock::new();
        c.advance(1.5);
        c.advance(0.5);
        assert_eq!(c.now(), 2.0);
    }

    #[test]
    fn overlap_takes_max() {
        let mut c = SimClock::new();
        c.advance_overlapped(2.0, 3.0);
        assert_eq!(c.now(), 3.0);
    }

    #[test]
    fn residual_clamps_at_zero() {
        let mut c = SimClock::new();
        c.advance_residual(1.0, 5.0); // fully hidden
        assert_eq!(c.now(), 0.0);
        c.advance_residual(5.0, 1.0);
        assert_eq!(c.now(), 4.0);
    }
}
