//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Flags that never take a value (so `--spec foo` keeps `foo` positional).
const BOOL_FLAGS: [&str; 8] =
    ["spec", "overlap", "show-trace", "live", "synthetic", "greedy", "help", "verbose"];

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if !BOOL_FLAGS.contains(&stripped)
                    && i + 1 < argv.len()
                    && !argv[i + 1].starts_with("--")
                {
                    out.flags.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(stripped.to_string(), String::from("true"));
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key}: expected integer, got {v:?}"),
            },
        }
    }
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key}: expected number, got {v:?}"),
            },
        }
    }
    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true" | "1" | "yes")) || (self.has(key) && self.get(key) == Some("true"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_forms() {
        let a = args(&["gen", "--n", "32", "--policy=lfu", "--spec", "extra"]);
        assert_eq!(a.positional, vec!["gen", "extra"]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 32);
        assert_eq!(a.str_or("policy", "lru"), "lfu");
        assert!(a.bool("spec"));
        assert!(!a.bool("overlap"));
    }

    #[test]
    fn defaults() {
        let a = args(&[]);
        assert_eq!(a.usize_or("cap", 4).unwrap(), 4);
        assert_eq!(a.f64_or("x", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn bad_number_errors() {
        let a = args(&["--n", "abc"]);
        assert!(a.usize_or("n", 0).is_err());
    }
}
