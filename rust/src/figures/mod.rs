//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §5 maps each experiment id to these modules).
//!
//! `moe-offload figures --out-dir results` writes, for each experiment,
//! a human-readable `.txt` and a machine-readable `.csv`:
//!
//! * `table1.*`   — MMLU-proxy / tokens/s / peak-memory vs #offloads (LRU)
//! * `table2.*`   — LRU vs LFU tokens/s on 4 GPUs + precision/recall,
//!                  under both fitted and physical profiles
//! * `fig_lru_layer*.txt`, `fig_lfu_layer*.txt` — Figures 1–6 & 8–12
//! * `fig7.*`     — per-layer activation histograms
//! * `fig13_14.*` — speculative-loading traces for two tokens
//! * `calibration.txt` — the Table-2 (bandwidth, compute) fits + the
//!                  internal-consistency finding
//!
//! Trace source: a calibrated synthetic Mixtral-shaped trace by default
//! (`--live` swaps in a live MiniMixtral decode through the PJRT engine;
//! figure *shapes* are the same — see EXPERIMENTS.md).

pub mod ablations;
pub mod table1;
pub mod table2;

use crate::cache::PolicyKind;
use crate::sim::{cachesim, speculative, tracegen};
use crate::trace::{export, render, Trace};
use crate::util::cliargs::Args;
use anyhow::Result;
use std::path::{Path, PathBuf};

pub struct FigCtx {
    pub out_dir: PathBuf,
    /// Mixtral-shaped activation trace (32 layers × 8 experts × top-2).
    pub trace: Trace,
    pub seed: u64,
}

impl FigCtx {
    pub fn synthetic(out_dir: &Path, n_tokens: usize, seed: u64) -> Self {
        let trace = tracegen::generate(&tracegen::TraceGenConfig::mixtral(n_tokens, seed));
        FigCtx { out_dir: out_dir.to_path_buf(), trace, seed }
    }

    pub fn write(&self, name: &str, content: &str) -> Result<()> {
        export::write_file(&self.out_dir.join(name), content)
    }
}

/// The paper's figure layers (1-based 1,8,16,24,32) mapped to 0-based.
pub fn paper_layers(n_layers: usize) -> Vec<usize> {
    [0.0f64, 7.0 / 31.0, 15.0 / 31.0, 23.0 / 31.0, 1.0]
        .iter()
        .map(|p| ((n_layers - 1) as f64 * p).round() as usize)
        .collect()
}

/// Figures 1–6 (LRU) and 8–12 (LFU): trace grids at the paper's layers.
pub fn fig_traces(ctx: &FigCtx, policy: PolicyKind, capacity: usize) -> Result<()> {
    let mut t = ctx.trace.clone();
    let r = cachesim::replay(&mut t, policy, capacity, ctx.seed);
    let tag = policy.name();
    for l in paper_layers(t.n_layers) {
        let grid = render::layer_grid(&t, l);
        ctx.write(&format!("fig_{tag}_layer{:02}.txt", l + 1), &grid)?;
    }
    ctx.write(&format!("fig_{tag}_trace.csv"), &export::trace_csv(&t))?;
    let pr = r.pr;
    ctx.write(
        &format!("fig_{tag}_summary.txt"),
        &format!(
            "policy {tag} capacity {capacity}\nhit-rate {:.3}\nprecision {:.3}\nrecall {:.3}\nmisses/token {:.2}\n",
            r.stats.hit_rate(),
            pr.precision(),
            pr.recall(),
            r.misses_per_token()
        ),
    )?;
    Ok(())
}

/// Figure 7: activation histograms at the paper's 10 layers (window 8,
/// hop 2 over 32 layers -> 1,2,7,8,15,16,23,24,31,32).
pub fn fig7(ctx: &FigCtx) -> Result<()> {
    let idx: Vec<usize> = [1usize, 2, 7, 8, 15, 16, 23, 24, 31, 32]
        .iter()
        .map(|&l| (l - 1).min(ctx.trace.n_layers - 1))
        .collect();
    let mut txt = String::new();
    for &l in &idx {
        txt.push_str(&render::layer_histogram(&ctx.trace, l, 40));
        txt.push('\n');
    }
    ctx.write("fig7.txt", &txt)?;
    ctx.write("fig7.csv", &export::histogram_csv(&ctx.trace))?;
    Ok(())
}

/// Figures 13–14: speculative-loading grids for two tokens, at the paper's
/// measured accuracy (84.6%).
pub fn fig_spec(ctx: &FigCtx, accuracy: f64) -> Result<()> {
    let mut t = ctx.trace.clone();
    speculative::synthesize_guesses(&mut t, accuracy, ctx.seed);
    let rep = speculative::score(&t);
    let pick = [t.n_tokens() / 3, 2 * t.n_tokens() / 3];
    let mut txt = format!(
        "speculative loading: precision {:.1}%  recall {:.1}%  (FP {} == FN {})\n\n",
        100.0 * rep.pr.precision(),
        100.0 * rep.pr.recall(),
        rep.pr.fp,
        rep.pr.fn_
    );
    for (i, &tok) in pick.iter().enumerate() {
        txt.push_str(&format!("--- Figure {} ---\n", 13 + i));
        txt.push_str(&render::spec_grid(&t, tok));
        txt.push('\n');
    }
    ctx.write("fig13_14.txt", &txt)?;
    ctx.write("fig_spec_trace.csv", &export::trace_csv(&t))?;
    Ok(())
}

/// Calibration report (supports Table 2; EXPERIMENTS.md finding).
pub fn calibration_report(ctx: &FigCtx) -> Result<()> {
    use crate::sim::calibrate;
    use crate::sim::hardware::ModelScale;
    let scale = ModelScale::mixtral_8x7b();
    let fits = calibrate::fit_paper_table2(&scale);
    let mut txt = String::from(
        "Table-2 calibration: per-GPU effective (compute, transfer) solved\n\
         from the paper's LRU/LFU tokens/s and the recall-implied miss rates.\n\n",
    );
    for f in &fits {
        txt.push_str(&format!(
            "{:8} compute {:7.1} ms/tok   transfer {:7.2} ms/miss   implied bw {:7.2} GB/s   {}\n",
            f.gpu,
            1e3 * f.compute_s,
            1e3 * f.transfer_s,
            f.implied_bw_bps / 1e9,
            if f.plausible { "plausible" } else { "IMPLAUSIBLE (see EXPERIMENTS.md)" }
        ));
    }
    ctx.write("calibration.txt", &txt)?;
    Ok(())
}

/// `moe-offload figures` entrypoint: regenerate everything.
pub fn cmd_figures(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.str_or("out-dir", "results"));
    std::fs::create_dir_all(&out)?;
    let tokens = args.usize_or("tokens", 64)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let ctx = FigCtx::synthetic(&out, tokens, seed);

    println!("[figures] Table 1 ...");
    table1::run(&ctx)?;
    println!("[figures] Table 2 ...");
    table2::run(&ctx)?;
    println!("[figures] Figures 1-6 (LRU traces) ...");
    fig_traces(&ctx, PolicyKind::Lru, 4)?;
    println!("[figures] Figures 8-12 (LFU traces) ...");
    fig_traces(&ctx, PolicyKind::Lfu, 4)?;
    println!("[figures] Figure 7 (histograms) ...");
    fig7(&ctx)?;
    println!("[figures] Figures 13-14 (speculative) ...");
    fig_spec(&ctx, 0.846)?;
    println!("[figures] calibration ...");
    calibration_report(&ctx)?;
    println!("[figures] ablations (Belady headroom, predictors, crossover) ...");
    ablations::run(&ctx)?;
    println!("[figures] wrote {}", out.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_layers_match_at_32() {
        assert_eq!(paper_layers(32), vec![0, 7, 15, 23, 31]);
    }

    #[test]
    fn paper_layers_scale_down() {
        let v = paper_layers(12);
        assert_eq!(v.first(), Some(&0));
        assert_eq!(v.last(), Some(&11));
        assert!(v.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn full_figure_run_writes_files() {
        let dir = std::env::temp_dir().join(format!("figs-{}", std::process::id()));
        let ctx = FigCtx::synthetic(&dir, 24, 1);
        table1::run(&ctx).unwrap();
        table2::run(&ctx).unwrap();
        fig_traces(&ctx, PolicyKind::Lru, 4).unwrap();
        fig7(&ctx).unwrap();
        fig_spec(&ctx, 0.846).unwrap();
        calibration_report(&ctx).unwrap();
        for f in [
            "table1.txt",
            "table2.txt",
            "fig_lru_layer01.txt",
            "fig7.csv",
            "fig13_14.txt",
            "calibration.txt",
        ] {
            assert!(dir.join(f).is_file(), "{f}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
