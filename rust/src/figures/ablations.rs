//! Ablation studies for the design choices DESIGN.md calls out — beyond
//! the paper's own evaluation but directly motivated by its §6.1:
//!
//! * **Belady headroom** — how far every online policy sits from the
//!   clairvoyant optimum, per capacity (how much a perfect predictor could
//!   still win).
//! * **Prediction sources** — speculative gating (needs live hidden
//!   states, one-layer lead) vs the learned Markov predictor (whole-token
//!   lead, no model access) vs the LFU frequency prior, as guess accuracy.
//! * **Locality sensitivity** — the LRU/LFU crossover the cache explorer
//!   surfaces, written as a figure artifact.
//!
//! Output: `results/ablation_*.csv` + a combined `.txt`.

use super::FigCtx;
use crate::cache::PolicyKind;
use crate::offload::predictor;
use crate::sim::{cachesim, speculative, tracegen};
use crate::util::stats::Table;
use anyhow::Result;

/// Belady headroom per capacity: hit-rate gap to the offline optimum.
pub fn belady_headroom(ctx: &FigCtx) -> Result<String> {
    let mut tab = Table::new(&["capacity", "belady", "lru", "lfu", "lfu-aged", "max gap"]);
    let mut csv = String::from("capacity,belady,lru,lfu,lfu_aged\n");
    for capacity in 1..=7 {
        let rs = cachesim::compare(
            &ctx.trace,
            &[PolicyKind::Belady, PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::LfuAged],
            capacity,
            ctx.seed,
        );
        let hr: Vec<f64> = rs.iter().map(|r| r.stats.hit_rate()).collect();
        let gap = hr[0] - hr[1..].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        tab.row(&[
            capacity.to_string(),
            format!("{:.1}%", 100.0 * hr[0]),
            format!("{:.1}%", 100.0 * hr[1]),
            format!("{:.1}%", 100.0 * hr[2]),
            format!("{:.1}%", 100.0 * hr[3]),
            format!("{:.1}pp", 100.0 * gap),
        ]);
        csv.push_str(&format!(
            "{capacity},{:.4},{:.4},{:.4},{:.4}\n",
            hr[0], hr[1], hr[2], hr[3]
        ));
    }
    ctx.write("ablation_belady.csv", &csv)?;
    Ok(format!("== Belady headroom (offline optimum vs online policies) ==\n{}", tab.render()))
}

/// Guess-accuracy comparison of the three prediction sources.
pub fn prediction_sources(ctx: &FigCtx) -> Result<String> {
    // speculative gating at the paper's measured accuracy
    let mut spec_trace = ctx.trace.clone();
    speculative::synthesize_guesses(&mut spec_trace, 0.846, ctx.seed);
    let spec = speculative::score(&spec_trace).pr;

    // learned Markov predictor over the same trace
    let markov = predictor::evaluate_on_trace(&ctx.trace, ctx.trace.top_k)?.pr;

    // frequency prior: guess the 2 most-activated experts so far per layer
    let mut freq_pr = crate::metrics::PrecisionRecall::default();
    let mut counts = vec![vec![0u64; ctx.trace.n_experts]; ctx.trace.n_layers];
    for t in 0..ctx.trace.n_tokens() {
        for l in 0..ctx.trace.n_layers {
            let activated = &ctx.trace.at(t, l).activated;
            if t > 0 {
                let f32s: Vec<f32> = counts[l].iter().map(|&c| c as f32).collect();
                let guess = crate::model::sampler::top_k(&f32s, ctx.trace.top_k);
                freq_pr.record(&guess, activated);
            }
            for &e in activated {
                counts[l][e] += 1;
            }
        }
    }

    let mut tab = Table::new(&["source", "precision", "recall", "lead time"]);
    let mut csv = String::from("source,precision,recall\n");
    for (name, pr, lead) in [
        ("speculative gating (paper §3.2)", spec, "1 layer"),
        ("markov predictor (§6.1 learned)", markov, "whole token"),
        ("frequency prior (LFU's signal)", freq_pr, "whole token"),
    ] {
        tab.row(&[
            name.to_string(),
            format!("{:.1}%", 100.0 * pr.precision()),
            format!("{:.1}%", 100.0 * pr.recall()),
            lead.to_string(),
        ]);
        csv.push_str(&format!("{name},{:.4},{:.4}\n", pr.precision(), pr.recall()));
    }
    ctx.write("ablation_predictors.csv", &csv)?;
    Ok(format!(
        "== Prediction sources (guess accuracy vs lead time) ==\n{}\n\
         Speculative gating is most accurate but earns only one layer of\n\
         lead; the learned predictor guesses a full token ahead at lower\n\
         accuracy — the §6.1 overlap trade-off in one table.\n",
        tab.render()
    ))
}

/// LRU/LFU crossover vs temporal locality (figure form of the cache
/// explorer's sweep 2).
pub fn locality_crossover(ctx: &FigCtx) -> Result<String> {
    let mut tab = Table::new(&["locality", "lru", "lfu", "winner"]);
    let mut csv = String::from("locality,lru,lfu\n");
    for loc in [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.8] {
        let cfg = tracegen::TraceGenConfig {
            n_tokens: ctx.trace.n_tokens().max(64),
            locality: loc,
            seed: ctx.seed,
            ..Default::default()
        };
        let tr = tracegen::generate(&cfg);
        let rs = cachesim::compare(&tr, &[PolicyKind::Lru, PolicyKind::Lfu], 4, ctx.seed);
        let (lru, lfu) = (rs[0].stats.hit_rate(), rs[1].stats.hit_rate());
        tab.row(&[
            format!("{loc:.1}"),
            format!("{:.1}%", 100.0 * lru),
            format!("{:.1}%", 100.0 * lfu),
            if lfu >= lru { "lfu" } else { "lru" }.to_string(),
        ]);
        csv.push_str(&format!("{loc},{lru:.4},{lfu:.4}\n"));
    }
    ctx.write("ablation_locality.csv", &csv)?;
    Ok(format!(
        "== LRU/LFU crossover vs temporal locality (capacity 4) ==\n{}\n\
         The paper's workload sits left of the crossover (locality ~0.3,\n\
         strong imbalance), which is exactly where LFU wins.\n",
        tab.render()
    ))
}

pub fn run(ctx: &FigCtx) -> Result<()> {
    let mut txt = String::new();
    txt.push_str(&belady_headroom(ctx)?);
    txt.push('\n');
    txt.push_str(&prediction_sources(ctx)?);
    txt.push('\n');
    txt.push_str(&locality_crossover(ctx)?);
    ctx.write("ablations.txt", &txt)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::FigCtx;

    fn ctx() -> (FigCtx, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("abl-{}-{}", std::process::id(), rand_tag()));
        (FigCtx::synthetic(&dir, 48, 5), dir)
    }

    fn rand_tag() -> u64 {
        use std::time::{SystemTime, UNIX_EPOCH};
        SystemTime::now().duration_since(UNIX_EPOCH).unwrap().subsec_nanos() as u64
    }

    #[test]
    fn writes_all_artifacts() {
        let (c, dir) = ctx();
        run(&c).unwrap();
        for f in ["ablations.txt", "ablation_belady.csv", "ablation_predictors.csv", "ablation_locality.csv"] {
            assert!(dir.join(f).is_file(), "{f}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn belady_gap_nonnegative() {
        let (c, dir) = ctx();
        let txt = belady_headroom(&c).unwrap();
        assert!(txt.contains("pp"));
        let csv = std::fs::read_to_string(dir.join("ablation_belady.csv")).unwrap();
        for line in csv.lines().skip(1) {
            let v: Vec<f64> = line.split(',').skip(1).map(|x| x.parse().unwrap()).collect();
            for online in &v[1..] {
                assert!(v[0] >= online - 1e-9, "{line}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spec_gating_most_precise() {
        let (c, dir) = ctx();
        let _ = prediction_sources(&c).unwrap();
        let csv = std::fs::read_to_string(dir.join("ablation_predictors.csv")).unwrap();
        let rows: Vec<f64> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(1).unwrap().parse().unwrap())
            .collect();
        // spec (row 0) beats markov (row 1) and frequency prior (row 2)
        assert!(rows[0] > rows[1], "{rows:?}");
        assert!(rows[0] > rows[2], "{rows:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
