//! Table 1: model performance vs "# offloads per layer" under LRU caching.
//!
//! Paper columns: MMLU (%), tokens/s, peak memory (MB), for offloads
//! ∈ {4, 5, 6} (cache capacity = 8 − offloads) on an A6000.
//!
//! Substitutions (DESIGN.md §3): MMLU -> semantic-transparency statement
//! (caching cannot change outputs; the paper's MMLU drift is sampling
//! noise), tokens/s -> replay misses × A6000 cost model at Mixtral scale,
//! peak memory -> byte-accurate accountant (static + cache × expert).

use super::FigCtx;
use crate::cache::PolicyKind;
use crate::sim::cachesim;
use crate::sim::costmodel::CostModel;
use crate::sim::hardware::{by_name, ModelScale};
use crate::util::stats::Table;
use anyhow::Result;

pub const PAPER_ROWS: [(usize, f64, f64, f64); 3] = [
    // (#offloads, MMLU %, tokens/s, peak MB)
    (4, 63.16, 4.23, 11148.3),
    (5, 61.40, 4.78, 9145.8),
    (6, 59.65, 7.16, 7127.7),
];

pub fn run(ctx: &FigCtx) -> Result<()> {
    let scale = ModelScale::mixtral_8x7b();
    let cm = CostModel::new(by_name("A6000").unwrap(), scale);

    let mut table = Table::new(&[
        "#offloads", "capacity", "hit-rate", "tok/s (sim)", "peak MB (sim)",
        "tok/s (paper)", "peak MB (paper)", "quality",
    ]);
    let mut csv = String::from(
        "offloads,capacity,hit_rate,tokens_per_s_sim,peak_mb_sim,tokens_per_s_paper,peak_mb_paper\n",
    );
    for (offloads, _mmlu, paper_tps, paper_mb) in PAPER_ROWS {
        let capacity = scale.n_experts - offloads;
        let mut t = ctx.trace.clone();
        let r = cachesim::replay(&mut t, PolicyKind::Lru, capacity, ctx.seed);
        let tps = cm.tokens_per_s(&r.events);
        let mb = cm.peak_memory_bytes(capacity) as f64 / (1 << 20) as f64;
        table.row(&[
            offloads.to_string(),
            capacity.to_string(),
            format!("{:.1}%", 100.0 * r.stats.hit_rate()),
            format!("{tps:.2}"),
            format!("{mb:.0}"),
            format!("{paper_tps:.2}"),
            format!("{paper_mb:.0}"),
            "bit-identical outputs".to_string(),
        ]);
        csv.push_str(&format!(
            "{offloads},{capacity},{:.4},{tps:.3},{mb:.1},{paper_tps},{paper_mb}\n",
            r.stats.hit_rate()
        ));
    }
    let mut txt = String::from(
        "Table 1 — LRU caching vs #offloads/layer (A6000 profile, Mixtral-8x7B scale)\n\n",
    );
    txt.push_str(&table.render());
    txt.push_str(
        "\nNotes:\n\
         * peak memory reproduces the paper's ~2 GB/offload linear slope.\n\
         * the paper reports tokens/s INCREASING with more offloads — the\n\
           opposite of a pure cache/bandwidth model (fewer cached experts =>\n\
           more transfers => slower). Our simulated column shows the\n\
           conventional monotone trend; see EXPERIMENTS.md for discussion.\n\
         * MMLU column: expert caching is semantically transparent (asserted\n\
           by property tests), so quality is identical across rows by\n\
           construction; the paper's drift is decode-sampling noise.\n",
    );
    ctx.write("table1.txt", &txt)?;
    ctx.write("table1.csv", &csv)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn table1_memory_slope_linear() {
        let dir = std::env::temp_dir().join(format!("t1-{}", std::process::id()));
        let ctx = FigCtx::synthetic(&dir, 20, 0);
        run(&ctx).unwrap();
        let csv = std::fs::read_to_string(dir.join("table1.csv")).unwrap();
        let rows: Vec<Vec<f64>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(|x| x.parse().unwrap()).collect())
            .collect();
        assert_eq!(rows.len(), 3);
        let mb: Vec<f64> = rows.iter().map(|r| r[4]).collect();
        let d1 = mb[0] - mb[1];
        let d2 = mb[1] - mb[2];
        assert!((d1 - d2).abs() < 1.0, "slope not linear: {mb:?}");
        // ~2 GB per offload like the paper
        assert!((1800.0..2200.0).contains(&d1), "{d1}");
        // hit rate decreases as capacity shrinks
        assert!(rows[0][2] > rows[2][2]);
        std::fs::remove_dir_all(&dir).ok();
        let _ = PathBuf::new();
    }
}
