//! Table 2: LRU vs LFU tokens/s across A100 / A6000 / L40 / RTX3090, plus
//! cache precision/recall.
//!
//! Generated twice:
//! * **fitted profiles** — per-GPU (compute, transfer) solved from the
//!   paper's own numbers (`sim::calibrate`), reproducing Table 2's absolute
//!   values and its LFU-wins-everywhere shape by construction;
//! * **physical profiles** — datasheet-plausible PCIe/TFLOPs, showing what
//!   a linear bandwidth model predicts for the same traces (the honest
//!   counterfactual; the LFU gain tracks the miss-rate gap).

use super::FigCtx;
use crate::cache::PolicyKind;
use crate::sim::cachesim;
use crate::sim::calibrate::{self, PAPER_TABLE2};
use crate::sim::costmodel::CostModel;
use crate::sim::hardware::{physical, ModelScale};
use crate::util::stats::Table;
use anyhow::Result;

pub fn run(ctx: &FigCtx) -> Result<()> {
    let scale = ModelScale::mixtral_8x7b();
    let mut t_lru = ctx.trace.clone();
    let r_lru = cachesim::replay(&mut t_lru, PolicyKind::Lru, 4, ctx.seed);
    let mut t_lfu = ctx.trace.clone();
    let r_lfu = cachesim::replay(&mut t_lfu, PolicyKind::Lfu, 4, ctx.seed);

    let mut txt = String::from("Table 2 — LRU vs LFU across GPUs (cache=4, Mixtral-8x7B scale)\n\n");

    // --- replayed trace statistics (paper's P/R columns) ---
    txt.push_str(&format!(
        "replayed trace: LRU precision {:.1}% recall {:.1}%   LFU precision {:.1}% recall {:.1}%\n",
        100.0 * r_lru.pr.precision(),
        100.0 * r_lru.pr.recall(),
        100.0 * r_lfu.pr.precision(),
        100.0 * r_lfu.pr.recall(),
    ));
    txt.push_str("paper:          LRU 29.1% / 58.2%            LFU 29.9% / 59.8%\n\n");

    // --- fitted profiles ---
    let fits = calibrate::fit_paper_table2(&scale);
    let m_lru = calibrate::misses_per_token_from_recall(0.582, scale.n_layers, scale.top_k);
    let m_lfu = calibrate::misses_per_token_from_recall(0.598, scale.n_layers, scale.top_k);
    let mut tab = Table::new(&["GPU", "LRU t/s", "LFU t/s", "speedup", "paper LRU", "paper LFU"]);
    let mut csv = String::from("profile_set,gpu,lru_tps,lfu_tps,speedup\n");
    for f in &fits {
        let (gpu, p_lru, p_lfu) =
            *PAPER_TABLE2.iter().find(|(g, _, _)| *g == f.gpu).unwrap();
        let lru = f.predict_tps(m_lru);
        let lfu = f.predict_tps(m_lfu);
        tab.row(&[
            gpu.to_string(),
            format!("{lru:.2}"),
            format!("{lfu:.2}"),
            format!("{:.1}%", 100.0 * (lfu / lru - 1.0)),
            format!("{p_lru:.2}"),
            format!("{p_lfu:.2}"),
        ]);
        csv.push_str(&format!("fitted,{gpu},{lru:.3},{lfu:.3},{:.4}\n", lfu / lru - 1.0));
    }
    txt.push_str("fitted profiles (calibrated to the paper's measurements):\n");
    txt.push_str(&tab.render());

    // --- physical profiles over OUR replayed traces ---
    let mut tab2 = Table::new(&["GPU", "LRU t/s", "LFU t/s", "speedup"]);
    for p in physical() {
        let cm = CostModel::new(p, scale);
        let lru = cm.tokens_per_s(&r_lru.events);
        let lfu = cm.tokens_per_s(&r_lfu.events);
        tab2.row(&[
            p.name.to_string(),
            format!("{lru:.2}"),
            format!("{lfu:.2}"),
            format!("{:.1}%", 100.0 * (lfu / lru - 1.0)),
        ]);
        csv.push_str(&format!(
            "physical,{},{lru:.3},{lfu:.3},{:.4}\n",
            p.name,
            lfu / lru - 1.0
        ));
    }
    txt.push_str("\nphysical profiles over the replayed synthetic trace:\n");
    txt.push_str(&tab2.render());
    txt.push_str(
        "\nShape checks: LFU ≥ LRU on every profile; the largest relative\n\
         gain lands on the most bandwidth-starved profile. The paper's 84.6%\n\
         A6000 speedup requires the fitted (physically implausible) transfer\n\
         time — see calibration.txt and EXPERIMENTS.md.\n",
    );

    ctx.write("table2.txt", &txt)?;
    ctx.write("table2.csv", &csv)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fitted_rows_match_paper() {
        let dir = std::env::temp_dir().join(format!("t2-{}", std::process::id()));
        let ctx = FigCtx::synthetic(&dir, 24, 0);
        run(&ctx).unwrap();
        let csv = std::fs::read_to_string(dir.join("table2.csv")).unwrap();
        // fitted A6000 speedup ≈ paper's 84.6%
        let row = csv
            .lines()
            .find(|l| l.starts_with("fitted,A6000"))
            .expect("a6000 row");
        let speedup: f64 = row.split(',').nth(4).unwrap().parse().unwrap();
        assert!((speedup - 0.846).abs() < 0.01, "{speedup}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lfu_never_slower_under_physical_model() {
        // long enough trace for the frequency signal to dominate noise
        let dir = std::env::temp_dir().join(format!("t2b-{}", std::process::id()));
        let ctx = FigCtx::synthetic(&dir, 160, 3);
        run(&ctx).unwrap();
        let csv = std::fs::read_to_string(dir.join("table2.csv")).unwrap();
        for l in csv.lines().filter(|l| l.starts_with("physical,")) {
            let f: Vec<&str> = l.split(',').collect();
            let (lru, lfu): (f64, f64) = (f[2].parse().unwrap(), f[3].parse().unwrap());
            assert!(lfu >= lru * 0.99, "{l}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
