//! FIFO — control baseline: evict in insertion order, ignoring use.

use super::{Expert, Policy};
use std::collections::HashMap;

#[derive(Default)]
pub struct Fifo {
    inserted_at: HashMap<Expert, u64>,
}

impl Fifo {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Policy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }
    fn on_hit(&mut self, _e: Expert, _tick: u64) {}
    fn on_insert(&mut self, e: Expert, tick: u64) {
        self.inserted_at.insert(e, tick);
    }
    fn victim(&mut self, resident: &[Expert], _tick: u64) -> Expert {
        *resident
            .iter()
            .min_by_key(|e| (self.inserted_at.get(e).copied().unwrap_or(0), **e))
            .expect("victim() on empty resident set")
    }
    fn on_evict(&mut self, e: Expert) {
        self.inserted_at.remove(&e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_oldest_insert_despite_hits() {
        let mut p = Fifo::new();
        p.on_insert(0, 1);
        p.on_insert(1, 2);
        p.on_hit(0, 3); // hits don't refresh FIFO order
        assert_eq!(p.victim(&[0, 1], 4), 0);
    }
}
