//! Random eviction — the "no information" control baseline. Seeded, so
//! replays are reproducible.

use super::{Expert, Policy};
use crate::util::rng::Rng;

pub struct RandomPolicy {
    rng: Rng,
}

impl RandomPolicy {
    pub fn new(seed: u64) -> Self {
        RandomPolicy { rng: Rng::new(seed) }
    }
}

impl Policy for RandomPolicy {
    fn name(&self) -> &'static str {
        "random"
    }
    fn on_hit(&mut self, _e: Expert, _tick: u64) {}
    fn on_insert(&mut self, _e: Expert, _tick: u64) {}
    fn victim(&mut self, resident: &[Expert], _tick: u64) -> Expert {
        resident[self.rng.below(resident.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn victim_is_resident_and_seeded() {
        let run = |seed| {
            let mut p = RandomPolicy::new(seed);
            (0..50).map(|t| p.victim(&[2, 5, 7], t)).collect::<Vec<_>>()
        };
        let a = run(1);
        assert!(a.iter().all(|e| [2, 5, 7].contains(e)));
        assert_eq!(a, run(1));
        assert_ne!(a, run(2));
    }
}
