//! Learned eviction — an online approximation of Belady driven by the
//! offline-trained predictor ([`crate::offload::learned`]).
//!
//! Belady evicts the resident with the farthest next use. We estimate that
//! distance for expert `e` from two signals:
//!
//! * `p1` — the predictor's probability that `e` activates at this layer's
//!   *imminent* visit, published by the engine (or sim replay) into a
//!   shared per-layer [`Scoreboard`] right before the layer runs;
//! * `rate` — `e`'s long-run activation rate at this layer, measured from
//!   the policy's own access counts (exactly LFU's frequency signal).
//!
//! Expected next-use distance ≈ `(1 − p1) / max(rate, MIN_RATE)`: miss the
//! imminent visit with probability `1 − p1`, then wait a geometric
//! `1/rate` visits. The victim is the resident with the largest distance;
//! exact ties fall through to LFU's `(freq, last_access, index)` key.
//!
//! **Exact LFU degradation** (asserted by tests): with no scoreboard — or
//! one still holding the 0.5 "no information" prior that zero predictor
//! weights produce — `p1` is constant across residents, so the distance
//! ordering reduces to the frequency ordering and every tie falls through
//! to LFU's own tiebreak. The policy then picks bit-for-bit the same
//! victims as [`super::lfu::Lfu`].

use super::{Expert, Policy};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// `board[layer][expert]` = predicted probability that the expert
/// activates at that layer's next visit. Shared between the engine (or
/// replay loop), which writes a layer's row at each layer boundary, and
/// the per-layer [`LearnedEviction`] policies, which read it at victim
/// time. A plain mutex: rows are tiny and evictions infrequent.
pub type Scoreboard = Arc<Mutex<Vec<Vec<f32>>>>;

/// Fresh scoreboard holding the 0.5 no-information prior everywhere (the
/// LFU-degenerate state).
pub fn new_scoreboard(n_layers: usize, n_experts: usize) -> Scoreboard {
    Arc::new(Mutex::new(vec![vec![0.5; n_experts]; n_layers]))
}

/// Floor on the measured activation rate, so never-seen experts get a
/// large-but-finite distance instead of a division blowup.
const MIN_RATE: f64 = 1e-3;

pub struct LearnedEviction {
    layer: usize,
    board: Option<Scoreboard>,
    /// Cumulative access counts, surviving eviction — identical
    /// bookkeeping to [`super::lfu::Lfu`] by construction.
    freq: HashMap<Expert, u64>,
    last_access: HashMap<Expert, u64>,
    /// Total accesses seen by this layer's policy (the rate denominator,
    /// shared by all candidates so it never changes their ordering).
    events: u64,
}

impl LearnedEviction {
    /// `board: None` is the weights-absent fallback: pure LFU behavior.
    pub fn new(layer: usize, board: Option<Scoreboard>) -> Self {
        LearnedEviction {
            layer,
            board,
            freq: HashMap::new(),
            last_access: HashMap::new(),
            events: 0,
        }
    }
}

impl Policy for LearnedEviction {
    fn name(&self) -> &'static str {
        "learned"
    }
    fn on_hit(&mut self, e: Expert, tick: u64) {
        *self.freq.entry(e).or_insert(0) += 1;
        self.last_access.insert(e, tick);
        self.events += 1;
    }
    fn on_insert(&mut self, e: Expert, tick: u64) {
        *self.freq.entry(e).or_insert(0) += 1;
        self.last_access.insert(e, tick);
        self.events += 1;
    }
    fn victim(&mut self, resident: &[Expert], _tick: u64) -> Expert {
        // Snapshot this layer's probability row so the lock isn't held
        // while ranking.
        let probs: Option<Vec<f32>> = self
            .board
            .as_ref()
            .map(|b| b.lock().expect("scoreboard poisoned")[self.layer].clone());
        let visits = self.events.max(1) as f64;
        let distance = |e: Expert| -> f64 {
            let p1 = probs
                .as_ref()
                .and_then(|p| p.get(e))
                .copied()
                .unwrap_or(0.5) as f64;
            let rate = self.freq.get(&e).copied().unwrap_or(0) as f64 / visits;
            (1.0 - p1).max(0.0) / rate.max(MIN_RATE)
        };
        let lfu_key =
            |e: Expert| (self.freq.get(&e).copied().unwrap_or(0), self.last_access.get(&e).copied().unwrap_or(0), e);
        let mut best = resident[0];
        let mut best_d = distance(best);
        for &e in &resident[1..] {
            let d = distance(e);
            // farthest predicted reuse wins; exact ties fall to LFU's key
            if d > best_d || (d == best_d && lfu_key(e) < lfu_key(best)) {
                best = e;
                best_d = d;
            }
        }
        best
    }
    // NOTE: no on_evict cleanup — like LFU, frequency is global history.
}

#[cfg(test)]
mod tests {
    use super::super::lfu::Lfu;
    use super::*;
    use crate::util::rng::Rng;

    /// Drive a policy through a pseudo-random access/evict schedule and
    /// record every victim it picks.
    fn victim_schedule(p: &mut dyn Policy, seed: u64) -> Vec<Expert> {
        let mut rng = Rng::new(seed);
        let mut victims = Vec::new();
        for tick in 0..400u64 {
            let e = (rng.f64() * 8.0) as usize;
            match (rng.f64() * 3.0) as usize {
                0 => p.on_hit(e, tick),
                1 => p.on_insert(e, tick),
                _ => victims.push(p.victim(&[0, 2, 4, 6], tick)),
            }
        }
        victims
    }

    #[test]
    fn no_scoreboard_degrades_exactly_to_lfu() {
        for seed in 0..5 {
            let mut lfu = Lfu::new();
            let mut learned = LearnedEviction::new(0, None);
            assert_eq!(
                victim_schedule(&mut learned, seed),
                victim_schedule(&mut lfu, seed),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn uninformative_scoreboard_degrades_exactly_to_lfu() {
        // the 0.5-everywhere prior is what zero predictor weights produce
        for seed in 0..5 {
            let mut lfu = Lfu::new();
            let mut learned = LearnedEviction::new(1, Some(new_scoreboard(2, 8)));
            assert_eq!(
                victim_schedule(&mut learned, seed),
                victim_schedule(&mut lfu, seed),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn prediction_breaks_frequency_ties() {
        let board = new_scoreboard(1, 4);
        let mut p = LearnedEviction::new(0, Some(board.clone()));
        p.on_insert(0, 1);
        p.on_insert(1, 2); // equal frequency
        board.lock().unwrap()[0] = vec![0.9, 0.1, 0.5, 0.5];
        // expert 1 is predicted dead -> larger reuse distance -> victim,
        // even though LFU's recency tiebreak would have evicted 0
        assert_eq!(p.victim(&[0, 1], 3), 1);
    }

    #[test]
    fn prediction_can_overrule_frequency() {
        // Belady-style call LFU cannot make: evict the historically hot
        // expert when the predictor says its run is over.
        let board = new_scoreboard(1, 4);
        let mut p = LearnedEviction::new(0, Some(board.clone()));
        for t in 0..10 {
            p.on_hit(0, t);
        }
        p.on_insert(1, 11);
        board.lock().unwrap()[0] = vec![0.0, 1.0, 0.5, 0.5];
        // dist(0) = 1.0/(10/11) ≈ 1.1, dist(1) = 0.0/... = 0
        assert_eq!(p.victim(&[0, 1], 12), 0);
    }

    #[test]
    fn out_of_range_expert_gets_prior() {
        // scoreboard row shorter than the expert id: falls back to 0.5
        let board = new_scoreboard(1, 2);
        let mut p = LearnedEviction::new(0, Some(board));
        p.on_insert(5, 1);
        p.on_insert(6, 2);
        assert_eq!(p.victim(&[5, 6], 3), 5); // LFU recency tiebreak
    }
}
