//! Expert caching — the paper's central object of study.
//!
//! The GPU keeps a fixed-size per-layer cache of expert weights (paper:
//! k of 8 experts per layer; "# offloads per layer" = 8 − k). On every MoE
//! layer the activated experts are looked up; misses trigger a transfer
//! from host memory and an eviction chosen by the policy:
//!
//! * [`lru`]  — baseline (Eliseev & Mazur 2023).
//! * [`lfu`]  — the paper's proposal (§4.2): evict the least *frequently*
//!   used; frequency is cumulative over the whole decode, which is what
//!   makes popular experts effectively unevictable (§5.3 observation).
//! * [`lfu_aged`] — the paper's §6.1 future-work hybrid ("popularity +
//!   unused count"): frequency decayed by time since last use.
//! * [`fifo`], [`random`] — control baselines.
//! * [`belady`] — clairvoyant optimal for trace replay (upper bound).
//! * [`learned`] — predictor-driven reuse-distance eviction (§6.1
//!   learning-based direction); degrades exactly to LFU without weights.
//!
//! The cache is **semantically transparent**: it stores weights, never
//! activations, so policy/size can never change model outputs — an
//! invariant the property tests assert.

pub mod belady;
pub mod fifo;
pub mod learned;
pub mod lfu;
pub mod lfu_aged;
pub mod lru;
pub mod random;
pub mod ttl;

use crate::metrics::CacheStats;

/// Expert index within one layer.
pub type Expert = usize;

/// Per-layer eviction policy. `tick` is a monotone access counter supplied
/// by the cache (one per lookup), giving policies a deterministic notion of
/// time that is identical between the live engine and the trace simulator.
pub trait Policy: Send {
    fn name(&self) -> &'static str;
    /// Expert was found resident (a hit).
    fn on_hit(&mut self, e: Expert, tick: u64);
    /// Expert was inserted after a miss.
    fn on_insert(&mut self, e: Expert, tick: u64);
    /// Pick a victim among `resident` (non-empty). Must return one of them.
    fn victim(&mut self, resident: &[Expert], tick: u64) -> Expert;
    /// Expert was evicted (bookkeeping hook).
    fn on_evict(&mut self, _e: Expert) {}
}

/// Policy constructors by name, shared by the CLI, simulator and benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    Lru,
    Lfu,
    LfuAged,
    Fifo,
    Random,
    Belady,
    Learned,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "lru" => Some(PolicyKind::Lru),
            "lfu" => Some(PolicyKind::Lfu),
            "lfu-aged" | "lfu_aged" | "hybrid" => Some(PolicyKind::LfuAged),
            "fifo" => Some(PolicyKind::Fifo),
            "random" => Some(PolicyKind::Random),
            "belady" | "oracle" => Some(PolicyKind::Belady),
            "learned" => Some(PolicyKind::Learned),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Lru => "lru",
            PolicyKind::Lfu => "lfu",
            PolicyKind::LfuAged => "lfu-aged",
            PolicyKind::Fifo => "fifo",
            PolicyKind::Random => "random",
            PolicyKind::Belady => "belady",
            PolicyKind::Learned => "learned",
        }
    }
    /// Instantiate for one layer. `seed` feeds the random policy; `future`
    /// (the layer's full activation sequence) is required for Belady.
    pub fn build(&self, seed: u64, future: Option<&[Vec<Expert>]>) -> Box<dyn Policy> {
        match self {
            PolicyKind::Lru => Box::new(lru::Lru::new()),
            PolicyKind::Lfu => Box::new(lfu::Lfu::new()),
            PolicyKind::LfuAged => Box::new(lfu_aged::LfuAged::default()),
            PolicyKind::Fifo => Box::new(fifo::Fifo::new()),
            PolicyKind::Random => Box::new(random::RandomPolicy::new(seed)),
            PolicyKind::Belady => Box::new(belady::Belady::new(
                future.expect("belady needs the future trace"),
            )),
            // `build` has no scoreboard to hand over, so this is the
            // weights-absent LFU-equivalent fallback; predictor-wired
            // instances come from `ExpertCache::with_policies` with
            // per-layer `learned::LearnedEviction::new(l, Some(board))`.
            PolicyKind::Learned => Box::new(learned::LearnedEviction::new(0, None)),
        }
    }
    pub fn all_online() -> [PolicyKind; 5] {
        [PolicyKind::Lru, PolicyKind::Lfu, PolicyKind::LfuAged, PolicyKind::Fifo, PolicyKind::Random]
    }
}

/// One layer's expert cache: capacity-bounded map expert -> V.
pub struct LayerCache<V> {
    capacity: usize,
    entries: Vec<(Expert, V)>,
    policy: Box<dyn Policy>,
    tick: u64,
    pub stats: CacheStats,
    /// Reused backing for the resident list handed to `Policy::victim`, so
    /// a steady-state eviction performs no allocation.
    victim_scratch: Vec<Expert>,
}

impl<V> LayerCache<V> {
    pub fn new(capacity: usize, policy: Box<dyn Policy>) -> Self {
        assert!(capacity > 0, "cache capacity must be > 0");
        LayerCache {
            capacity,
            entries: Vec::with_capacity(capacity),
            policy,
            tick: 0,
            stats: CacheStats::default(),
            victim_scratch: Vec::with_capacity(capacity),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
    /// Residents in unspecified order (for trace snapshots).
    pub fn resident(&self) -> Vec<Expert> {
        self.entries.iter().map(|(e, _)| *e).collect()
    }
    pub fn contains(&self, e: Expert) -> bool {
        self.entries.iter().any(|(k, _)| *k == e)
    }

    /// Look up `e`, recording a hit or miss. Returns the value if resident.
    pub fn access(&mut self, e: Expert) -> Option<&V> {
        self.tick += 1;
        let tick = self.tick;
        if let Some(i) = self.entries.iter().position(|(k, _)| *k == e) {
            self.stats.hits += 1;
            self.policy.on_hit(e, tick);
            Some(&self.entries[i].1)
        } else {
            self.stats.misses += 1;
            None
        }
    }

    /// Check residency without counting a hit/miss (prefetch decisions,
    /// trace snapshots).
    pub fn peek(&self, e: Expert) -> Option<&V> {
        self.entries.iter().find(|(k, _)| *k == e).map(|(_, v)| v)
    }

    /// Insert after a miss (or prefetch), evicting if full.
    /// Returns the evicted (expert, value) if any.
    pub fn insert(&mut self, e: Expert, v: V) -> Option<(Expert, V)> {
        self.tick += 1;
        let tick = self.tick;
        if let Some(i) = self.entries.iter().position(|(k, _)| *k == e) {
            // refresh in place (e.g. prefetch of an already-resident expert)
            self.entries[i].1 = v;
            self.policy.on_hit(e, tick);
            return None;
        }
        let mut evicted = None;
        if self.entries.len() >= self.capacity {
            self.victim_scratch.clear();
            self.victim_scratch.extend(self.entries.iter().map(|(k, _)| *k));
            let victim = self.policy.victim(&self.victim_scratch, tick);
            assert!(
                self.victim_scratch.contains(&victim),
                "policy {} returned non-resident victim {victim}",
                self.policy.name()
            );
            let i = self.entries.iter().position(|(k, _)| *k == victim).unwrap();
            let (k, val) = self.entries.swap_remove(i);
            self.policy.on_evict(k);
            self.stats.evictions += 1;
            evicted = Some((k, val));
        }
        self.policy.on_insert(e, tick);
        self.entries.push((e, v));
        evicted
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }
}

/// Whole-model expert cache: one [`LayerCache`] per MoE layer, as in the
/// paper (capacity is per layer, "k of E experts cached").
pub struct ExpertCache<V> {
    pub layers: Vec<LayerCache<V>>,
}

impl<V> ExpertCache<V> {
    pub fn new(n_layers: usize, capacity: usize, kind: PolicyKind, seed: u64) -> Self {
        let layers = (0..n_layers)
            .map(|l| LayerCache::new(capacity, kind.build(seed.wrapping_add(l as u64), None)))
            .collect();
        ExpertCache { layers }
    }

    /// Build from explicit per-layer policies (one per layer) — the hook
    /// the learned policy needs, since [`PolicyKind`] is `Copy` and cannot
    /// carry the shared scoreboard `Arc`.
    pub fn with_policies(capacity: usize, policies: Vec<Box<dyn Policy>>) -> Self {
        let layers = policies.into_iter().map(|p| LayerCache::new(capacity, p)).collect();
        ExpertCache { layers }
    }

    pub fn layer(&mut self, l: usize) -> &mut LayerCache<V> {
        &mut self.layers[l]
    }

    pub fn total_stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for l in &self.layers {
            s.merge(&l.stats);
        }
        s
    }

    /// Total resident f32 bytes given a per-expert footprint.
    pub fn resident_bytes(&self, expert_bytes: usize) -> usize {
        self.layers.iter().map(|l| l.len() * expert_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(kind: PolicyKind, cap: usize) -> LayerCache<u32> {
        LayerCache::new(cap, kind.build(0, None))
    }

    #[test]
    fn hit_miss_accounting() {
        let mut c = cache(PolicyKind::Lru, 2);
        assert!(c.access(1).is_none());
        c.insert(1, 10);
        assert_eq!(c.access(1), Some(&10));
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn capacity_never_exceeded() {
        for kind in PolicyKind::all_online() {
            let mut c = cache(kind, 3);
            for e in 0..20 {
                c.access(e % 7);
                if !c.contains(e % 7) {
                    c.insert(e % 7, e as u32);
                }
                assert!(c.len() <= 3, "{}: {} resident", kind.name(), c.len());
            }
        }
    }

    #[test]
    fn insert_existing_refreshes_not_grows() {
        let mut c = cache(PolicyKind::Lru, 2);
        c.insert(1, 10);
        c.insert(1, 11);
        assert_eq!(c.len(), 1);
        assert_eq!(c.peek(1), Some(&11));
    }

    #[test]
    fn eviction_returns_victim_value() {
        let mut c = cache(PolicyKind::Fifo, 1);
        c.insert(1, 10);
        let ev = c.insert(2, 20);
        assert_eq!(ev, Some((1, 10)));
        assert!(c.contains(2));
        assert!(!c.contains(1));
    }

    #[test]
    fn peek_does_not_count() {
        let mut c = cache(PolicyKind::Lru, 2);
        c.insert(1, 10);
        c.peek(1);
        c.peek(2);
        assert_eq!(c.stats.hits, 0);
        assert_eq!(c.stats.misses, 0);
    }

    #[test]
    fn policy_kind_parse() {
        assert_eq!(PolicyKind::parse("LRU"), Some(PolicyKind::Lru));
        assert_eq!(PolicyKind::parse("lfu_aged"), Some(PolicyKind::LfuAged));
        assert_eq!(PolicyKind::parse("oracle"), Some(PolicyKind::Belady));
        assert_eq!(PolicyKind::parse("arc"), None);
    }

    #[test]
    fn expert_cache_resident_bytes() {
        let mut ec: ExpertCache<()> = ExpertCache::new(2, 2, PolicyKind::Lru, 0);
        ec.layer(0).insert(1, ());
        ec.layer(0).insert(2, ());
        ec.layer(1).insert(3, ());
        assert_eq!(ec.resident_bytes(100), 300);
    }
}
