//! LFU-aged — the paper's §6.1 future-work hybrid, implemented.
//!
//! The paper's takeaway: *"we cannot allow an expert to be unevictable just
//! because it is popular. Some combination of popularity and unused count
//! might be a better option."* This policy scores each resident expert as
//! `freq * 0.5^((now - last_access) / half_life)` and evicts the minimum:
//! popularity decays exponentially while an expert goes unused, so a
//! formerly-hot expert eventually becomes evictable.

use super::{Expert, Policy};
use std::collections::HashMap;

pub struct LfuAged {
    freq: HashMap<Expert, f64>,
    last_access: HashMap<Expert, u64>,
    /// Ticks for the score to halve. One lookup = one tick; with top-2 of 8
    /// experts a token is ~2 ticks, so 32 ≈ 16 tokens of grace.
    pub half_life: f64,
}

impl Default for LfuAged {
    fn default() -> Self {
        LfuAged::new(32.0)
    }
}

impl LfuAged {
    pub fn new(half_life: f64) -> Self {
        assert!(half_life > 0.0);
        LfuAged { freq: HashMap::new(), last_access: HashMap::new(), half_life }
    }

    fn score(&self, e: Expert, now: u64) -> f64 {
        let f = self.freq.get(&e).copied().unwrap_or(0.0);
        let last = self.last_access.get(&e).copied().unwrap_or(0);
        let idle = now.saturating_sub(last) as f64;
        f * 0.5f64.powf(idle / self.half_life)
    }
}

impl Policy for LfuAged {
    fn name(&self) -> &'static str {
        "lfu-aged"
    }
    fn on_hit(&mut self, e: Expert, tick: u64) {
        *self.freq.entry(e).or_insert(0.0) += 1.0;
        self.last_access.insert(e, tick);
    }
    fn on_insert(&mut self, e: Expert, tick: u64) {
        *self.freq.entry(e).or_insert(0.0) += 1.0;
        self.last_access.insert(e, tick);
    }
    fn victim(&mut self, resident: &[Expert], tick: u64) -> Expert {
        *resident
            .iter()
            .min_by(|a, b| {
                self.score(**a, tick)
                    .partial_cmp(&self.score(**b, tick))
                    .unwrap()
                    .then(a.cmp(b))
            })
            .expect("victim() on empty resident set")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn popular_but_stale_becomes_evictable() {
        let mut p = LfuAged::new(8.0);
        for t in 0..20 {
            p.on_hit(0, t); // expert 0 very popular early
        }
        p.on_insert(1, 21); // expert 1 fresh, freq 1
        // immediately, 1 loses (0's score still high)
        assert_eq!(p.victim(&[0, 1], 22), 1);
        // but far in the future 0 has decayed below a recently-used 1
        p.on_hit(1, 200);
        assert_eq!(p.victim(&[0, 1], 201), 0);
    }

    #[test]
    fn acts_like_lfu_at_equal_recency() {
        let mut p = LfuAged::new(1e9); // effectively no decay
        p.on_insert(0, 1);
        p.on_insert(1, 1);
        p.on_hit(0, 2);
        assert_eq!(p.victim(&[0, 1], 3), 1);
    }

    #[test]
    #[should_panic]
    fn zero_half_life_rejected() {
        LfuAged::new(0.0);
    }
}
