//! Belady's MIN — the clairvoyant optimal eviction policy, used in trace
//! replay as the upper bound on what any online policy (LRU/LFU/...) could
//! achieve. Evicts the resident expert whose *next* use lies farthest in
//! the future (never-used-again first).
//!
//! Requires the layer's full activation trace up front (one entry per
//! token: the set of activated experts), so it is only available in the
//! simulator — the live engine cannot see the future, which is exactly the
//! gap speculative prefetching (paper §3.2) tries to close.

use super::{Expert, Policy};

pub struct Belady {
    /// next_use[e] = sorted positions (token indices) where e is activated.
    next_use: Vec<Vec<u64>>,
    /// Cursor per expert into `next_use`.
    cursor: Vec<usize>,
    /// Current token position, advanced via on_hit/on_insert ticks.
    now_token: u64,
}

impl Belady {
    /// `future`: per-token activated expert sets for this layer.
    pub fn new(future: &[Vec<Expert>]) -> Self {
        let max_e = future
            .iter()
            .flat_map(|s| s.iter().copied())
            .max()
            .map_or(0, |m| m + 1);
        let mut next_use = vec![Vec::new(); max_e];
        for (t, set) in future.iter().enumerate() {
            for &e in set {
                next_use[e].push(t as u64);
            }
        }
        Belady { next_use, cursor: vec![0; max_e], now_token: 0 }
    }

    /// The replay loop calls this once per token before the lookups.
    pub fn advance_token(&mut self, token_idx: u64) {
        self.now_token = token_idx;
        for e in 0..self.next_use.len() {
            while self.cursor[e] < self.next_use[e].len()
                && self.next_use[e][self.cursor[e]] < token_idx
            {
                self.cursor[e] += 1;
            }
        }
    }

    /// Next token index at which `e` is used at/after the current token.
    fn next_use_of(&self, e: Expert) -> u64 {
        if e >= self.next_use.len() {
            return u64::MAX;
        }
        let mut c = self.cursor[e];
        while c < self.next_use[e].len() {
            let t = self.next_use[e][c];
            if t > self.now_token {
                return t;
            }
            c += 1;
        }
        u64::MAX
    }
}

impl Policy for Belady {
    fn name(&self) -> &'static str {
        "belady"
    }
    fn on_hit(&mut self, _e: Expert, _tick: u64) {}
    fn on_insert(&mut self, _e: Expert, _tick: u64) {}
    fn victim(&mut self, resident: &[Expert], _tick: u64) -> Expert {
        *resident
            .iter()
            .max_by_key(|e| (self.next_use_of(**e), **e))
            .expect("victim() on empty resident set")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_farthest_next_use() {
        // tokens: t0 {0,1}, t1 {0,2}, t2 {1}, t3 {2}
        let future = vec![vec![0, 1], vec![0, 2], vec![1], vec![2]];
        let mut b = Belady::new(&future);
        b.advance_token(1);
        // at t1: next use of 0 -> never (MAX), 1 -> t2, 2 -> t3 (cursor at t1 but >now)
        assert_eq!(b.victim(&[0, 1, 2], 0), 0);
        assert_eq!(b.victim(&[1, 2], 0), 2);
    }

    #[test]
    fn never_used_again_evicted_first() {
        let future = vec![vec![3], vec![4], vec![4]];
        let mut b = Belady::new(&future);
        b.advance_token(1);
        assert_eq!(b.victim(&[3, 4], 0), 3);
    }

    #[test]
    fn unknown_expert_is_never_used() {
        let future = vec![vec![0]];
        let mut b = Belady::new(&future);
        b.advance_token(0);
        // expert 9 not in trace at all -> farthest
        assert_eq!(b.victim(&[0, 9], 0), 9);
    }
}
