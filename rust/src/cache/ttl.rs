//! TTL-augmented LRU — the paper's §6.1 "early eviction on experts that
//! have not been used for a long time period" direction.
//!
//! Behaves like LRU for victim selection, but additionally exposes
//! `expired` so the engine/simulator can proactively drop entries idle for
//! more than `ttl` ticks — freeing (simulated) device memory without
//! waiting for capacity pressure. The paper's warning applies: proactive
//! management only pays off when the freed space is used for something
//! (e.g. speculative prefetch) and transfers overlap with compute.

use super::{Expert, Policy};
use std::collections::HashMap;

pub struct TtlLru {
    last_access: HashMap<Expert, u64>,
    pub ttl: u64,
}

impl TtlLru {
    pub fn new(ttl: u64) -> Self {
        assert!(ttl > 0);
        TtlLru { last_access: HashMap::new(), ttl }
    }

    /// Experts idle longer than the TTL (candidates for early eviction).
    pub fn expired(&self, resident: &[Expert], now: u64) -> Vec<Expert> {
        resident
            .iter()
            .copied()
            .filter(|e| {
                now.saturating_sub(self.last_access.get(e).copied().unwrap_or(0)) > self.ttl
            })
            .collect()
    }
}

impl Policy for TtlLru {
    fn name(&self) -> &'static str {
        "ttl-lru"
    }
    fn on_hit(&mut self, e: Expert, tick: u64) {
        self.last_access.insert(e, tick);
    }
    fn on_insert(&mut self, e: Expert, tick: u64) {
        self.last_access.insert(e, tick);
    }
    fn victim(&mut self, resident: &[Expert], now: u64) -> Expert {
        // expired entries first, then plain LRU
        if let Some(&e) = self
            .expired(resident, now)
            .iter()
            .min_by_key(|e| (self.last_access.get(e).copied().unwrap_or(0), **e))
        {
            return e;
        }
        *resident
            .iter()
            .min_by_key(|e| (self.last_access.get(e).copied().unwrap_or(0), **e))
            .expect("victim() on empty resident set")
    }
    fn on_evict(&mut self, e: Expert) {
        self.last_access.remove(&e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expired_detection() {
        let mut p = TtlLru::new(10);
        p.on_insert(0, 5);
        p.on_insert(1, 14);
        assert_eq!(p.expired(&[0, 1], 16), vec![0]);
        assert!(p.expired(&[0, 1], 10).is_empty());
    }

    #[test]
    fn victim_prefers_expired() {
        let mut p = TtlLru::new(5);
        p.on_insert(0, 1);
        p.on_insert(1, 2);
        p.on_hit(0, 20); // 1 is long idle
        assert_eq!(p.victim(&[0, 1], 21), 1);
    }

    #[test]
    fn falls_back_to_lru_when_nothing_expired() {
        let mut p = TtlLru::new(1000);
        p.on_insert(0, 1);
        p.on_insert(1, 2);
        assert_eq!(p.victim(&[0, 1], 3), 0);
    }
}
