//! LRU — the baseline policy (Eliseev & Mazur 2023, used by the paper's
//! Figures 1–6). Evicts the least recently *accessed* expert. The paper's
//! traces show its weakness: the cache "repeats history rather than
//! predicting the future" when temporal locality is weak.

use super::{Expert, Policy};
use std::collections::HashMap;

#[derive(Default)]
pub struct Lru {
    last_access: HashMap<Expert, u64>,
}

impl Lru {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Policy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }
    fn on_hit(&mut self, e: Expert, tick: u64) {
        self.last_access.insert(e, tick);
    }
    fn on_insert(&mut self, e: Expert, tick: u64) {
        self.last_access.insert(e, tick);
    }
    fn victim(&mut self, resident: &[Expert], _tick: u64) -> Expert {
        *resident
            .iter()
            .min_by_key(|e| (self.last_access.get(e).copied().unwrap_or(0), **e))
            .expect("victim() on empty resident set")
    }
    fn on_evict(&mut self, e: Expert) {
        self.last_access.remove(&e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recent() {
        let mut p = Lru::new();
        p.on_insert(0, 1);
        p.on_insert(1, 2);
        p.on_insert(2, 3);
        p.on_hit(0, 4); // 0 refreshed; 1 is now oldest
        assert_eq!(p.victim(&[0, 1, 2], 5), 1);
    }

    #[test]
    fn deterministic_tiebreak() {
        let mut p = Lru::new();
        // never-seen experts tie at 0 -> lowest index wins
        assert_eq!(p.victim(&[3, 1, 2], 1), 1);
    }

    #[test]
    fn eviction_clears_state() {
        let mut p = Lru::new();
        p.on_insert(5, 10);
        p.on_evict(5);
        p.on_insert(6, 11);
        // 5 re-inserted later should not remember its old timestamp
        p.on_insert(5, 12);
        assert_eq!(p.victim(&[5, 6], 13), 6);
    }
}
