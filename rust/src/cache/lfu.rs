//! LFU — the paper's proposed policy (§4.2): evict the least *frequently*
//! used expert, exploiting the strong expert-imbalance phenomenon (§5.2).
//!
//! Frequency is cumulative over the whole decode and survives eviction —
//! this matches the paper's implementation ("we added one usage count field
//! in the information of experts") and produces its §5.3 observation that
//! "some experts remain in the cache throughout all tokens". Ties break by
//! recency, then index, for determinism.

use super::{Expert, Policy};
use std::collections::HashMap;

#[derive(Default)]
pub struct Lfu {
    freq: HashMap<Expert, u64>,
    last_access: HashMap<Expert, u64>,
}

impl Lfu {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn frequency(&self, e: Expert) -> u64 {
        self.freq.get(&e).copied().unwrap_or(0)
    }
}

impl Policy for Lfu {
    fn name(&self) -> &'static str {
        "lfu"
    }
    fn on_hit(&mut self, e: Expert, tick: u64) {
        *self.freq.entry(e).or_insert(0) += 1;
        self.last_access.insert(e, tick);
    }
    fn on_insert(&mut self, e: Expert, tick: u64) {
        *self.freq.entry(e).or_insert(0) += 1;
        self.last_access.insert(e, tick);
    }
    fn victim(&mut self, resident: &[Expert], _tick: u64) -> Expert {
        *resident
            .iter()
            .min_by_key(|e| {
                (
                    self.freq.get(e).copied().unwrap_or(0),
                    self.last_access.get(e).copied().unwrap_or(0),
                    **e,
                )
            })
            .expect("victim() on empty resident set")
    }
    // NOTE: no on_evict cleanup — frequency is global history by design.
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_frequent() {
        let mut p = Lfu::new();
        p.on_insert(0, 1);
        p.on_insert(1, 2);
        p.on_hit(0, 3);
        p.on_hit(0, 4); // freq: 0 -> 3, 1 -> 1
        assert_eq!(p.victim(&[0, 1], 5), 1);
    }

    #[test]
    fn frequency_survives_eviction() {
        let mut p = Lfu::new();
        for t in 0..5 {
            p.on_hit(7, t);
        }
        p.on_evict(7);
        p.on_insert(7, 10); // comes back with freq 6
        p.on_insert(3, 11); // freq 1
        assert_eq!(p.victim(&[7, 3], 12), 3);
    }

    #[test]
    fn tie_breaks_by_recency() {
        let mut p = Lfu::new();
        p.on_insert(0, 1);
        p.on_insert(1, 2); // equal freq, 0 older
        assert_eq!(p.victim(&[0, 1], 3), 0);
    }
}
