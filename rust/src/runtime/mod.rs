//! Execution runtime: the PJRT-backed AOT path and the pure-rust native
//! oracle, behind one [`Backend`] trait the engine composes per token.
//!
//! The PJRT path (`pjrt.rs`) is the production configuration: it loads the
//! HLO-text artifacts produced by `python/compile/aot.py`, compiles them
//! once on the CPU PJRT client, and executes them from the request path.
//! The native path (`native.rs`) reimplements the exact same math in rust;
//! it exists as a correctness cross-check (`selfcheck`), lets the full test
//! suite run without artifacts, and serves as the compute-cost baseline.

pub mod artifacts;
pub mod native;
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
pub mod xla_stub;

// The `xla` crate needs the XLA C library and a network to fetch it; the
// default (offline) build substitutes `xla_stub`, which has the same API
// surface but fails at PJRT-client construction. Enabling `--features pjrt`
// switches to the real crate (which must be added to Cargo.toml manually in
// an online environment — see DESIGN.md §6).
#[cfg(not(feature = "pjrt"))]
pub(crate) use xla_stub as xla;
#[cfg(feature = "pjrt")]
pub(crate) use ::xla;

use crate::model::ModelConfig;
use anyhow::Result;

/// Expert weights made resident "on device" — the unit the expert cache
/// holds. For the PJRT backend these are device buffers (upload happened at
/// transfer time); for the native backend, dequantized host tensors.
pub enum ExpertHandle {
    Device { w1: xla::PjRtBuffer, w3: xla::PjRtBuffer, w2: xla::PjRtBuffer },
    Host { w1: Vec<f32>, w3: Vec<f32>, w2: Vec<f32> },
}

impl ExpertHandle {
    /// Resident f32 bytes this handle pins on the (simulated) device.
    pub fn resident_bytes(cfg: &ModelConfig) -> usize {
        cfg.expert_bytes_f32()
    }
}

/// Per-sequence KV-cache state, one (k, v) pair per layer, host-resident
/// f32 (flattened `[max_seq, n_heads, head_dim]`).
///
/// Host-side for both backends: PJRT stage outputs arrive as ONE tuple
/// buffer (the c-wrapper never sets `untuple_result`), so the updated
/// caches must be downloaded each step anyway; and the crate's
/// `buffer_from_host_literal` does not await the async transfer, making
/// host slices + `buffer_from_host_buffer` (which copies during the call)
/// the only sound upload path for per-step data.
pub struct KvState(pub Vec<(Vec<f32>, Vec<f32>)>);

impl KvState {
    pub fn zeros(cfg: &ModelConfig) -> KvState {
        let per_layer = cfg.max_seq * cfg.hidden_size;
        KvState(
            (0..cfg.n_layers)
                .map(|_| (vec![0.0; per_layer], vec![0.0; per_layer]))
                .collect(),
        )
    }
    /// f32 bytes resident for one sequence's caches.
    pub fn bytes(cfg: &ModelConfig) -> usize {
        2 * cfg.n_layers * cfg.max_seq * cfg.hidden_size * 4
    }
}

/// Stage-level model execution. One impl per runtime; the engine (L3)
/// composes stages and owns every offloading decision in between.
pub trait Backend {
    fn config(&self) -> &ModelConfig;
    fn new_kv(&self) -> Result<KvState>;
    /// Token embedding: x[1,H].
    fn embed(&self, tok: u32) -> Result<Vec<f32>>;
    /// Attention block at `layer`: returns post-residual hidden states and
    /// updates `kv` at position `pos`.
    fn attn(&self, layer: usize, x: &[f32], kv: &mut KvState, pos: usize) -> Result<Vec<f32>>;
    /// Router at `layer`: returns (normed hidden states h, expert probs).
    fn router(&self, layer: usize, x_res: &[f32]) -> Result<(Vec<f32>, Vec<f32>)>;
    /// Speculative gating (paper §3.2): apply `layer`'s router to hidden
    /// states that came out of the *previous* layer. Probs only.
    fn spec_router(&self, layer: usize, x_res: &[f32]) -> Result<Vec<f32>>;
    /// One expert's FFN with explicitly provided (cached) weights.
    fn expert(&self, h: &[f32], handle: &ExpertHandle) -> Result<Vec<f32>>;
    /// Marks the start of one `step_round` call. A pure observability hook:
    /// test wrappers (the round recorder) segment their logs on it; real
    /// backends need no state and keep the default no-op.
    fn begin_round(&self) {}
    /// One expert's FFN over several rows at once — the round-batched form
    /// of [`Backend::expert`]. `layer`/`expert`/`sessions` are observability
    /// tags (consumed by test wrappers, ignored by real backends); the math
    /// contract is that row `i` of the result is bit-identical to
    /// `self.expert(hs[i], handle)`, which is exactly what the default
    /// implementation computes. Backends with reusable scratch (native)
    /// override this to amortize buffer setup across rows.
    fn expert_multi(
        &self,
        layer: usize,
        expert: usize,
        sessions: &[u64],
        hs: &[&[f32]],
        handle: &ExpertHandle,
    ) -> Result<Vec<Vec<f32>>> {
        let _ = (layer, expert, sessions);
        hs.iter().map(|h| self.expert(h, handle)).collect()
    }
    /// Make dequantized expert weights device-resident (the upload half of a
    /// transfer; the dequant half lives in `offload::store`).
    fn upload_expert(&self, w1: Vec<f32>, w3: Vec<f32>, w2: Vec<f32>) -> Result<ExpertHandle>;
    /// Final norm + LM head: logits[1,V].
    fn final_logits(&self, x: &[f32]) -> Result<Vec<f32>>;
    fn name(&self) -> &'static str;
}
