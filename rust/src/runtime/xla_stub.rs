//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The build environment has no crates.io access and no XLA C library, so
//! the real `xla` dependency is gated behind the `pjrt` cargo feature (see
//! DESIGN.md §6). Without that feature this module provides the exact API
//! surface `runtime::pjrt` compiles against: every type is uninhabited and
//! every constructor returns [`XlaError`], so [`super::pjrt::PjrtBackend`]
//! type-checks, links, and fails at *construction time* with an actionable
//! message instead of failing the whole build. All tests, benches, examples
//! and the serve path run on the native backend, which needs none of this.

use std::fmt;

/// Error every stubbed constructor returns.
#[derive(Debug)]
pub struct XlaError;

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PJRT runtime unavailable: built without the `pjrt` feature \
             (the `xla` crate is not vendored); use --backend native, or add \
             the xla dependency and build with --features pjrt"
        )
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

/// Device buffer handle (uninhabited: no PJRT client can exist in a stub
/// build, so no buffer can either).
pub enum PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match *self {}
    }
}

/// Host literal (uninhabited).
pub enum Literal {}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match *self {}
    }
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        match *self {}
    }
}

/// PJRT client (uninhabited; [`PjRtClient::cpu`] always errors).
pub enum PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError)
    }
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        match *self {}
    }
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match *self {}
    }
}

/// Compiled executable (uninhabited).
pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match *self {}
    }
}

/// Parsed HLO module proto (uninhabited; parsing always errors).
pub enum HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaError)
    }
}

/// XLA computation wrapper (uninhabited).
pub enum XlaComputation {}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match *proto {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_fail_with_actionable_message() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("pjrt"), "{msg}");
        assert!(msg.contains("native"), "{msg}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
