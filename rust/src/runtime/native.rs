//! Pure-rust reference implementation of MiniMixtral — the native oracle.
//!
//! Bit-for-bit architectural mirror of `python/compile/model.py` (RMSNorm,
//! rotate-half RoPE, causal MHA over a static KV cache, SwiGLU experts,
//! softmax gating). Used to cross-check the PJRT artifacts (`selfcheck`),
//! to run the full engine/cache/offload stack in tests without artifacts,
//! and as the compute-time baseline in the cost model.

use super::{Backend, ExpertHandle, KvState};
use crate::model::{ModelConfig, Weights};
use anyhow::{bail, Result};
use std::sync::Arc;

pub struct NativeBackend {
    weights: Arc<Weights>,
    cfg: ModelConfig,
}

impl NativeBackend {
    pub fn new(weights: Arc<Weights>) -> Self {
        let cfg = weights.config;
        NativeBackend { weights, cfg }
    }

    pub fn weights(&self) -> &Weights {
        &self.weights
    }
}

// ---------------------------------------------------------------------------
// linear algebra primitives (f32, row-major)
// ---------------------------------------------------------------------------

/// y[j] = sum_i x[i] * w[i, j]  — vector–matrix product, w: [n, m].
pub fn vecmat(x: &[f32], w: &[f32], m: usize, out: &mut [f32]) {
    let n = x.len();
    debug_assert_eq!(w.len(), n * m);
    debug_assert_eq!(out.len(), m);
    out.fill(0.0);
    // row-major traversal: stream w sequentially, accumulate into out
    for i in 0..n {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * m..(i + 1) * m];
        for (o, &wv) in out.iter_mut().zip(row) {
            *o += xi * wv;
        }
    }
}

pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &xv), &wv) in out.iter_mut().zip(x).zip(w) {
        *o = xv * inv * wv;
    }
}

pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Rotate-half RoPE applied in place to one head vector of length `hd`.
fn rope_inplace(v: &mut [f32], pos: usize, theta: f32) {
    let hd = v.len();
    let half = hd / 2;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let (a, b) = (v[i], v[i + half]);
        v[i] = a * cos - b * sin;
        v[i + half] = a * sin + b * cos;
    }
}

/// SwiGLU expert FFN on host weights: `(silu(h@w1) * (h@w3)) @ w2`.
pub fn expert_ffn(h: &[f32], w1: &[f32], w3: &[f32], w2: &[f32], f: usize, out: &mut [f32]) {
    let mut a = vec![0.0f32; f];
    let mut u = vec![0.0f32; f];
    vecmat(h, w1, f, &mut a);
    vecmat(h, w3, f, &mut u);
    for (av, &uv) in a.iter_mut().zip(u.iter()) {
        *av = silu(*av) * uv;
    }
    vecmat(&a, w2, out.len(), out);
}

// ---------------------------------------------------------------------------
// Backend impl
// ---------------------------------------------------------------------------

const ROPE_THETA: f32 = 10000.0;
const RMS_EPS: f32 = 1e-5;

impl Backend for NativeBackend {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn new_kv(&self) -> Result<KvState> {
        Ok(KvState::zeros(&self.cfg))
    }

    fn embed(&self, tok: u32) -> Result<Vec<f32>> {
        let c = &self.cfg;
        if tok as usize >= c.vocab_size {
            bail!("token {tok} out of vocab {}", c.vocab_size);
        }
        let table = self.weights.get("embed.table")?;
        let h = c.hidden_size;
        Ok(table[tok as usize * h..(tok as usize + 1) * h].to_vec())
    }

    fn attn(&self, layer: usize, x: &[f32], kv: &mut KvState, pos: usize) -> Result<Vec<f32>> {
        let c = &self.cfg;
        let (h, nh, hd, s) = (c.hidden_size, c.n_heads, c.head_dim(), c.max_seq);
        if pos >= s {
            bail!("pos {pos} >= max_seq {s}");
        }
        let (kc, vc) = &mut kv.0[layer];

        let ln1 = self.weights.layer(layer, "ln1")?;
        let mut hn = vec![0.0f32; h];
        rmsnorm(x, ln1, RMS_EPS, &mut hn);

        let mut q = vec![0.0f32; h];
        let mut k = vec![0.0f32; h];
        let mut v = vec![0.0f32; h];
        vecmat(&hn, self.weights.layer(layer, "wq")?, h, &mut q);
        vecmat(&hn, self.weights.layer(layer, "wk")?, h, &mut k);
        vecmat(&hn, self.weights.layer(layer, "wv")?, h, &mut v);
        for hh in 0..nh {
            rope_inplace(&mut q[hh * hd..(hh + 1) * hd], pos, ROPE_THETA);
            rope_inplace(&mut k[hh * hd..(hh + 1) * hd], pos, ROPE_THETA);
        }
        // cache rows are [pos][head][dim] flattened as pos*h + head*hd + d
        kc[pos * h..(pos + 1) * h].copy_from_slice(&k);
        vc[pos * h..(pos + 1) * h].copy_from_slice(&v);

        // attention per head over positions 0..=pos
        let scale = 1.0 / (hd as f32).sqrt();
        let mut attn_out = vec![0.0f32; h];
        let mut scores = vec![0.0f32; pos + 1];
        for hh in 0..nh {
            let qh = &q[hh * hd..(hh + 1) * hd];
            for (p, sc) in scores.iter_mut().enumerate() {
                let kh = &kc[p * h + hh * hd..p * h + (hh + 1) * hd];
                *sc = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            softmax_inplace(&mut scores);
            let oh = &mut attn_out[hh * hd..(hh + 1) * hd];
            for (p, &w) in scores.iter().enumerate() {
                let vh = &vc[p * h + hh * hd..p * h + (hh + 1) * hd];
                for (o, &vv) in oh.iter_mut().zip(vh) {
                    *o += w * vv;
                }
            }
        }
        let mut proj = vec![0.0f32; h];
        vecmat(&attn_out, self.weights.layer(layer, "wo")?, h, &mut proj);
        Ok(x.iter().zip(&proj).map(|(a, b)| a + b).collect())
    }

    fn router(&self, layer: usize, x_res: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let c = &self.cfg;
        let mut hn = vec![0.0f32; c.hidden_size];
        rmsnorm(x_res, self.weights.layer(layer, "ln2")?, RMS_EPS, &mut hn);
        let mut probs = vec![0.0f32; c.n_experts];
        vecmat(&hn, self.weights.layer(layer, "gate")?, c.n_experts, &mut probs);
        softmax_inplace(&mut probs);
        Ok((hn, probs))
    }

    fn spec_router(&self, layer: usize, x_res: &[f32]) -> Result<Vec<f32>> {
        Ok(self.router(layer, x_res)?.1)
    }

    fn expert(&self, h: &[f32], handle: &ExpertHandle) -> Result<Vec<f32>> {
        let ExpertHandle::Host { w1, w3, w2 } = handle else {
            bail!("native backend got a device handle");
        };
        let mut out = vec![0.0f32; self.cfg.hidden_size];
        expert_ffn(h, w1, w3, w2, self.cfg.ffn_size, &mut out);
        Ok(out)
    }

    fn upload_expert(&self, w1: Vec<f32>, w3: Vec<f32>, w2: Vec<f32>) -> Result<ExpertHandle> {
        Ok(ExpertHandle::Host { w1, w3, w2 })
    }

    fn final_logits(&self, x: &[f32]) -> Result<Vec<f32>> {
        let c = &self.cfg;
        let mut hn = vec![0.0f32; c.hidden_size];
        rmsnorm(x, self.weights.get("final.ln")?, RMS_EPS, &mut hn);
        let mut logits = vec![0.0f32; c.vocab_size];
        vecmat(&hn, self.weights.get("final.lm_head")?, c.vocab_size, &mut logits);
        Ok(logits)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vecmat_identity() {
        let x = [1.0, 2.0, 3.0];
        #[rustfmt::skip]
        let w = [1.0, 0.0, 0.0,
                 0.0, 1.0, 0.0,
                 0.0, 0.0, 1.0];
        let mut out = [0.0; 3];
        vecmat(&x, &w, 3, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn vecmat_known() {
        // x[1,2] @ w[2,2] = [1*1+2*3, 1*2+2*4] = [7, 10]
        let x = [1.0, 2.0];
        let w = [1.0, 2.0, 3.0, 4.0];
        let mut out = [0.0; 2];
        vecmat(&x, &w, 2, &mut out);
        assert_eq!(out, [7.0, 10.0]);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = [3.0f32, 4.0];
        let w = [1.0f32, 1.0];
        let mut out = [0.0f32; 2];
        rmsnorm(&x, &w, 0.0, &mut out);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-6);
        assert!((out[1] - 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn softmax_normalizes() {
        let mut xs = [1.0f32, 2.0, 3.0];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let mut v = [0.1f32, 0.2, 0.3, 0.4];
        let orig = v;
        rope_inplace(&mut v, 0, 10000.0);
        assert_eq!(v, orig);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut v = [0.5f32, -0.3, 0.8, 0.1];
        let n0: f32 = v.iter().map(|x| x * x).sum();
        rope_inplace(&mut v, 17, 10000.0);
        let n1: f32 = v.iter().map(|x| x * x).sum();
        assert!((n0 - n1).abs() < 1e-5);
    }

    #[test]
    fn expert_ffn_zero_input_zero_output() {
        let h = vec![0.0f32; 4];
        let w = vec![0.5f32; 4 * 8];
        let w2 = vec![0.5f32; 8 * 4];
        let mut out = vec![1.0f32; 4];
        expert_ffn(&h, &w, &w, &w2, 8, &mut out);
        assert_eq!(out, vec![0.0; 4]);
    }
}
