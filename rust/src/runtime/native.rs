//! Pure-rust reference implementation of MiniMixtral — the native oracle.
//!
//! Bit-for-bit architectural mirror of `python/compile/model.py` (RMSNorm,
//! rotate-half RoPE, causal MHA over a static KV cache, SwiGLU experts,
//! softmax gating). Used to cross-check the PJRT artifacts (`selfcheck`),
//! to run the full engine/cache/offload stack in tests without artifacts,
//! and as the compute-time baseline in the cost model.

use super::{Backend, ExpertHandle, KvState};
use crate::model::{ModelConfig, Weights};
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::sync::Arc;

/// Reusable intermediates for the per-token hot path. `attn`, `spec_router`
/// and `expert` run once per (token, layer[, expert]) and used to allocate
/// every temporary; the scratch keeps them alive across calls so the only
/// steady-state allocations left are the owned return values the
/// [`Backend`] trait requires. Behind a `RefCell` because the trait takes
/// `&self` and exactly one engine thread drives a backend.
struct Scratch {
    hn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    scores: Vec<f32>,
    proj: Vec<f32>,
    ffn_a: Vec<f32>,
    ffn_u: Vec<f32>,
}

impl Scratch {
    fn new(cfg: &ModelConfig) -> Scratch {
        let h = cfg.hidden_size;
        Scratch {
            hn: vec![0.0; h],
            q: vec![0.0; h],
            k: vec![0.0; h],
            v: vec![0.0; h],
            attn_out: vec![0.0; h],
            scores: Vec::with_capacity(cfg.max_seq),
            proj: vec![0.0; h],
            ffn_a: vec![0.0; cfg.ffn_size],
            ffn_u: vec![0.0; cfg.ffn_size],
        }
    }
}

pub struct NativeBackend {
    weights: Arc<Weights>,
    cfg: ModelConfig,
    scratch: RefCell<Scratch>,
}

impl NativeBackend {
    pub fn new(weights: Arc<Weights>) -> Self {
        let cfg = weights.config;
        let scratch = RefCell::new(Scratch::new(&cfg));
        NativeBackend { weights, cfg, scratch }
    }

    pub fn weights(&self) -> &Weights {
        &self.weights
    }
}

// ---------------------------------------------------------------------------
// linear algebra primitives (f32, row-major)
// ---------------------------------------------------------------------------

/// y[j] = sum_i x[i] * w[i, j]  — vector–matrix product, w: [n, m].
///
/// The inner loop is unrolled 4-wide with `chunks_exact` so the
/// accumulations auto-vectorize; per-element results are bit-identical to
/// the naive loop because each `out[j]` still receives exactly one
/// `xi * w[i][j]` per row, in row order (asserted by
/// `vecmat_unrolled_matches_naive`).
pub fn vecmat(x: &[f32], w: &[f32], m: usize, out: &mut [f32]) {
    let n = x.len();
    debug_assert_eq!(w.len(), n * m);
    debug_assert_eq!(out.len(), m);
    out.fill(0.0);
    // row-major traversal: stream w sequentially, accumulate into out
    for i in 0..n {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * m..(i + 1) * m];
        let mut oc = out.chunks_exact_mut(4);
        let mut rc = row.chunks_exact(4);
        for (o4, r4) in oc.by_ref().zip(rc.by_ref()) {
            o4[0] += xi * r4[0];
            o4[1] += xi * r4[1];
            o4[2] += xi * r4[2];
            o4[3] += xi * r4[3];
        }
        for (o, &wv) in oc.into_remainder().iter_mut().zip(rc.remainder()) {
            *o += xi * wv;
        }
    }
}

pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &xv), &wv) in out.iter_mut().zip(x).zip(w) {
        *o = xv * inv * wv;
    }
}

pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Rotate-half RoPE applied in place to one head vector of length `hd`.
fn rope_inplace(v: &mut [f32], pos: usize, theta: f32) {
    let hd = v.len();
    let half = hd / 2;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let (a, b) = (v[i], v[i + half]);
        v[i] = a * cos - b * sin;
        v[i + half] = a * sin + b * cos;
    }
}

/// SwiGLU expert FFN on host weights: `(silu(h@w1) * (h@w3)) @ w2`, writing
/// through caller-provided intermediates (resized to `f`; allocation-free
/// when recycled across calls).
#[allow(clippy::too_many_arguments)]
pub fn expert_ffn_into(
    h: &[f32],
    w1: &[f32],
    w3: &[f32],
    w2: &[f32],
    f: usize,
    a: &mut Vec<f32>,
    u: &mut Vec<f32>,
    out: &mut [f32],
) {
    a.resize(f, 0.0);
    u.resize(f, 0.0);
    vecmat(h, w1, f, a);
    vecmat(h, w3, f, u);
    for (av, &uv) in a.iter_mut().zip(u.iter()) {
        *av = silu(*av) * uv;
    }
    vecmat(a, w2, out.len(), out);
}

/// SwiGLU expert FFN allocating its own intermediates (tests/benches).
pub fn expert_ffn(h: &[f32], w1: &[f32], w3: &[f32], w2: &[f32], f: usize, out: &mut [f32]) {
    let mut a = vec![0.0f32; f];
    let mut u = vec![0.0f32; f];
    expert_ffn_into(h, w1, w3, w2, f, &mut a, &mut u, out);
}

/// One expert's SwiGLU FFN over several rows at once — the round-batched
/// form of [`expert_ffn_into`]. Row `i` of `outs` is bit-identical to
/// `expert_ffn_into(hs[i], ...)` because each row runs the exact same
/// per-row vecmat sequence over the same weights; batching only amortizes
/// the intermediate buffers (`a`/`u` resized once, then recycled row to
/// row — the zero-allocation invariant from DESIGN.md §7 holds for the
/// whole batch).
#[allow(clippy::too_many_arguments)]
pub fn expert_ffn_multi_into(
    hs: &[&[f32]],
    w1: &[f32],
    w3: &[f32],
    w2: &[f32],
    f: usize,
    a: &mut Vec<f32>,
    u: &mut Vec<f32>,
    outs: &mut [Vec<f32>],
) {
    debug_assert_eq!(hs.len(), outs.len());
    a.resize(f, 0.0);
    u.resize(f, 0.0);
    for (h, out) in hs.iter().zip(outs.iter_mut()) {
        vecmat(h, w1, f, a);
        vecmat(h, w3, f, u);
        for (av, &uv) in a.iter_mut().zip(u.iter()) {
            *av = silu(*av) * uv;
        }
        vecmat(a, w2, out.len(), out);
    }
}

// ---------------------------------------------------------------------------
// Backend impl
// ---------------------------------------------------------------------------

const ROPE_THETA: f32 = 10000.0;
const RMS_EPS: f32 = 1e-5;

impl Backend for NativeBackend {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn new_kv(&self) -> Result<KvState> {
        Ok(KvState::zeros(&self.cfg))
    }

    fn embed(&self, tok: u32) -> Result<Vec<f32>> {
        let c = &self.cfg;
        if tok as usize >= c.vocab_size {
            bail!("token {tok} out of vocab {}", c.vocab_size);
        }
        let table = self.weights.get("embed.table")?;
        let h = c.hidden_size;
        Ok(table[tok as usize * h..(tok as usize + 1) * h].to_vec())
    }

    fn attn(&self, layer: usize, x: &[f32], kv: &mut KvState, pos: usize) -> Result<Vec<f32>> {
        let c = &self.cfg;
        let (h, nh, hd, s) = (c.hidden_size, c.n_heads, c.head_dim(), c.max_seq);
        if pos >= s {
            bail!("pos {pos} >= max_seq {s}");
        }
        let (kc, vc) = &mut kv.0[layer];
        let mut scratch = self.scratch.borrow_mut();
        let Scratch { hn, q, k, v, attn_out, scores, proj, .. } = &mut *scratch;

        let ln1 = self.weights.layer(layer, "ln1")?;
        rmsnorm(x, ln1, RMS_EPS, hn);

        vecmat(hn, self.weights.layer(layer, "wq")?, h, q);
        vecmat(hn, self.weights.layer(layer, "wk")?, h, k);
        vecmat(hn, self.weights.layer(layer, "wv")?, h, v);
        for hh in 0..nh {
            rope_inplace(&mut q[hh * hd..(hh + 1) * hd], pos, ROPE_THETA);
            rope_inplace(&mut k[hh * hd..(hh + 1) * hd], pos, ROPE_THETA);
        }
        // cache rows are [pos][head][dim] flattened as pos*h + head*hd + d
        kc[pos * h..(pos + 1) * h].copy_from_slice(k);
        vc[pos * h..(pos + 1) * h].copy_from_slice(v);

        // attention per head over positions 0..=pos
        let scale = 1.0 / (hd as f32).sqrt();
        attn_out.fill(0.0);
        scores.resize(pos + 1, 0.0);
        for hh in 0..nh {
            let qh = &q[hh * hd..(hh + 1) * hd];
            for (p, sc) in scores.iter_mut().enumerate() {
                let kh = &kc[p * h + hh * hd..p * h + (hh + 1) * hd];
                *sc = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            softmax_inplace(scores);
            let oh = &mut attn_out[hh * hd..(hh + 1) * hd];
            for (p, &w) in scores.iter().enumerate() {
                let vh = &vc[p * h + hh * hd..p * h + (hh + 1) * hd];
                for (o, &vv) in oh.iter_mut().zip(vh) {
                    *o += w * vv;
                }
            }
        }
        vecmat(attn_out, self.weights.layer(layer, "wo")?, h, proj);
        Ok(x.iter().zip(proj.iter()).map(|(a, b)| a + b).collect())
    }

    fn router(&self, layer: usize, x_res: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let c = &self.cfg;
        let mut hn = vec![0.0f32; c.hidden_size];
        rmsnorm(x_res, self.weights.layer(layer, "ln2")?, RMS_EPS, &mut hn);
        let mut probs = vec![0.0f32; c.n_experts];
        vecmat(&hn, self.weights.layer(layer, "gate")?, c.n_experts, &mut probs);
        softmax_inplace(&mut probs);
        Ok((hn, probs))
    }

    fn spec_router(&self, layer: usize, x_res: &[f32]) -> Result<Vec<f32>> {
        // same math as `router`, but the normed hidden states land in
        // scratch (only the probs are returned, so only they allocate)
        let c = &self.cfg;
        let mut scratch = self.scratch.borrow_mut();
        let Scratch { hn, .. } = &mut *scratch;
        rmsnorm(x_res, self.weights.layer(layer, "ln2")?, RMS_EPS, hn);
        let mut probs = vec![0.0f32; c.n_experts];
        vecmat(hn, self.weights.layer(layer, "gate")?, c.n_experts, &mut probs);
        softmax_inplace(&mut probs);
        Ok(probs)
    }

    fn expert(&self, h: &[f32], handle: &ExpertHandle) -> Result<Vec<f32>> {
        let ExpertHandle::Host { w1, w3, w2 } = handle else {
            bail!("native backend got a device handle");
        };
        let mut out = vec![0.0f32; self.cfg.hidden_size];
        let mut scratch = self.scratch.borrow_mut();
        let Scratch { ffn_a, ffn_u, .. } = &mut *scratch;
        expert_ffn_into(h, w1, w3, w2, self.cfg.ffn_size, ffn_a, ffn_u, &mut out);
        Ok(out)
    }

    fn expert_multi(
        &self,
        _layer: usize,
        _expert: usize,
        _sessions: &[u64],
        hs: &[&[f32]],
        handle: &ExpertHandle,
    ) -> Result<Vec<Vec<f32>>> {
        let ExpertHandle::Host { w1, w3, w2 } = handle else {
            bail!("native backend got a device handle");
        };
        let mut outs = vec![vec![0.0f32; self.cfg.hidden_size]; hs.len()];
        let mut scratch = self.scratch.borrow_mut();
        let Scratch { ffn_a, ffn_u, .. } = &mut *scratch;
        expert_ffn_multi_into(hs, w1, w3, w2, self.cfg.ffn_size, ffn_a, ffn_u, &mut outs);
        Ok(outs)
    }

    fn upload_expert(&self, w1: Vec<f32>, w3: Vec<f32>, w2: Vec<f32>) -> Result<ExpertHandle> {
        Ok(ExpertHandle::Host { w1, w3, w2 })
    }

    fn final_logits(&self, x: &[f32]) -> Result<Vec<f32>> {
        let c = &self.cfg;
        let mut hn = vec![0.0f32; c.hidden_size];
        rmsnorm(x, self.weights.get("final.ln")?, RMS_EPS, &mut hn);
        let mut logits = vec![0.0f32; c.vocab_size];
        vecmat(&hn, self.weights.get("final.lm_head")?, c.vocab_size, &mut logits);
        Ok(logits)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vecmat_identity() {
        let x = [1.0, 2.0, 3.0];
        #[rustfmt::skip]
        let w = [1.0, 0.0, 0.0,
                 0.0, 1.0, 0.0,
                 0.0, 0.0, 1.0];
        let mut out = [0.0; 3];
        vecmat(&x, &w, 3, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn vecmat_known() {
        // x[1,2] @ w[2,2] = [1*1+2*3, 1*2+2*4] = [7, 10]
        let x = [1.0, 2.0];
        let w = [1.0, 2.0, 3.0, 4.0];
        let mut out = [0.0; 2];
        vecmat(&x, &w, 2, &mut out);
        assert_eq!(out, [7.0, 10.0]);
    }

    #[test]
    fn vecmat_unrolled_matches_naive() {
        fn naive(x: &[f32], w: &[f32], m: usize, out: &mut [f32]) {
            out.fill(0.0);
            for i in 0..x.len() {
                let xi = x[i];
                if xi == 0.0 {
                    continue;
                }
                for j in 0..m {
                    out[j] += xi * w[i * m + j];
                }
            }
        }
        // ragged shapes around the 4-wide unroll boundary, with zeros in x
        for &(n, m) in
            &[(1usize, 1usize), (3, 5), (4, 4), (5, 7), (7, 9), (8, 3), (6, 13), (2, 17)]
        {
            let x: Vec<f32> = (0..n)
                .map(|i| if i % 3 == 2 { 0.0 } else { (i as f32 * 0.7).sin() })
                .collect();
            let w: Vec<f32> = (0..n * m).map(|i| (i as f32 * 0.13).cos()).collect();
            let mut unrolled = vec![0.0f32; m];
            let mut reference = vec![0.0f32; m];
            vecmat(&x, &w, m, &mut unrolled);
            naive(&x, &w, m, &mut reference);
            assert_eq!(unrolled, reference, "n={n} m={m}");
        }
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        use crate::model::weights::generate_weights;
        let w = Arc::new(generate_weights(ModelConfig::TINY, 3));
        let be1 = NativeBackend::new(Arc::clone(&w));
        let be2 = NativeBackend::new(w);
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.3).sin()).collect();
        // be1 dirties its scratch with unrelated calls first; a fresh
        // backend must still produce identical results
        let mut kv_dirty = be1.new_kv().unwrap();
        let _ = be1.attn(1, &x, &mut kv_dirty, 0).unwrap();
        let _ = be1.spec_router(1, &x).unwrap();
        let mut kv1 = be1.new_kv().unwrap();
        let mut kv2 = be2.new_kv().unwrap();
        let a = be1.attn(0, &x, &mut kv1, 0).unwrap();
        let b = be2.attn(0, &x, &mut kv2, 0).unwrap();
        assert_eq!(a, b, "dirty scratch changed attention output");
        assert_eq!(
            be1.spec_router(1, &a).unwrap(),
            be2.router(1, &b).unwrap().1,
            "spec_router diverged from router probs"
        );
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = [3.0f32, 4.0];
        let w = [1.0f32, 1.0];
        let mut out = [0.0f32; 2];
        rmsnorm(&x, &w, 0.0, &mut out);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-6);
        assert!((out[1] - 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn softmax_normalizes() {
        let mut xs = [1.0f32, 2.0, 3.0];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let mut v = [0.1f32, 0.2, 0.3, 0.4];
        let orig = v;
        rope_inplace(&mut v, 0, 10000.0);
        assert_eq!(v, orig);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut v = [0.5f32, -0.3, 0.8, 0.1];
        let n0: f32 = v.iter().map(|x| x * x).sum();
        rope_inplace(&mut v, 17, 10000.0);
        let n1: f32 = v.iter().map(|x| x * x).sum();
        assert!((n0 - n1).abs() < 1e-5);
    }

    #[test]
    fn expert_ffn_multi_matches_single_bitwise() {
        // ragged hidden values either side of the unroll boundary, with a
        // dirty (oversized, garbage-filled) scratch pair — each batched row
        // must equal its solo expert_ffn_into run bit for bit
        let (hsz, f) = (6usize, 10usize);
        let w1: Vec<f32> = (0..hsz * f).map(|i| (i as f32 * 0.11).sin()).collect();
        let w3: Vec<f32> = (0..hsz * f).map(|i| (i as f32 * 0.07).cos()).collect();
        let w2: Vec<f32> = (0..f * hsz).map(|i| (i as f32 * 0.05).sin()).collect();
        let rows: Vec<Vec<f32>> = (0..3)
            .map(|r| (0..hsz).map(|i| ((r * 7 + i) as f32 * 0.31).sin()).collect())
            .collect();
        let hs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut a = vec![9.9f32; f + 5];
        let mut u = vec![-9.9f32; f + 5];
        let mut outs = vec![vec![0.0f32; hsz]; rows.len()];
        expert_ffn_multi_into(&hs, &w1, &w3, &w2, f, &mut a, &mut u, &mut outs);
        for (row, batched) in rows.iter().zip(&outs) {
            let mut solo = vec![0.0f32; hsz];
            let (mut sa, mut su) = (Vec::new(), Vec::new());
            expert_ffn_into(row, &w1, &w3, &w2, f, &mut sa, &mut su, &mut solo);
            assert_eq!(batched, &solo);
        }
    }

    #[test]
    fn backend_expert_multi_matches_expert() {
        use crate::model::weights::generate_weights;
        let w = Arc::new(generate_weights(ModelConfig::TINY, 7));
        let be = NativeBackend::new(w);
        let (w1, w3, w2) = (
            be.weights().expert(0, 0, "w1").unwrap().to_vec(),
            be.weights().expert(0, 0, "w3").unwrap().to_vec(),
            be.weights().expert(0, 0, "w2").unwrap().to_vec(),
        );
        let handle = be.upload_expert(w1, w3, w2).unwrap();
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|r| {
                (0..be.config().hidden_size)
                    .map(|i| ((r * 5 + i) as f32 * 0.17).sin())
                    .collect()
            })
            .collect();
        let hs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let outs = be.expert_multi(0, 0, &[1, 2, 3, 4], &hs, &handle).unwrap();
        for (row, batched) in rows.iter().zip(&outs) {
            assert_eq!(batched, &be.expert(row, &handle).unwrap());
        }
    }

    #[test]
    fn expert_ffn_zero_input_zero_output() {
        let h = vec![0.0f32; 4];
        let w = vec![0.5f32; 4 * 8];
        let w2 = vec![0.5f32; 8 * 4];
        let mut out = vec![1.0f32; 4];
        expert_ffn(&h, &w, &w, &w2, 8, &mut out);
        assert_eq!(out, vec![0.0; 4]);
    }
}
