//! PJRT-backed runtime: loads the HLO-text artifacts, compiles them once,
//! and executes them on the request path. This is the production backend.
//!
//! Buffer discipline (the offloading semantics live here):
//!   * **Static weights** (embeddings, attention, norms, gates, LM head)
//!     are staged as DEVICE BUFFERS once at startup and every stage runs
//!     via `execute_b` — in the paper's terms these are the always-resident
//!     "shared attention layers". (Perf: re-uploading them per call cost
//!     ~1.3 MB/layer/token on the CPU plugin; see EXPERIMENTS.md §Perf.)
//!   * **Expert weights** are NOT held here. They live quantized in the
//!     host store (`offload::store`); a transfer dequantizes and uploads
//!     them as device buffers (`upload_expert` -> [`ExpertHandle::Device`]),
//!     so cache hits reuse resident buffers with no host->device traffic —
//!     the exact mechanism the paper's GPU cache implements over PCIe.
//!   * **KV caches** round-trip via host f32 slices per layer step: stage
//!     outputs arrive as ONE tuple buffer (PJRT `untuple_result` is off in
//!     the c-wrapper), so the k/v updates must be downloaded anyway; they
//!     are re-uploaded with `buffer_from_host_buffer`, which copies during
//!     the call — the crate's `buffer_from_host_literal` does NOT await the
//!     async transfer and racing it segfaults (found the hard way; see
//!     EXPERIMENTS.md §Perf).

use super::xla::{self, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};
use super::{artifacts::Artifacts, Backend, ExpertHandle, KvState};
use crate::model::{ModelConfig, Weights};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

struct LayerBufs {
    ln1: PjRtBuffer,
    wq: PjRtBuffer,
    wk: PjRtBuffer,
    wv: PjRtBuffer,
    wo: PjRtBuffer,
    ln2: PjRtBuffer,
    gate: PjRtBuffer,
}

pub struct PjrtBackend {
    cfg: ModelConfig,
    client: PjRtClient,
    exes: HashMap<&'static str, PjRtLoadedExecutable>,
    embed_table: PjRtBuffer,
    layers: Vec<LayerBufs>,
    final_ln: PjRtBuffer,
    lm_head: PjRtBuffer,
}

impl PjrtBackend {
    /// Compile all stages and stage the static weights on-device.
    pub fn new(artifacts: &Artifacts, weights: &Weights) -> Result<PjrtBackend> {
        if weights.config != artifacts.config {
            bail!(
                "weights config {:?} != manifest config {:?}",
                weights.config,
                artifacts.config
            );
        }
        let cfg = artifacts.config;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;

        let mut exes = HashMap::new();
        for name in ["embed", "attn", "router", "expert", "final"] {
            let meta = artifacts.stage(name)?;
            let path = meta
                .file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {:?}", meta.file))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text for stage {name}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling stage {name}"))?;
            exes.insert(name, exe);
        }

        let (h, v, e) = (cfg.hidden_size, cfg.vocab_size, cfg.n_experts);
        let buf2 = |data: &[f32], d0: usize, d1: usize| -> Result<PjRtBuffer> {
            Ok(client.buffer_from_host_buffer(data, &[d0, d1], None)?)
        };
        let buf1 = |data: &[f32]| -> Result<PjRtBuffer> {
            Ok(client.buffer_from_host_buffer(data, &[data.len()], None)?)
        };
        let embed_table = buf2(weights.get("embed.table")?, v, h)?;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            layers.push(LayerBufs {
                ln1: buf1(weights.layer(l, "ln1")?)?,
                wq: buf2(weights.layer(l, "wq")?, h, h)?,
                wk: buf2(weights.layer(l, "wk")?, h, h)?,
                wv: buf2(weights.layer(l, "wv")?, h, h)?,
                wo: buf2(weights.layer(l, "wo")?, h, h)?,
                ln2: buf1(weights.layer(l, "ln2")?)?,
                gate: buf2(weights.layer(l, "gate")?, h, e)?,
            });
        }
        let final_ln = buf1(weights.get("final.ln")?)?;
        let lm_head = buf2(weights.get("final.lm_head")?, h, v)?;

        Ok(PjrtBackend { cfg, client, exes, embed_table, layers, final_ln, lm_head })
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    fn exe(&self, name: &str) -> &PjRtLoadedExecutable {
        &self.exes[name]
    }

    /// Run a stage on device buffers and decompose the tuple result.
    fn run_b(&self, name: &str, args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        let out = self
            .exe(name)
            .execute_b::<&PjRtBuffer>(args)
            .with_context(|| format!("executing stage {name}"))?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    fn x_buf(&self, x: &[f32]) -> Result<PjRtBuffer> {
        Ok(self
            .client
            .buffer_from_host_buffer(x, &[1, self.cfg.hidden_size], None)?)
    }
}

impl Backend for PjrtBackend {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn new_kv(&self) -> Result<KvState> {
        Ok(KvState::zeros(&self.cfg))
    }

    fn embed(&self, tok: u32) -> Result<Vec<f32>> {
        if tok as usize >= self.cfg.vocab_size {
            bail!("token {tok} out of vocab");
        }
        let tok_buf = self
            .client
            .buffer_from_host_buffer(&[tok as i32], &[1], None)?;
        let outs = self.run_b("embed", &[&tok_buf, &self.embed_table])?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    fn attn(&self, layer: usize, x: &[f32], kv: &mut KvState, pos: usize) -> Result<Vec<f32>> {
        if pos >= self.cfg.max_seq {
            bail!("pos {pos} >= max_seq {}", self.cfg.max_seq);
        }
        let (s, nh, hd) = (self.cfg.max_seq, self.cfg.n_heads, self.cfg.head_dim());
        let lw = &self.layers[layer];
        let x_buf = self.x_buf(x)?;
        // scalar i32: rank-0 buffer (buffer_from_host_buffer copies during
        // the call — buffer_from_host_literal would race the async upload)
        let pos_buf = self.client.buffer_from_host_buffer(&[pos as i32], &[], None)?;
        let (kc, vc) = &kv.0[layer];
        let kc_buf = self.client.buffer_from_host_buffer(kc, &[s, nh, hd], None)?;
        let vc_buf = self.client.buffer_from_host_buffer(vc, &[s, nh, hd], None)?;
        let mut outs = self.run_b(
            "attn",
            &[&x_buf, &lw.ln1, &lw.wq, &lw.wk, &lw.wv, &lw.wo, &kc_buf, &vc_buf, &pos_buf],
        )?;
        if outs.len() != 3 {
            bail!("attn returned {} outputs", outs.len());
        }
        let vc_new = outs.pop().unwrap().to_vec::<f32>()?;
        let kc_new = outs.pop().unwrap().to_vec::<f32>()?;
        let x_res = outs.pop().unwrap().to_vec::<f32>()?;
        kv.0[layer] = (kc_new, vc_new);
        Ok(x_res)
    }

    fn router(&self, layer: usize, x_res: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let lw = &self.layers[layer];
        let x_buf = self.x_buf(x_res)?;
        let outs = self.run_b("router", &[&x_buf, &lw.ln2, &lw.gate])?;
        if outs.len() != 2 {
            bail!("router returned {} outputs", outs.len());
        }
        Ok((outs[0].to_vec::<f32>()?, outs[1].to_vec::<f32>()?))
    }

    fn spec_router(&self, layer: usize, x_res: &[f32]) -> Result<Vec<f32>> {
        Ok(self.router(layer, x_res)?.1)
    }

    fn expert(&self, h: &[f32], handle: &ExpertHandle) -> Result<Vec<f32>> {
        let ExpertHandle::Device { w1, w3, w2 } = handle else {
            bail!("pjrt backend got a host handle");
        };
        // x is uploaded per call (tiny); the weight buffers are the cached
        // device-resident experts — a hit costs no host->device transfer.
        let x_buf = self.x_buf(h)?;
        let outs = self.run_b("expert", &[&x_buf, w1, w3, w2])?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    fn upload_expert(&self, w1: Vec<f32>, w3: Vec<f32>, w2: Vec<f32>) -> Result<ExpertHandle> {
        let (h, f) = (self.cfg.hidden_size, self.cfg.ffn_size);
        Ok(ExpertHandle::Device {
            w1: self.client.buffer_from_host_buffer(&w1, &[h, f], None)?,
            w3: self.client.buffer_from_host_buffer(&w3, &[h, f], None)?,
            w2: self.client.buffer_from_host_buffer(&w2, &[f, h], None)?,
        })
    }

    fn final_logits(&self, x: &[f32]) -> Result<Vec<f32>> {
        let x_buf = self.x_buf(x)?;
        let outs = self.run_b("final", &[&x_buf, &self.final_ln, &self.lm_head])?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
