//! AOT artifact loading: manifest.json + HLO-text stages + golden vectors.

use crate::model::ModelConfig;
use crate::util::json::{self, Value};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct StageMeta {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `artifacts/` directory.
pub struct Artifacts {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub stages: HashMap<String, StageMeta>,
    pub weights_path: PathBuf,
    pub testvec_path: Option<PathBuf>,
}

const REQUIRED_STAGES: [&str; 5] = ["embed", "attn", "router", "expert", "final"];

impl Artifacts {
    pub fn load(dir: &Path) -> Result<Artifacts> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {mpath:?} — run `make artifacts` first"))?;
        let m = json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let config = ModelConfig::from_json(m.get("config"))?;

        let mut stages = HashMap::new();
        for s in m.get("stages").as_arr().unwrap_or(&[]) {
            let name = s.get("name").as_str().unwrap_or_default().to_string();
            let file = dir.join(s.get("file").as_str().unwrap_or_default());
            if !file.is_file() {
                bail!("stage {name}: missing artifact {file:?}");
            }
            let parse_specs = |v: &Value| -> Vec<TensorSpec> {
                v.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|t| TensorSpec {
                        shape: t.get("shape").as_usize_vec().unwrap_or_default(),
                        dtype: t.get("dtype").as_str().unwrap_or("float32").to_string(),
                    })
                    .collect()
            };
            stages.insert(
                name.clone(),
                StageMeta {
                    name,
                    file,
                    inputs: parse_specs(s.get("inputs")),
                    outputs: parse_specs(s.get("outputs")),
                },
            );
        }
        for req in REQUIRED_STAGES {
            if !stages.contains_key(req) {
                bail!("manifest missing required stage {req:?}");
            }
        }

        let weights_path = dir.join(m.get("weights").as_str().unwrap_or("weights.bin"));
        if !weights_path.is_file() {
            bail!("missing weights file {weights_path:?}");
        }
        let testvec_path = m
            .get("testvec")
            .as_str()
            .map(|t| dir.join(t))
            .filter(|p| p.is_file());

        Ok(Artifacts { dir: dir.to_path_buf(), config, stages, weights_path, testvec_path })
    }

    pub fn stage(&self, name: &str) -> Result<&StageMeta> {
        self.stages
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no stage {name:?}"))
    }

    /// Directory for the tiered expert store's spill file
    /// (`--host-cache-mb`): co-located with the artifacts so the quantized
    /// spill lives next to the weights it was derived from, on the same
    /// filesystem budget. The store unlinks the file after opening (unix),
    /// so nothing persists past the process.
    pub fn expert_spill_dir(&self) -> PathBuf {
        self.dir.clone()
    }

    pub fn load_testvec(&self) -> Result<Value> {
        let p = self
            .testvec_path
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no testvec in artifacts"))?;
        let text = std::fs::read_to_string(p)?;
        json::parse(&text).map_err(|e| anyhow::anyhow!("testvec: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_fake_artifacts(dir: &Path) {
        let mk = |name: &str| {
            let mut f = std::fs::File::create(dir.join(format!("{name}.hlo.txt"))).unwrap();
            writeln!(f, "HloModule {name}\nENTRY main {{}}").unwrap();
        };
        for s in REQUIRED_STAGES {
            mk(s);
        }
        std::fs::write(dir.join("weights.bin"), b"MOEW").unwrap();
        let stages: Vec<String> = REQUIRED_STAGES
            .iter()
            .map(|s| {
                format!(
                    r#"{{"name":"{s}","file":"{s}.hlo.txt","inputs":[{{"shape":[1,32],"dtype":"float32"}}],"outputs":[{{"shape":[1,32],"dtype":"float32"}}]}}"#
                )
            })
            .collect();
        let manifest = format!(
            r#"{{"version":1,"config":{{"vocab_size":64,"hidden_size":32,"n_layers":2,"n_heads":4,"n_experts":8,"top_k":2,"ffn_size":64,"max_seq":16}},"stages":[{}],"weights":"weights.bin","testvec":null}}"#,
            stages.join(",")
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    #[test]
    fn loads_valid_dir() {
        let dir = std::env::temp_dir().join(format!("art-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_fake_artifacts(&dir);
        let a = Artifacts::load(&dir).unwrap();
        assert_eq!(a.config, ModelConfig::TINY);
        assert_eq!(a.stage("router").unwrap().inputs.len(), 1);
        assert!(a.testvec_path.is_none());
        assert_eq!(a.expert_spill_dir(), dir);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_friendly() {
        match Artifacts::load(Path::new("/nonexistent-artifacts")) {
            Ok(_) => panic!("expected failure"),
            Err(err) => assert!(format!("{err:#}").contains("make artifacts")),
        }
    }
}
