//! CSV export of traces (for external plotting of the paper's figures).

use super::Trace;
use anyhow::Result;
use std::io::Write;
use std::path::Path;

/// One row per (token, layer, expert) with activation/cache/spec flags.
pub fn trace_csv(trace: &Trace) -> String {
    let mut out = String::from("token,layer,expert,activated,weight,cached,spec_guessed\n");
    for t in 0..trace.n_tokens() {
        for l in 0..trace.n_layers {
            let rec = trace.at(t, l);
            for e in 0..trace.n_experts {
                let act_pos = rec.activated.iter().position(|&a| a == e);
                let weight = act_pos.map(|i| rec.weights.get(i).copied().unwrap_or(0.0));
                out.push_str(&format!(
                    "{t},{l},{e},{},{},{},{}\n",
                    act_pos.is_some() as u8,
                    weight.map_or(String::from(""), |w| format!("{w:.4}")),
                    rec.cached_before.contains(&e) as u8,
                    rec.spec_guess.as_ref().is_some_and(|g| g.contains(&e)) as u8,
                ));
            }
        }
    }
    out
}

/// Per-layer histogram CSV (paper Fig 7).
pub fn histogram_csv(trace: &Trace) -> String {
    let mut out = String::from("layer,expert,count\n");
    for l in 0..trace.n_layers {
        for (e, c) in trace.layer_histogram(l).iter().enumerate() {
            out.push_str(&format!("{l},{e},{c}\n"));
        }
    }
    out
}

pub fn write_file(path: &Path, content: &str) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(content.as_bytes())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Trace;

    #[test]
    fn csv_has_all_cells() {
        let mut t = Trace::new(2, 4, 2);
        t.push_token(0);
        t.push_token(1);
        t.at_mut(0, 0).activated = vec![1, 2];
        t.at_mut(0, 0).weights = vec![0.7, 0.3];
        let csv = trace_csv(&t);
        // header + 2 tokens * 2 layers * 4 experts
        assert_eq!(csv.lines().count(), 1 + 16);
        assert!(csv.contains("0,0,1,1,0.7000,0,0"));
    }

    #[test]
    fn histogram_csv_shape() {
        let mut t = Trace::new(3, 2, 1);
        t.push_token(0);
        t.at_mut(0, 2).activated = vec![1];
        let csv = histogram_csv(&t);
        assert_eq!(csv.lines().count(), 1 + 6);
        assert!(csv.ends_with("2,1,1\n"));
    }
}
