//! CSV export/import of traces (for external plotting of the paper's
//! figures, and for feeding recorded activations back into the
//! `train-predictor` subcommand).

use super::Trace;
use anyhow::{bail, Result};
use std::io::Write;
use std::path::Path;

const HEADER: &str = "token,layer,expert,activated,weight,cached,spec_guessed";

/// One row per (token, layer, expert) with activation/cache/spec flags.
/// Sequence boundaries are emitted as `#boundary,<token>` directive lines
/// right after the header so a round-trip through [`parse_trace_csv`]
/// preserves them.
pub fn trace_csv(trace: &Trace) -> String {
    let mut out = String::from(HEADER);
    out.push('\n');
    for &b in &trace.seq_breaks {
        out.push_str(&format!("#boundary,{b}\n"));
    }
    for t in 0..trace.n_tokens() {
        for l in 0..trace.n_layers {
            let rec = trace.at(t, l);
            for e in 0..trace.n_experts {
                let act_pos = rec.activated.iter().position(|&a| a == e);
                let weight = act_pos.map(|i| rec.weights.get(i).copied().unwrap_or(0.0));
                out.push_str(&format!(
                    "{t},{l},{e},{},{},{},{}\n",
                    act_pos.is_some() as u8,
                    weight.map_or(String::from(""), |w| format!("{w:.4}")),
                    rec.cached_before.contains(&e) as u8,
                    rec.spec_guess.as_ref().is_some_and(|g| g.contains(&e)) as u8,
                ));
            }
        }
    }
    out
}

/// Per-layer histogram CSV (paper Fig 7).
pub fn histogram_csv(trace: &Trace) -> String {
    let mut out = String::from("layer,expert,count\n");
    for l in 0..trace.n_layers {
        for (e, c) in trace.layer_histogram(l).iter().enumerate() {
            out.push_str(&format!("{l},{e},{c}\n"));
        }
    }
    out
}

pub fn write_file(path: &Path, content: &str) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(content.as_bytes())?;
    Ok(())
}

/// Parse a CSV produced by [`trace_csv`] (or an external exporter using the
/// same schema) back into a [`Trace`].
///
/// Structural problems — a wrong header, a short row, an unparsable number,
/// out-of-order rows — are real errors, not panics: this is the entry point
/// for user-supplied trace files (`train-predictor --trace <csv>`).
/// Dimensions are inferred from the data (every expert cell is present in
/// the export format, so the max indices are exact); activated lists come
/// back sorted by expert id with their weights kept parallel.
pub fn parse_trace_csv(input: &str) -> Result<Trace> {
    let mut lines = input.lines().enumerate();
    match lines.next() {
        Some((_, h)) if h.trim() == HEADER => {}
        Some((_, h)) => bail!("trace csv: expected header {HEADER:?}, got {h:?}"),
        None => bail!("trace csv: empty input"),
    }
    // (token, layer, expert, activated, weight, cached, spec)
    type Row = (usize, usize, usize, bool, f32, bool, bool);
    let mut boundaries: Vec<usize> = Vec::new();
    let mut rows: Vec<Row> = Vec::new();
    let mut n_layers = 0usize;
    let mut n_experts = 0usize;
    for (idx, line) in lines {
        let lineno = idx + 1;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            if let Some(b) = rest.strip_prefix("boundary,") {
                let b: usize = b
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("trace csv line {lineno}: bad boundary {b:?}"))?;
                boundaries.push(b);
            }
            continue; // unknown directives / comments are skipped
        }
        let cols: Vec<&str> = line.split(',').collect();
        if cols.len() != 7 {
            bail!("trace csv line {lineno}: expected 7 columns, got {}", cols.len());
        }
        let num = |i: usize| -> Result<usize> {
            cols[i]
                .parse()
                .map_err(|_| anyhow::anyhow!("trace csv line {lineno}: bad field {:?}", cols[i]))
        };
        let flag = |i: usize| -> Result<bool> {
            match cols[i] {
                "0" => Ok(false),
                "1" => Ok(true),
                other => bail!("trace csv line {lineno}: expected 0/1, got {other:?}"),
            }
        };
        let (t, l, e) = (num(0)?, num(1)?, num(2)?);
        let activated = flag(3)?;
        let weight = if cols[4].is_empty() {
            0.0
        } else {
            cols[4]
                .parse::<f32>()
                .map_err(|_| anyhow::anyhow!("trace csv line {lineno}: bad weight {:?}", cols[4]))?
        };
        n_layers = n_layers.max(l + 1);
        n_experts = n_experts.max(e + 1);
        rows.push((t, l, e, activated, weight, flag(5)?, flag(6)?));
    }
    if rows.is_empty() {
        bail!("trace csv: no data rows");
    }
    let n_tokens = rows.iter().map(|r| r.0 + 1).max().unwrap_or(0);
    if rows.len() != n_tokens * n_layers * n_experts {
        bail!(
            "trace csv: {} rows but dimensions {n_tokens}x{n_layers}x{n_experts} need {}",
            rows.len(),
            n_tokens * n_layers * n_experts
        );
    }
    let mut top_k = 0usize;
    let mut trace = Trace::new(n_layers, n_experts, 0);
    for t in 0..n_tokens {
        trace.push_token(t as u32);
    }
    for (i, &(t, l, e, activated, weight, cached, spec)) in rows.iter().enumerate() {
        let expect = (
            i / (n_layers * n_experts),
            (i / n_experts) % n_layers,
            i % n_experts,
        );
        if (t, l, e) != expect {
            bail!("trace csv: row {} out of order: got ({t},{l},{e}), expected {expect:?}", i + 1);
        }
        let rec = trace.at_mut(t, l);
        if activated {
            rec.activated.push(e);
            rec.weights.push(weight);
            top_k = top_k.max(rec.activated.len());
        }
        if cached {
            rec.cached_before.push(e);
        }
        if spec {
            match &mut rec.spec_guess {
                Some(g) => g.push(e),
                None => rec.spec_guess = Some(vec![e]),
            }
        }
    }
    trace.top_k = top_k;
    boundaries.sort_unstable();
    boundaries.dedup();
    if let Some(&b) = boundaries.last() {
        if b >= n_tokens {
            bail!("trace csv: boundary {b} out of range (trace has {n_tokens} tokens)");
        }
    }
    trace.seq_breaks = boundaries.into_iter().filter(|&b| b > 0).collect();
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Trace;

    #[test]
    fn csv_has_all_cells() {
        let mut t = Trace::new(2, 4, 2);
        t.push_token(0);
        t.push_token(1);
        t.at_mut(0, 0).activated = vec![1, 2];
        t.at_mut(0, 0).weights = vec![0.7, 0.3];
        let csv = trace_csv(&t);
        // header + 2 tokens * 2 layers * 4 experts
        assert_eq!(csv.lines().count(), 1 + 16);
        assert!(csv.contains("0,0,1,1,0.7000,0,0"));
    }

    #[test]
    fn histogram_csv_shape() {
        let mut t = Trace::new(3, 2, 1);
        t.push_token(0);
        t.at_mut(0, 2).activated = vec![1];
        let csv = histogram_csv(&t);
        assert_eq!(csv.lines().count(), 1 + 6);
        assert!(csv.ends_with("2,1,1\n"));
    }

    #[test]
    fn csv_round_trips_records_and_boundaries() {
        let mut t = Trace::new(2, 4, 2);
        t.push_token(7);
        t.at_mut(0, 0).activated = vec![1, 2];
        t.at_mut(0, 0).weights = vec![0.75, 0.25];
        t.at_mut(0, 1).activated = vec![0, 3];
        t.at_mut(0, 1).weights = vec![0.5, 0.5];
        t.at_mut(0, 1).cached_before = vec![0];
        t.at_mut(0, 1).spec_guess = Some(vec![0, 1]);
        t.mark_sequence_boundary();
        t.push_token(8);
        t.at_mut(1, 0).activated = vec![2, 3];
        t.at_mut(1, 0).weights = vec![0.9, 0.1];
        t.at_mut(1, 1).activated = vec![0, 1];
        t.at_mut(1, 1).weights = vec![0.6, 0.4];
        let parsed = parse_trace_csv(&trace_csv(&t)).unwrap();
        assert_eq!(parsed.n_layers, 2);
        assert_eq!(parsed.n_experts, 4);
        assert_eq!(parsed.top_k, 2);
        assert_eq!(parsed.n_tokens(), 2);
        assert_eq!(parsed.seq_breaks, vec![1]);
        assert_eq!(parsed.at(0, 0).activated, vec![1, 2]);
        assert_eq!(parsed.at(0, 1).cached_before, vec![0]);
        assert_eq!(parsed.at(0, 1).spec_guess, Some(vec![0, 1]));
        assert_eq!(parsed.at(1, 0).activated, vec![2, 3]);
        // weights survive at export precision
        assert!((parsed.at(1, 0).weights[0] - 0.9).abs() < 1e-4);
    }

    #[test]
    fn csv_parse_rejects_garbage() {
        assert!(parse_trace_csv("").is_err());
        assert!(parse_trace_csv("not,the,header\n").is_err());
        let hdr = "token,layer,expert,activated,weight,cached,spec_guessed\n";
        assert!(parse_trace_csv(hdr).is_err()); // no data rows
        assert!(parse_trace_csv(&format!("{hdr}0,0,0,1,,0\n")).is_err()); // short row
        assert!(parse_trace_csv(&format!("{hdr}0,0,x,1,,0,0\n")).is_err()); // bad number
        assert!(parse_trace_csv(&format!("{hdr}0,0,0,2,,0,0\n")).is_err()); // bad flag
        let past_end = format!("{hdr}#boundary,5\n0,0,0,1,,0,0\n");
        assert!(parse_trace_csv(&past_end).is_err());
    }
}
