//! Tracing system — the paper's §3.1 contribution: record the entire
//! activation + caching history "at any layer, for any token, in any
//! prompt", then render and analyze it (Figures 1–14).

pub mod export;
pub mod render;

use crate::metrics::PrecisionRecall;

/// Everything observed at one (token, layer) step.
#[derive(Clone, Debug, Default)]
pub struct LayerTokenRecord {
    /// Experts selected by the router (top-k), with their renormalized
    /// gating weights (drives the blue depth in the paper's figures).
    pub activated: Vec<usize>,
    pub weights: Vec<f32>,
    /// Cache residents at the moment the lookups happened (the gray
    /// squares in the paper's figures).
    pub cached_before: Vec<usize>,
    /// Speculative guess made for this layer from the previous layer's
    /// hidden states (None at layer 0 — impossible to guess, paper §5.4).
    pub spec_guess: Option<Vec<usize>>,
}

/// Full decode history: `records[token][layer]`.
#[derive(Clone, Debug)]
pub struct Trace {
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub records: Vec<Vec<LayerTokenRecord>>,
    /// Token ids, parallel to `records` (for labeling figures).
    pub tokens: Vec<u32>,
    /// Token indices at which a new independent sequence begins (sorted,
    /// deduplicated). Token 0 is always an implicit sequence start.
    /// Predictor evaluation resets its context at these points so
    /// transition history never bleeds across unrelated prompts.
    pub seq_breaks: Vec<usize>,
}

impl Trace {
    pub fn new(n_layers: usize, n_experts: usize, top_k: usize) -> Self {
        Trace {
            n_layers,
            n_experts,
            top_k,
            records: Vec::new(),
            tokens: Vec::new(),
            seq_breaks: Vec::new(),
        }
    }

    pub fn n_tokens(&self) -> usize {
        self.records.len()
    }

    /// Begin a new token's records (one per layer, filled by the engine).
    pub fn push_token(&mut self, tok: u32) {
        self.tokens.push(tok);
        self.records
            .push((0..self.n_layers).map(|_| LayerTokenRecord::default()).collect());
    }

    /// Mark that the NEXT pushed token starts a new independent sequence.
    pub fn mark_sequence_boundary(&mut self) {
        let at = self.records.len();
        if at > 0 && self.seq_breaks.last() != Some(&at) {
            self.seq_breaks.push(at);
        }
    }

    /// Does token `t` begin a new sequence? (Token 0 always does.)
    pub fn is_sequence_start(&self, t: usize) -> bool {
        t == 0 || self.seq_breaks.binary_search(&t).is_ok()
    }

    /// Split at token `t`: `self` keeps `[0, t)`, the returned trace gets
    /// `[t, end)` rebased to token 0 (implicitly a sequence start).
    /// Train/eval splits for the learned predictor ride on this.
    pub fn split_off(&mut self, t: usize) -> Trace {
        let records = self.records.split_off(t);
        let tokens = self.tokens.split_off(t);
        let seq_breaks = self.seq_breaks.iter().filter(|&&b| b > t).map(|&b| b - t).collect();
        self.seq_breaks.retain(|&b| b < t);
        Trace {
            n_layers: self.n_layers,
            n_experts: self.n_experts,
            top_k: self.top_k,
            records,
            tokens,
            seq_breaks,
        }
    }

    pub fn at_mut(&mut self, token: usize, layer: usize) -> &mut LayerTokenRecord {
        &mut self.records[token][layer]
    }
    pub fn at(&self, token: usize, layer: usize) -> &LayerTokenRecord {
        &self.records[token][layer]
    }

    /// Per-layer activation sequences (token -> activated experts), the
    /// input format for trace replay and Belady.
    pub fn layer_activations(&self, layer: usize) -> Vec<Vec<usize>> {
        self.records.iter().map(|t| t[layer].activated.clone()).collect()
    }

    /// Cache precision/recall over the whole trace (paper §4.2).
    pub fn cache_precision_recall(&self) -> PrecisionRecall {
        let mut pr = PrecisionRecall::default();
        for tok in &self.records {
            for rec in tok {
                pr.record(&rec.cached_before, &rec.activated);
            }
        }
        pr
    }

    /// Speculative precision/recall (paper §5.4) — layer 0 is excluded
    /// exactly as the paper does ("not possible to guess for the first
    /// layer").
    pub fn spec_precision_recall(&self) -> PrecisionRecall {
        let mut pr = PrecisionRecall::default();
        for tok in &self.records {
            for rec in tok {
                if let Some(guess) = &rec.spec_guess {
                    pr.record(guess, &rec.activated);
                }
            }
        }
        pr
    }

    /// Histogram of expert activations at `layer` (paper Figure 7).
    pub fn layer_histogram(&self, layer: usize) -> Vec<u64> {
        let mut h = vec![0u64; self.n_experts];
        for tok in &self.records {
            for &e in &tok[layer].activated {
                h[e] += 1;
            }
        }
        h
    }

    /// Temporal locality: P(expert activated for token t was also activated
    /// for token t-1), the Mixtral-paper statistic (§3.1); random = k/E.
    pub fn temporal_locality(&self) -> f64 {
        let mut same = 0u64;
        let mut total = 0u64;
        for t in 1..self.records.len() {
            for l in 0..self.n_layers {
                let prev = &self.records[t - 1][l].activated;
                for &e in &self.records[t][l].activated {
                    total += 1;
                    if prev.contains(&e) {
                        same += 1;
                    }
                }
            }
        }
        if total == 0 {
            return 0.0;
        }
        same as f64 / total as f64
    }

    /// Coefficient of variation of the per-expert activation counts at a
    /// layer — the imbalance measure behind paper §5.2.
    pub fn layer_imbalance(&self, layer: usize) -> f64 {
        let h = self.layer_histogram(layer);
        let n = h.len() as f64;
        let mean = h.iter().sum::<u64>() as f64 / n;
        if mean == 0.0 {
            return 0.0;
        }
        let var = h.iter().map(|&c| (c as f64 - mean).powi(2)).sum::<f64>() / n;
        var.sqrt() / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        let mut t = Trace::new(2, 4, 2);
        // token 0: layer0 {0,1} cached {0,2}; layer1 {2,3} cached {2,3}
        t.push_token(10);
        t.at_mut(0, 0).activated = vec![0, 1];
        t.at_mut(0, 0).weights = vec![0.6, 0.4];
        t.at_mut(0, 0).cached_before = vec![0, 2];
        t.at_mut(0, 1).activated = vec![2, 3];
        t.at_mut(0, 1).cached_before = vec![2, 3];
        // token 1: layer0 {0,1} again; layer1 {0,1}, spec guess {0,2}
        t.push_token(11);
        t.at_mut(1, 0).activated = vec![0, 1];
        t.at_mut(1, 0).cached_before = vec![0, 1];
        t.at_mut(1, 1).activated = vec![0, 1];
        t.at_mut(1, 1).cached_before = vec![2, 3];
        t.at_mut(1, 1).spec_guess = Some(vec![0, 2]);
        t
    }

    #[test]
    fn cache_pr() {
        let t = sample_trace();
        let pr = t.cache_precision_recall();
        // events: (c{0,2},a{0,1}): tp1 fp1 fn1; (c{2,3},a{2,3}): tp2;
        // (c{0,1},a{0,1}): tp2; (c{2,3},a{0,1}): fp2 fn2
        assert_eq!(pr.tp, 5);
        assert_eq!(pr.fp, 3);
        assert_eq!(pr.fn_, 3);
    }

    #[test]
    fn spec_pr_excludes_unguessed() {
        let t = sample_trace();
        let pr = t.spec_precision_recall();
        assert_eq!(pr.tp, 1); // guessed {0,2}, activated {0,1}
        assert_eq!(pr.fp, 1);
        assert_eq!(pr.fn_, 1);
        assert_eq!(pr.precision(), pr.recall());
    }

    #[test]
    fn histogram_counts() {
        let t = sample_trace();
        assert_eq!(t.layer_histogram(0), vec![2, 2, 0, 0]);
        assert_eq!(t.layer_histogram(1), vec![1, 1, 1, 1]);
    }

    #[test]
    fn locality() {
        let t = sample_trace();
        // token1 layer0 {0,1} both repeat; layer1 {0,1} neither repeats
        assert_eq!(t.temporal_locality(), 0.5);
    }

    #[test]
    fn imbalance_zero_when_uniform() {
        let t = sample_trace();
        assert_eq!(t.layer_imbalance(1), 0.0);
        assert!(t.layer_imbalance(0) > 0.0);
    }

    #[test]
    fn sequence_boundaries_dedup_and_query() {
        let mut t = Trace::new(1, 4, 2);
        t.mark_sequence_boundary(); // before any token: implicit, not recorded
        t.push_token(1);
        t.mark_sequence_boundary();
        t.mark_sequence_boundary(); // duplicate collapses
        t.push_token(2);
        t.push_token(3);
        assert_eq!(t.seq_breaks, vec![1]);
        assert!(t.is_sequence_start(0));
        assert!(t.is_sequence_start(1));
        assert!(!t.is_sequence_start(2));
    }

    #[test]
    fn split_off_rebases_boundaries() {
        let mut t = Trace::new(1, 4, 2);
        for i in 0..6 {
            t.push_token(i);
            if i == 1 || i == 3 {
                t.mark_sequence_boundary();
            }
        }
        let tail = t.split_off(3);
        assert_eq!(t.n_tokens(), 3);
        assert_eq!(tail.n_tokens(), 3);
        assert_eq!(t.seq_breaks, vec![2]);
        assert_eq!(tail.seq_breaks, vec![1]); // old break at 4 rebased
        assert!(tail.is_sequence_start(0));
        assert_eq!(tail.tokens, vec![3, 4, 5]);
    }
}
