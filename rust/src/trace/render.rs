//! ASCII renderers for the paper's trace figures.
//!
//! Figures 1–6 & 8–12 (activation × cache grid, one layer): rows are
//! experts, columns are decoded tokens:
//!
//! ```text
//!   '#'  activated & cached   (hit)
//!   '*'  activated, not cached (miss — must transfer)
//!   'o'  cached, not activated (miscached)
//!   '.'  neither
//! ```
//!
//! Figures 13–14 (speculative loading, one token): rows are layers,
//! columns are experts: 'P' true positive (guessed & activated), 'F' false
//! positive, 'N' false negative, '.' neither. Layer 0 renders 'n' for its
//! unguessable activations (marked red-but-excluded in the paper).

use super::Trace;

/// Render one layer's activation/cache history grid.
pub fn layer_grid(trace: &Trace, layer: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "layer {layer}: rows=experts 0..{}, cols=tokens 0..{} ('#' hit, '*' miss, 'o' miscached)\n",
        trace.n_experts - 1,
        trace.n_tokens().saturating_sub(1)
    ));
    for e in 0..trace.n_experts {
        out.push_str(&format!("e{e} |"));
        for t in 0..trace.n_tokens() {
            let rec = trace.at(t, layer);
            let act = rec.activated.contains(&e);
            let cached = rec.cached_before.contains(&e);
            out.push(match (act, cached) {
                (true, true) => '#',
                (true, false) => '*',
                (false, true) => 'o',
                (false, false) => '.',
            });
        }
        out.push('\n');
    }
    out
}

/// Render the speculative-loading grid for one token (paper Fig 13/14).
pub fn spec_grid(trace: &Trace, token: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "token {token}: rows=layers, cols=experts ('P' TP, 'F' FP, 'N' FN, 'n' layer-0 unguessable)\n"
    ));
    for l in 0..trace.n_layers {
        out.push_str(&format!("L{l:02} |"));
        let rec = trace.at(token, l);
        for e in 0..trace.n_experts {
            let act = rec.activated.contains(&e);
            let guessed = rec.spec_guess.as_ref().is_some_and(|g| g.contains(&e));
            out.push(match (rec.spec_guess.is_some(), act, guessed) {
                (true, true, true) => 'P',
                (true, false, true) => 'F',
                (true, true, false) => 'N',
                (false, true, _) => 'n',
                _ => '.',
            });
        }
        out.push('\n');
    }
    out
}

/// Render a per-layer activation histogram (paper Fig 7), one bar row per
/// expert, scaled to `width` characters.
pub fn layer_histogram(trace: &Trace, layer: usize, width: usize) -> String {
    let h = trace.layer_histogram(layer);
    let max = h.iter().copied().max().unwrap_or(1).max(1);
    let mut out = format!("layer {layer} activation histogram (imbalance cv={:.2})\n", trace.layer_imbalance(layer));
    for (e, &c) in h.iter().enumerate() {
        let bar = "=".repeat((c as usize * width) / max as usize);
        out.push_str(&format!("e{e} |{bar:<width$}| {c}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Trace;

    fn t() -> Trace {
        let mut t = Trace::new(2, 3, 1);
        t.push_token(5);
        t.at_mut(0, 0).activated = vec![0];
        t.at_mut(0, 0).cached_before = vec![0, 1];
        t.at_mut(0, 1).activated = vec![2];
        t.at_mut(0, 1).spec_guess = Some(vec![1]);
        t
    }

    #[test]
    fn grid_symbols() {
        let g = layer_grid(&t(), 0);
        let lines: Vec<&str> = g.lines().collect();
        assert!(lines[1].ends_with('#')); // e0 activated+cached
        assert!(lines[2].ends_with('o')); // e1 cached only
        assert!(lines[3].ends_with('.')); // e2 neither
    }

    #[test]
    fn spec_symbols() {
        let g = spec_grid(&t(), 0);
        let lines: Vec<&str> = g.lines().collect();
        // layer0 has no guess -> activated renders 'n'
        assert!(lines[1].contains('n'));
        // layer1: guessed e1 (F), activated e2 (N)
        assert!(lines[2].contains('F'));
        assert!(lines[2].contains('N'));
    }

    #[test]
    fn histogram_renders_counts() {
        let g = layer_histogram(&t(), 0, 10);
        assert!(g.contains("e0"));
        assert!(g.lines().count() >= 4);
    }
}
