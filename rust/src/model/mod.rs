//! Model-side substrates: configuration, the MOEW weights reader, the
//! byte-level tokenizer, and the token sampler.

pub mod config;
pub mod sampler;
pub mod tokenizer;
pub mod weights;

pub use config::ModelConfig;
pub use weights::Weights;
