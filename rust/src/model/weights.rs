//! MOEW weights reader (format written by `python/compile/weights.py`).
//!
//! Layout (little-endian):
//! ```text
//! magic   b"MOEW"
//! version u32 = 1
//! hlen    u32
//! header  JSON {config, tensors: [{name, shape, offset, nbytes}], data_start}
//! data    raw f32 tensors, 64-byte aligned, offsets relative to data_start
//! ```

use crate::model::config::ModelConfig;
use crate::util::json;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct TensorInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// All model weights, resident in host memory ("main memory" in the paper's
/// offloading setup). Expert tensors are *additionally* re-encoded into the
/// quantized host store by `offload::store`; the f32 copies here back the
/// non-offloaded layers (attention, norms, embeddings) and the native oracle.
pub struct Weights {
    pub config: ModelConfig,
    data: Vec<f32>,
    index: HashMap<String, (usize, usize, Vec<usize>)>, // name -> (start, len, shape)
    pub tensors: Vec<TensorInfo>,
}

impl Weights {
    pub fn load(path: &Path) -> Result<Weights> {
        let blob = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Self::from_bytes(&blob)
    }

    pub fn from_bytes(blob: &[u8]) -> Result<Weights> {
        if blob.len() < 12 || &blob[..4] != b"MOEW" {
            bail!("bad MOEW magic");
        }
        let version = u32::from_le_bytes(blob[4..8].try_into()?);
        if version != 1 {
            bail!("unsupported MOEW version {version}");
        }
        let hlen = u32::from_le_bytes(blob[8..12].try_into()?) as usize;
        if blob.len() < 12 + hlen {
            bail!("truncated MOEW header");
        }
        let header = json::parse(std::str::from_utf8(&blob[12..12 + hlen])?)
            .map_err(|e| anyhow::anyhow!("MOEW header: {e}"))?;
        let config = ModelConfig::from_json(header.get("config"))?;
        let data_start = header
            .get("data_start")
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("missing data_start"))?;

        let tarr = header
            .get("tensors")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing tensors"))?;
        let mut tensors = Vec::with_capacity(tarr.len());
        let mut total_floats = 0usize;
        for t in tarr {
            let info = TensorInfo {
                name: t.get("name").as_str().unwrap_or_default().to_string(),
                shape: t.get("shape").as_usize_vec().unwrap_or_default(),
                offset: t.get("offset").as_usize().unwrap_or(0),
                nbytes: t.get("nbytes").as_usize().unwrap_or(0),
            };
            if info.name.is_empty() || info.nbytes % 4 != 0 {
                bail!("bad tensor entry {:?}", info.name);
            }
            let numel: usize = info.shape.iter().product();
            if numel * 4 != info.nbytes {
                bail!("{}: shape {:?} != nbytes {}", info.name, info.shape, info.nbytes);
            }
            if data_start + info.offset + info.nbytes > blob.len() {
                bail!("{}: extends past EOF", info.name);
            }
            total_floats += numel;
            tensors.push(info);
        }

        // Copy into one contiguous f32 arena, tensors back to back.
        let mut data = Vec::with_capacity(total_floats);
        let mut index = HashMap::with_capacity(tensors.len());
        for info in &tensors {
            let start = data.len();
            let bytes = &blob[data_start + info.offset..data_start + info.offset + info.nbytes];
            data.extend(bytes.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())));
            index.insert(info.name.clone(), (start, info.nbytes / 4, info.shape.clone()));
        }
        Ok(Weights { config, data, index, tensors })
    }

    /// Borrow a tensor by name as a flat f32 slice.
    pub fn get(&self, name: &str) -> Result<&[f32]> {
        let (start, len, _) = self
            .index
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no tensor named {name:?}"))?;
        Ok(&self.data[*start..*start + *len])
    }

    pub fn shape(&self, name: &str) -> Option<&[usize]> {
        self.index.get(name).map(|(_, _, s)| s.as_slice())
    }

    pub fn has(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    /// Convenience accessors for the fixed layout.
    pub fn layer(&self, l: usize, t: &str) -> Result<&[f32]> {
        self.get(&format!("layer.{l}.{t}"))
    }
    pub fn expert(&self, l: usize, e: usize, t: &str) -> Result<&[f32]> {
        self.get(&format!("layer.{l}.expert.{e}.{t}"))
    }

    pub fn n_params(&self) -> usize {
        self.data.len()
    }

    /// Verify the tensor set matches the config (paranoia at startup).
    pub fn validate_layout(&self) -> Result<()> {
        let c = &self.config;
        let expect = |name: String, shape: &[usize]| -> Result<()> {
            match self.shape(&name) {
                None => bail!("missing tensor {name}"),
                Some(s) if s != shape => bail!("{name}: shape {s:?}, want {shape:?}"),
                _ => Ok(()),
            }
        };
        expect("embed.table".into(), &[c.vocab_size, c.hidden_size])?;
        expect("final.ln".into(), &[c.hidden_size])?;
        expect("final.lm_head".into(), &[c.hidden_size, c.vocab_size])?;
        for l in 0..c.n_layers {
            expect(format!("layer.{l}.ln1"), &[c.hidden_size])?;
            expect(format!("layer.{l}.ln2"), &[c.hidden_size])?;
            for t in ["wq", "wk", "wv", "wo"] {
                expect(format!("layer.{l}.{t}"), &[c.hidden_size, c.hidden_size])?;
            }
            expect(format!("layer.{l}.gate"), &[c.hidden_size, c.n_experts])?;
            for e in 0..c.n_experts {
                expect(format!("layer.{l}.expert.{e}.w1"), &[c.hidden_size, c.ffn_size])?;
                expect(format!("layer.{l}.expert.{e}.w3"), &[c.hidden_size, c.ffn_size])?;
                expect(format!("layer.{l}.expert.{e}.w2"), &[c.ffn_size, c.hidden_size])?;
            }
        }
        Ok(())
    }
}

/// Canonical tensor-name list for a config, in file order.
pub fn tensor_names(cfg: &ModelConfig) -> Vec<(String, Vec<usize>)> {
    let mut names: Vec<(String, Vec<usize>)> =
        vec![("embed.table".into(), vec![cfg.vocab_size, cfg.hidden_size])];
    for l in 0..cfg.n_layers {
        for t in ["ln1", "ln2"] {
            names.push((format!("layer.{l}.{t}"), vec![cfg.hidden_size]));
        }
        for t in ["wq", "wk", "wv", "wo"] {
            names.push((format!("layer.{l}.{t}"), vec![cfg.hidden_size, cfg.hidden_size]));
        }
        names.push((format!("layer.{l}.gate"), vec![cfg.hidden_size, cfg.n_experts]));
        for e in 0..cfg.n_experts {
            names.push((format!("layer.{l}.expert.{e}.w1"), vec![cfg.hidden_size, cfg.ffn_size]));
            names.push((format!("layer.{l}.expert.{e}.w3"), vec![cfg.hidden_size, cfg.ffn_size]));
            names.push((format!("layer.{l}.expert.{e}.w2"), vec![cfg.ffn_size, cfg.hidden_size]));
        }
    }
    names.push(("final.ln".into(), vec![cfg.hidden_size]));
    names.push(("final.lm_head".into(), vec![cfg.hidden_size, cfg.vocab_size]));
    names
}

/// Build synthetic `Weights` directly in memory from a fill function —
/// used by tests, benches and examples that must run without artifacts.
pub fn synth_weights(cfg: ModelConfig, fill: impl Fn(&str, usize) -> f32) -> Weights {
    let names = tensor_names(&cfg);
    let mut data = Vec::new();
    let mut index = HashMap::new();
    let mut tensors = Vec::new();
    for (name, shape) in names {
        let numel: usize = shape.iter().product();
        let start = data.len();
        data.extend((0..numel).map(|i| fill(&name, i)));
        index.insert(name.clone(), (start, numel, shape.clone()));
        tensors.push(TensorInfo { name, shape, offset: start * 4, nbytes: numel * 4 });
    }
    Weights { config: cfg, data, index, tensors }
}

/// Seeded random synthetic weights (rust-side analogue of
/// `python/compile/weights.py::generate`, incl. ln weights = 1 and the
/// gate-column imbalance shaping; not bit-identical to the python RNG).
pub fn generate_weights(cfg: ModelConfig, seed: u64) -> Weights {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let names = tensor_names(&cfg);
    let mut data = Vec::new();
    let mut index = HashMap::new();
    let mut tensors = Vec::new();
    for (name, shape) in names {
        let numel: usize = shape.iter().product();
        let start = data.len();
        if name.ends_with("ln1") || name.ends_with("ln2") || name.ends_with("final.ln") {
            data.extend(std::iter::repeat(1.0f32).take(numel));
        } else if name.ends_with(".gate") {
            // imbalance shaping: per-expert column scales, skew peaking
            // mid-network (mirrors weights.py)
            let l: usize = name
                .split('.')
                .nth(1)
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            let depth = l as f64 / (cfg.n_layers.max(2) - 1) as f64;
            let alpha = 0.15 + 0.55 * (std::f64::consts::PI * depth).sin();
            let perm = rng.permutation(cfg.n_experts);
            let mut scales: Vec<f32> = perm
                .iter()
                .map(|&r| (1.0 / (r as f64 + 1.0)).powf(alpha) as f32)
                .collect();
            let mean: f32 = scales.iter().sum::<f32>() / scales.len() as f32;
            for s in scales.iter_mut() {
                *s /= mean;
            }
            for i in 0..numel {
                let e = i % cfg.n_experts;
                data.push((rng.normal() * 0.02) as f32 * scales[e]);
            }
        } else {
            data.extend((0..numel).map(|_| (rng.normal() * 0.02) as f32));
        }
        index.insert(name.clone(), (start, numel, shape.clone()));
        tensors.push(TensorInfo { name, shape, offset: start * 4, nbytes: numel * 4 });
    }
    Weights { config: cfg, data, index, tensors }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny MOEW blob in-memory (mirrors the python writer).
    pub fn synth_moew(cfg: ModelConfig, fill: impl Fn(&str, usize) -> f32) -> Vec<u8> {
        let mut names: Vec<(String, Vec<usize>)> = vec![
            ("embed.table".into(), vec![cfg.vocab_size, cfg.hidden_size]),
        ];
        for l in 0..cfg.n_layers {
            for t in ["ln1", "ln2"] {
                names.push((format!("layer.{l}.{t}"), vec![cfg.hidden_size]));
            }
            for t in ["wq", "wk", "wv", "wo"] {
                names.push((format!("layer.{l}.{t}"), vec![cfg.hidden_size, cfg.hidden_size]));
            }
            names.push((format!("layer.{l}.gate"), vec![cfg.hidden_size, cfg.n_experts]));
            for e in 0..cfg.n_experts {
                names.push((format!("layer.{l}.expert.{e}.w1"), vec![cfg.hidden_size, cfg.ffn_size]));
                names.push((format!("layer.{l}.expert.{e}.w3"), vec![cfg.hidden_size, cfg.ffn_size]));
                names.push((format!("layer.{l}.expert.{e}.w2"), vec![cfg.ffn_size, cfg.hidden_size]));
            }
        }
        names.push(("final.ln".into(), vec![cfg.hidden_size]));
        names.push(("final.lm_head".into(), vec![cfg.hidden_size, cfg.vocab_size]));

        let align = |n: usize| n.div_ceil(64) * 64;
        let mut tensors_json = String::from("[");
        let mut offset = 0usize;
        for (i, (name, shape)) in names.iter().enumerate() {
            let numel: usize = shape.iter().product();
            if i > 0 {
                tensors_json.push(',');
            }
            tensors_json.push_str(&format!(
                r#"{{"name":"{name}","shape":{shape:?},"offset":{offset},"nbytes":{}}}"#,
                numel * 4
            ));
            offset = align(offset + numel * 4);
        }
        tensors_json.push(']');
        let cfg_json = format!(
            r#"{{"vocab_size":{},"hidden_size":{},"n_layers":{},"n_heads":{},"n_experts":{},"top_k":{},"ffn_size":{},"max_seq":{}}}"#,
            cfg.vocab_size, cfg.hidden_size, cfg.n_layers, cfg.n_heads,
            cfg.n_experts, cfg.top_k, cfg.ffn_size, cfg.max_seq
        );
        let mut header = format!(
            r#"{{"config":{cfg_json},"tensors":{tensors_json},"data_start":0}}"#
        );
        let data_start = align(12 + header.len() + 32);
        header = header.replace("\"data_start\":0", &format!("\"data_start\":{data_start}"));

        let total = data_start + offset + 1024;
        let mut blob = vec![0u8; total];
        blob[..4].copy_from_slice(b"MOEW");
        blob[4..8].copy_from_slice(&1u32.to_le_bytes());
        blob[8..12].copy_from_slice(&(header.len() as u32).to_le_bytes());
        blob[12..12 + header.len()].copy_from_slice(header.as_bytes());
        let mut offset = 0usize;
        for (name, shape) in &names {
            let numel: usize = shape.iter().product();
            for i in 0..numel {
                let v = fill(name, i);
                let at = data_start + offset + i * 4;
                blob[at..at + 4].copy_from_slice(&v.to_le_bytes());
            }
            offset = align(offset + numel * 4);
        }
        blob
    }

    #[test]
    fn parse_and_validate_synth() {
        let blob = synth_moew(ModelConfig::TINY, |_, i| i as f32 * 0.001);
        let w = Weights::from_bytes(&blob).unwrap();
        assert_eq!(w.config, ModelConfig::TINY);
        w.validate_layout().unwrap();
        let t = w.get("embed.table").unwrap();
        assert_eq!(t.len(), 64 * 32);
        assert_eq!(t[3], 0.003);
        assert!(w.has("layer.1.expert.7.w2"));
        assert!(!w.has("layer.2.ln1"));
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(Weights::from_bytes(b"NOPE00000000").is_err());
    }

    #[test]
    fn truncation_rejected() {
        let blob = synth_moew(ModelConfig::TINY, |_, _| 0.0);
        assert!(Weights::from_bytes(&blob[..200]).is_err());
    }

    #[test]
    fn layer_and_expert_accessors() {
        let blob = synth_moew(ModelConfig::TINY, |name, _| name.len() as f32);
        let w = Weights::from_bytes(&blob).unwrap();
        assert_eq!(w.layer(0, "ln1").unwrap()[0], "layer.0.ln1".len() as f32);
        assert_eq!(
            w.expert(1, 3, "w1").unwrap()[0],
            "layer.1.expert.3.w1".len() as f32
        );
        assert!(w.layer(9, "ln1").is_err());
    }
}
