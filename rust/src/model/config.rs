//! MiniMixtral hyper-parameters, mirrored from `python/compile/model.py`
//! and cross-checked against `artifacts/manifest.json` at load time.

use crate::util::json::Value;
use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub hidden_size: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub ffn_size: usize,
    pub max_seq: usize,
}

impl ModelConfig {
    /// The default (shipped-artifact) configuration.
    pub const DEFAULT: ModelConfig = ModelConfig {
        vocab_size: 1024,
        hidden_size: 256,
        n_layers: 12,
        n_heads: 8,
        n_experts: 8,
        top_k: 2,
        ffn_size: 1024,
        max_seq: 256,
    };

    /// The tiny test configuration (matches `compile.model.TINY`).
    pub const TINY: ModelConfig = ModelConfig {
        vocab_size: 64,
        hidden_size: 32,
        n_layers: 2,
        n_heads: 4,
        n_experts: 8,
        top_k: 2,
        ffn_size: 64,
        max_seq: 16,
    };

    pub fn head_dim(&self) -> usize {
        self.hidden_size / self.n_heads
    }

    /// Floats in one expert (w1 + w3 + w2).
    pub fn expert_params(&self) -> usize {
        3 * self.hidden_size * self.ffn_size
    }

    /// fp32 bytes of one expert — the unit of offloading traffic.
    pub fn expert_bytes_f32(&self) -> usize {
        self.expert_params() * 4
    }

    pub fn from_json(v: &Value) -> Result<ModelConfig> {
        let need = |k: &str| -> Result<usize> {
            v.get(k).as_usize().ok_or_else(|| anyhow::anyhow!("config missing {k}"))
        };
        let cfg = ModelConfig {
            vocab_size: need("vocab_size")?,
            hidden_size: need("hidden_size")?,
            n_layers: need("n_layers")?,
            n_heads: need("n_heads")?,
            n_experts: need("n_experts")?,
            top_k: need("top_k")?,
            ffn_size: need("ffn_size")?,
            max_seq: need("max_seq")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.hidden_size % self.n_heads != 0 {
            bail!("hidden_size {} not divisible by n_heads {}", self.hidden_size, self.n_heads);
        }
        if self.top_k == 0 || self.top_k > self.n_experts {
            bail!("top_k {} out of range (E={})", self.top_k, self.n_experts);
        }
        if self.head_dim() % 2 != 0 {
            bail!("head_dim {} must be even for RoPE", self.head_dim());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn default_is_valid() {
        ModelConfig::DEFAULT.validate().unwrap();
        ModelConfig::TINY.validate().unwrap();
    }

    #[test]
    fn from_json_roundtrip() {
        let j = r#"{"vocab_size":64,"hidden_size":32,"n_layers":2,"n_heads":4,
                    "n_experts":8,"top_k":2,"ffn_size":64,"max_seq":16,
                    "rope_theta":10000.0,"rms_eps":1e-5}"#;
        let v = json::parse(j).unwrap();
        let cfg = ModelConfig::from_json(&v).unwrap();
        assert_eq!(cfg, ModelConfig::TINY);
    }

    #[test]
    fn invalid_rejected() {
        let mut c = ModelConfig::TINY;
        c.top_k = 9;
        assert!(c.validate().is_err());
        let mut c = ModelConfig::TINY;
        c.n_heads = 5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn expert_bytes() {
        let c = ModelConfig::DEFAULT;
        assert_eq!(c.expert_params(), 3 * 256 * 1024);
        assert_eq!(c.expert_bytes_f32(), 3 * 256 * 1024 * 4);
    }
}
