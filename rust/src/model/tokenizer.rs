//! Byte-level tokenizer with special tokens.
//!
//! The paper's workload is natural-language prompts through Mixtral's BPE
//! tokenizer; with synthetic weights the exact segmentation is immaterial,
//! so we use a transparent byte-level scheme: token = byte value + offset,
//! plus BOS/EOS/PAD specials. Vocab 1024 leaves headroom (260 used).

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const UNK: u32 = 3;
const BYTE_OFFSET: u32 = 4;

#[derive(Clone, Copy, Debug)]
pub struct Tokenizer {
    pub vocab_size: usize,
}

impl Tokenizer {
    pub fn new(vocab_size: usize) -> Self {
        assert!(
            vocab_size >= BYTE_OFFSET as usize + 256,
            "vocab must fit 256 bytes + specials"
        );
        Tokenizer { vocab_size }
    }

    /// Encode text as BOS + bytes.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut toks = Vec::with_capacity(text.len() + 1);
        toks.push(BOS);
        toks.extend(text.bytes().map(|b| b as u32 + BYTE_OFFSET));
        toks
    }

    /// Decode tokens back to text; specials are dropped, non-byte tokens
    /// become U+FFFD.
    pub fn decode(&self, toks: &[u32]) -> String {
        String::from_utf8_lossy(&self.decode_bytes(toks)).into_owned()
    }

    /// The raw byte stream behind [`Tokenizer::decode`] (specials and
    /// out-of-range tokens dropped, no UTF-8 substitution). Streaming
    /// delivery works at this level so it can hold back a trailing
    /// incomplete UTF-8 sequence until later tokens stabilize it —
    /// keeping the concatenated stream byte-identical to `decode` of the
    /// whole sequence.
    pub fn decode_bytes(&self, toks: &[u32]) -> Vec<u8> {
        toks.iter()
            .filter(|&&t| t >= BYTE_OFFSET && t < BYTE_OFFSET + 256)
            .map(|&t| (t - BYTE_OFFSET) as u8)
            .collect()
    }

    pub fn is_special(&self, tok: u32) -> bool {
        tok < BYTE_OFFSET
    }

    pub fn is_eos(&self, tok: u32) -> bool {
        tok == EOS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let tk = Tokenizer::new(1024);
        let toks = tk.encode("Introduce yourself");
        assert_eq!(toks[0], BOS);
        assert_eq!(tk.decode(&toks), "Introduce yourself");
    }

    #[test]
    fn roundtrip_utf8() {
        let tk = Tokenizer::new(1024);
        let s = "héllo 😀";
        assert_eq!(tk.decode(&tk.encode(s)), s);
    }

    #[test]
    fn specials_dropped_on_decode() {
        let tk = Tokenizer::new(1024);
        let mut toks = tk.encode("ab");
        toks.push(EOS);
        toks.push(PAD);
        assert_eq!(tk.decode(&toks), "ab");
    }

    #[test]
    fn tokens_in_vocab() {
        let tk = Tokenizer::new(1024);
        for t in tk.encode("\u{0}\u{7f}xyz") {
            assert!((t as usize) < tk.vocab_size);
        }
    }

    #[test]
    #[should_panic]
    fn tiny_vocab_rejected() {
        Tokenizer::new(100);
    }
}
