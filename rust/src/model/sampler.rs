//! Token sampling: greedy / temperature / top-p (nucleus), matching the
//! paper's decoding setups (temperature = top_p = 0.9 for MMLU; 0.1 for the
//! hardware comparison so responses are length-comparable; greedy for the
//! golden cross-check).

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    Greedy,
    /// temperature + nucleus top-p
    TopP { temperature: f32, top_p: f32 },
}

impl Sampling {
    pub fn paper_mmlu() -> Self {
        Sampling::TopP { temperature: 0.9, top_p: 0.9 }
    }
    pub fn paper_hw_comparison() -> Self {
        Sampling::TopP { temperature: 0.1, top_p: 0.1 }
    }
}

pub struct Sampler {
    pub mode: Sampling,
    rng: Rng,
}

impl Sampler {
    pub fn new(mode: Sampling, seed: u64) -> Self {
        Sampler { mode, rng: Rng::new(seed) }
    }

    pub fn sample(&mut self, logits: &[f32]) -> usize {
        match self.mode {
            Sampling::Greedy => argmax(logits),
            Sampling::TopP { temperature, top_p } => {
                self.sample_top_p(logits, temperature, top_p)
            }
        }
    }

    fn sample_top_p(&mut self, logits: &[f32], temperature: f32, top_p: f32) -> usize {
        let t = temperature.max(1e-4);
        // softmax with temperature
        let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<(usize, f64)> = logits
            .iter()
            .enumerate()
            .map(|(i, &l)| (i, (((l - max) / t) as f64).exp()))
            .collect();
        let z: f64 = probs.iter().map(|(_, p)| p).sum();
        for p in probs.iter_mut() {
            p.1 /= z;
        }
        // nucleus: smallest prefix of sorted probs with mass >= top_p
        probs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut mass = 0.0;
        let mut cut = probs.len();
        for (i, (_, p)) in probs.iter().enumerate() {
            mass += p;
            if mass >= top_p as f64 {
                cut = i + 1;
                break;
            }
        }
        probs.truncate(cut);
        let weights: Vec<f64> = probs.iter().map(|(_, p)| *p).collect();
        probs[self.rng.categorical(&weights)].0
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Top-k indices by value, descending — the MoE expert selection primitive.
/// Deterministic tie-break: lower index wins (matches `jax.lax.top_k`).
pub fn top_k(xs: &[f32], k: usize) -> Vec<usize> {
    assert!(k <= xs.len());
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap().then(a.cmp(&b)));
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 3.0]), 1); // first max wins
    }

    #[test]
    fn top_k_descending_with_tiebreak() {
        let xs = [0.1, 0.9, 0.5, 0.9, 0.2];
        assert_eq!(top_k(&xs, 3), vec![1, 3, 2]);
    }

    #[test]
    fn greedy_matches_argmax() {
        let mut s = Sampler::new(Sampling::Greedy, 0);
        assert_eq!(s.sample(&[0.0, 2.0, 1.0]), 1);
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut s = Sampler::new(Sampling::TopP { temperature: 0.05, top_p: 0.99 }, 1);
        let logits = [1.0f32, 5.0, 2.0, 0.0];
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn top_p_cuts_tail() {
        // with top_p tiny, only the argmax survives the nucleus
        let mut s = Sampler::new(Sampling::TopP { temperature: 1.0, top_p: 0.01 }, 2);
        let logits = [1.0f32, 4.0, 2.0];
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn sampling_deterministic_per_seed() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let run = |seed| {
            let mut s = Sampler::new(Sampling::paper_mmlu(), seed);
            (0..20).map(|_| s.sample(&logits)).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
