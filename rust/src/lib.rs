//! # moe-offload
//!
//! Production-grade reproduction of *"In-depth Analysis on Caching and
//! Pre-fetching in Mixture of Experts Offloading"* (Lin, He & Chen, 2025)
//! as a three-layer rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the serving coordinator: expert cache
//!   (LRU/LFU/LFU-aged/oracle), offload transfer engine with a simulated
//!   PCIe clock, speculative expert prefetcher, trace recorder, cache
//!   simulator, HTTP server, and the figure/table regeneration harness.
//! * **L2 (python/compile/model.py)** — MiniMixtral staged forward pass,
//!   AOT-lowered once to HLO text (`make artifacts`).
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the expert FFN
//!   and router, `interpret=True`, validated against pure-jnp oracles.
//!
//! Python never runs on the request path: the rust binary loads the HLO
//! artifacts via PJRT (`runtime::pjrt`) and is self-contained.
//!
//! See DESIGN.md for the system inventory and the per-experiment index.

pub mod bench_harness;
pub mod cache;
pub mod engine;
pub mod figures;
pub mod metrics;
pub mod serve;
pub mod offload;
pub mod sim;
pub mod trace;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;
