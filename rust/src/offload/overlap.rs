//! Overlap worker — the paper's §6.1 "overlapping transfer with
//! computation" direction, implemented.
//!
//! The dominant CPU cost of a transfer on this substrate is dequantization.
//! A background thread performs dequantization off the critical path: the
//! engine submits (layer, expert) requests when a speculative guess is
//! made, keeps computing, and collects finished results at the next layer
//! boundary. The upload half (creating the PJRT buffer) stays on the engine
//! thread because the PJRT client is not shared across threads.

use crate::offload::store::HostExpertStore;
use std::collections::HashSet;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

pub struct DequantResult {
    pub layer: usize,
    pub expert: usize,
    pub w1: Vec<f32>,
    pub w3: Vec<f32>,
    pub w2: Vec<f32>,
}

pub struct OverlapWorker {
    tx: Option<Sender<(usize, usize)>>,
    rx: Receiver<DequantResult>,
    handle: Option<JoinHandle<()>>,
    /// Requests submitted but not yet collected.
    in_flight: HashSet<(usize, usize)>,
    /// Results drained while waiting for a specific one.
    ready_stash: Vec<DequantResult>,
}

impl OverlapWorker {
    pub fn spawn(store: Arc<HostExpertStore>) -> Self {
        let (req_tx, req_rx) = channel::<(usize, usize)>();
        let (res_tx, res_rx) = channel::<DequantResult>();
        let handle = std::thread::Builder::new()
            .name("overlap-dequant".into())
            .spawn(move || {
                while let Ok((layer, expert)) = req_rx.recv() {
                    let (w1, w3, w2) = store.fetch(layer, expert);
                    if res_tx.send(DequantResult { layer, expert, w1, w3, w2 }).is_err() {
                        break;
                    }
                }
            })
            .expect("spawn overlap worker");
        OverlapWorker {
            tx: Some(req_tx),
            rx: res_rx,
            handle: Some(handle),
            in_flight: HashSet::new(),
            ready_stash: Vec::new(),
        }
    }

    /// Submit a prefetch; duplicates of in-flight requests are dropped.
    pub fn submit(&mut self, layer: usize, expert: usize) {
        if self.in_flight.insert((layer, expert)) {
            if let Some(tx) = &self.tx {
                let _ = tx.send((layer, expert));
            }
        }
    }

    pub fn in_flight(&self, layer: usize, expert: usize) -> bool {
        self.in_flight.contains(&(layer, expert))
    }

    /// Non-blocking drain of finished dequantizations.
    pub fn collect_ready(&mut self) -> Vec<DequantResult> {
        let mut out = std::mem::take(&mut self.ready_stash);
        loop {
            match self.rx.try_recv() {
                Ok(r) => {
                    self.in_flight.remove(&(r.layer, r.expert));
                    out.push(r);
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        out
    }

    /// Blocking wait for one specific in-flight request (demand promotion
    /// of a prefetch). Other results drained along the way are stashed and
    /// returned by the next `collect_ready`.
    pub fn wait_for(&mut self, layer: usize, expert: usize) -> Option<DequantResult> {
        if !self.in_flight.contains(&(layer, expert)) {
            return self
                .ready_stash
                .iter()
                .position(|r| r.layer == layer && r.expert == expert)
                .map(|i| self.ready_stash.swap_remove(i));
        }
        while let Ok(r) = self.rx.recv() {
            self.in_flight.remove(&(r.layer, r.expert));
            if r.layer == layer && r.expert == expert {
                return Some(r);
            }
            self.ready_stash.push(r);
        }
        None
    }
}

impl Drop for OverlapWorker {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::synth_weights;
    use crate::model::ModelConfig;
    use crate::quant::Scheme;

    fn store() -> Arc<HostExpertStore> {
        let w = synth_weights(ModelConfig::TINY, |_, i| (i % 5) as f32 * 0.02);
        Arc::new(HostExpertStore::build(&w, Scheme::Int8 { block: 16 }).unwrap())
    }

    #[test]
    fn submit_and_wait() {
        let mut w = OverlapWorker::spawn(store());
        w.submit(0, 3);
        let r = w.wait_for(0, 3).expect("result");
        assert_eq!((r.layer, r.expert), (0, 3));
        assert_eq!(r.w1.len(), 32 * 64);
        assert!(!w.in_flight(0, 3));
    }

    #[test]
    fn collect_ready_eventually_gets_all() {
        let mut w = OverlapWorker::spawn(store());
        w.submit(0, 1);
        w.submit(1, 2);
        let mut got = Vec::new();
        while got.len() < 2 {
            got.extend(w.collect_ready().into_iter().map(|r| (r.layer, r.expert)));
            std::thread::yield_now();
        }
        got.sort_unstable();
        assert_eq!(got, vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn duplicate_submits_coalesce() {
        let mut w = OverlapWorker::spawn(store());
        w.submit(0, 0);
        w.submit(0, 0);
        let r1 = w.wait_for(0, 0);
        assert!(r1.is_some());
        // only one result total: nothing else ever arrives
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(w.collect_ready().is_empty());
    }

    #[test]
    fn wait_for_unknown_is_none() {
        let mut w = OverlapWorker::spawn(store());
        assert!(w.wait_for(1, 7).is_none());
    }

    #[test]
    fn wait_stashes_unrelated_results() {
        let mut w = OverlapWorker::spawn(store());
        w.submit(0, 1);
        w.submit(0, 2);
        // wait for the second; the first gets stashed
        let r = w.wait_for(0, 2).unwrap();
        assert_eq!(r.expert, 2);
        let rest = w.collect_ready();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].expert, 1);
    }
}
