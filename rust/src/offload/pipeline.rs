//! Multi-worker transfer pipeline — the zero-allocation replacement for the
//! single-thread overlap worker (paper §6.1, "overlapping transfer with
//! computation" without "competing for bandwidth").
//!
//! The dominant CPU cost of a transfer on this substrate is dequantization,
//! so the pipeline runs N dequant workers fed by a **two-priority queue**:
//! demand misses preempt speculative prefetches, a demand miss *joins* an
//! in-flight prefetch of the same `(layer, expert)` instead of
//! double-fetching, and queued prefetches whose guess was superseded (or
//! whose product was evicted) are cancelled before a worker wastes cycles
//! on them. All dequantization lands in recycled f32 buffers from a shared
//! [`BufferPool`], so the steady state performs no heap allocation: buffers
//! flow pool -> worker -> `ExpertHandle::Host` -> (eviction) -> pool.
//!
//! The upload half (creating device buffers) stays on the engine thread
//! because the PJRT client is not shared across threads; the native backend
//! takes ownership of the pooled buffers directly, which is what lets the
//! eviction path recycle them.
//!
//! With a tiered store (`HostExpertStore::build_tiered`, DESIGN.md §10) the
//! disk read stage rides the same two-priority queue for free: a worker's
//! `fetch_pooled` promotes a RAM-missing expert from the spill file *before*
//! dequantizing, so demand misses preempt speculative jobs at the disk tier
//! exactly as they do at the dequant tier, and concurrent workers demanding
//! the same `(layer, expert)` dedup inside the store's in-flight set (one
//! pread, everyone else waits on the promoted entry).

use crate::metrics::PipelineStats;
use crate::offload::store::HostExpertStore;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// `(layer, expert)` — the unit of transfer.
pub type Key = (usize, usize);

/// Queue class of a submitted job. Demand jobs are popped before any
/// prefetch job, regardless of arrival order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    Demand,
    Prefetch,
}

// ---------------------------------------------------------------------------
// BufferPool
// ---------------------------------------------------------------------------

/// Reusable f32 buffer pool shared by the transfer path (sync and async).
///
/// `acquire` pops a recycled buffer when one is available (resizing is a
/// no-op after warmup because every expert tensor in a model has the same
/// element count) and only allocates on a cold pool; `release` returns a
/// buffer with its capacity intact. The `allocs`/`reuses` counters feed the
/// steady-state *pool reuse rate* reported by benches and `/metrics`.
#[derive(Default)]
pub struct BufferPool {
    free: Mutex<Vec<Vec<f32>>>,
    allocs: AtomicU64,
    reuses: AtomicU64,
}

impl BufferPool {
    pub fn new() -> Arc<BufferPool> {
        Arc::new(BufferPool::default())
    }

    /// Get a buffer of exactly `len` elements (contents unspecified — every
    /// consumer fully overwrites via `dequantize_into`).
    pub fn acquire(&self, len: usize) -> Vec<f32> {
        let recycled = self.free.lock().unwrap().pop();
        match recycled {
            Some(mut buf) => {
                self.reuses.fetch_add(1, Ordering::Relaxed);
                buf.resize(len, 0.0);
                buf
            }
            None => {
                self.allocs.fetch_add(1, Ordering::Relaxed);
                vec![0.0; len]
            }
        }
    }

    /// Return a buffer to the pool (capacity kept, contents kept — the next
    /// `acquire` overwrites them).
    pub fn release(&self, buf: Vec<f32>) {
        self.free.lock().unwrap().push(buf);
    }

    pub fn allocs(&self) -> u64 {
        self.allocs.load(Ordering::Relaxed)
    }

    pub fn reuses(&self) -> u64 {
        self.reuses.load(Ordering::Relaxed)
    }

    /// Fraction of acquires served by recycling (0.0 on an unused pool).
    pub fn reuse_rate(&self) -> f64 {
        let a = self.allocs();
        let r = self.reuses();
        if a + r == 0 {
            return 0.0;
        }
        r as f64 / (a + r) as f64
    }
}

// ---------------------------------------------------------------------------
// TransferPipeline
// ---------------------------------------------------------------------------

/// A dequantized expert produced by a worker, in pooled buffers.
pub struct FetchedExpert {
    pub layer: usize,
    pub expert: usize,
    pub w1: Vec<f32>,
    pub w3: Vec<f32>,
    pub w2: Vec<f32>,
}

/// Worker-shared queue state behind the mutex.
struct PipeShared {
    demand: VecDeque<Key>,
    prefetch: VecDeque<Key>,
    closed: bool,
}

impl PipeShared {
    fn pop(&mut self) -> Option<Key> {
        self.demand.pop_front().or_else(|| self.prefetch.pop_front())
    }
}

/// Engine-side handle to the N dequant workers. Not `Sync`: exactly one
/// thread (the engine) submits, waits and collects; only the queue behind
/// the mutex is shared with workers.
pub struct TransferPipeline {
    shared: Arc<(Mutex<PipeShared>, Condvar)>,
    res_rx: Receiver<FetchedExpert>,
    handles: Vec<JoinHandle<()>>,
    /// Keys submitted but not yet collected, with their current priority.
    tracked: HashMap<Key, Priority>,
    /// Results drained while waiting for a specific key.
    ready_stash: Vec<FetchedExpert>,
    pool: Arc<BufferPool>,
    stats: PipelineStats,
}

impl TransferPipeline {
    /// Spawn `workers` dequant threads over `store`, drawing output buffers
    /// from `pool`. (`workers == 0` is permitted for queue-mechanics tests;
    /// the engine always spawns at least one.)
    pub fn spawn(
        store: Arc<HostExpertStore>,
        pool: Arc<BufferPool>,
        workers: usize,
    ) -> TransferPipeline {
        let shared = Arc::new((
            Mutex::new(PipeShared {
                demand: VecDeque::new(),
                prefetch: VecDeque::new(),
                closed: false,
            }),
            Condvar::new(),
        ));
        let (res_tx, res_rx) = channel::<FetchedExpert>();
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let shared = Arc::clone(&shared);
            let store = Arc::clone(&store);
            let pool = Arc::clone(&pool);
            let res_tx = res_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("transfer-worker-{i}"))
                .spawn(move || {
                    let (lock, cvar) = &*shared;
                    loop {
                        let key = {
                            let mut st = lock.lock().unwrap();
                            loop {
                                if let Some(k) = st.pop() {
                                    break Some(k);
                                }
                                if st.closed {
                                    break None;
                                }
                                st = cvar.wait(st).unwrap();
                            }
                        };
                        let Some((layer, expert)) = key else { break };
                        let (w1, w3, w2) = store.fetch_pooled(&pool, layer, expert);
                        let sent = res_tx.send(FetchedExpert { layer, expert, w1, w3, w2 });
                        if sent.is_err() {
                            break; // engine gone
                        }
                    }
                })
                .expect("spawn transfer worker");
            handles.push(handle);
        }
        drop(res_tx); // workers hold the only senders
        TransferPipeline {
            shared,
            res_rx,
            handles,
            tracked: HashMap::new(),
            ready_stash: Vec::new(),
            pool,
            stats: PipelineStats { workers: workers as u64, ..PipelineStats::default() },
        }
    }

    /// Is `(layer, expert)` queued, running, or stashed-uncollected?
    /// (Stashed results count: the transfer happened and will be delivered
    /// by `collect_ready`/`wait_for`, so a new submission — or engine-side
    /// bus bookkeeping — for the same key would double it.)
    pub fn in_flight(&self, layer: usize, expert: usize) -> bool {
        self.tracked.contains_key(&(layer, expert)) || self.stashed((layer, expert))
    }

    fn note_depth(&mut self) {
        self.stats.peak_in_flight = self.stats.peak_in_flight.max(self.tracked.len() as u64);
    }

    /// A finished result for `key` already sits in the stash (drained while
    /// waiting for something else) — resubmitting would double-fetch.
    fn stashed(&self, key: Key) -> bool {
        self.ready_stash.iter().any(|r| (r.layer, r.expert) == key)
    }

    /// Submit a speculative prefetch. Duplicates of any in-flight request
    /// (either priority) and of already-delivered results are dropped.
    pub fn submit_prefetch(&mut self, layer: usize, expert: usize) {
        let key = (layer, expert);
        if self.tracked.contains_key(&key) || self.stashed(key) {
            return;
        }
        self.tracked.insert(key, Priority::Prefetch);
        self.stats.submitted_prefetch += 1;
        self.note_depth();
        let (lock, cvar) = &*self.shared;
        lock.lock().unwrap().prefetch.push_back(key);
        cvar.notify_one();
    }

    /// Submit a demand miss. If the same key is already in flight as a
    /// prefetch, the demand **joins** it: a queued job is promoted to the
    /// front of the demand queue, a running job is simply awaited — either
    /// way no second fetch is issued (counted as `demand_joined_prefetch`).
    /// Returns whether an existing prefetch was joined (so the caller can
    /// charge only the residual of the already-reserved simulated bus slot
    /// instead of a second transfer).
    pub fn submit_demand(&mut self, layer: usize, expert: usize) -> bool {
        let key = (layer, expert);
        match self.tracked.get(&key).copied() {
            Some(Priority::Demand) => true, // joined earlier this call chain
            Some(Priority::Prefetch) => {
                self.stats.demand_joined_prefetch += 1;
                self.tracked.insert(key, Priority::Demand);
                let (lock, _) = &*self.shared;
                let mut st = lock.lock().unwrap();
                if let Some(i) = st.prefetch.iter().position(|k| *k == key) {
                    st.prefetch.remove(i);
                    st.demand.push_front(key); // escalate ahead of the queue
                }
                // not queued => already running on a worker: just await it
                true
            }
            None if self.stashed(key) => {
                // the prefetch already delivered; `wait_for` will take it
                // from the stash — joining a completed prefetch is free
                self.stats.demand_joined_prefetch += 1;
                true
            }
            None => {
                self.tracked.insert(key, Priority::Demand);
                self.stats.submitted_demand += 1;
                self.note_depth();
                let (lock, cvar) = &*self.shared;
                lock.lock().unwrap().demand.push_back(key);
                cvar.notify_one();
                false
            }
        }
    }

    /// Cancel a *queued* prefetch (a running or demand job is untouched).
    /// Returns whether a job was removed from the queue.
    pub fn cancel_queued_prefetch(&mut self, layer: usize, expert: usize) -> bool {
        let key = (layer, expert);
        if self.tracked.get(&key) != Some(&Priority::Prefetch) {
            return false;
        }
        let removed = {
            let (lock, _) = &*self.shared;
            let mut st = lock.lock().unwrap();
            match st.prefetch.iter().position(|k| *k == key) {
                Some(i) => {
                    st.prefetch.remove(i);
                    true
                }
                None => false, // already picked up by a worker
            }
        };
        if removed {
            self.tracked.remove(&key);
            self.stats.cancelled_prefetches += 1;
        }
        removed
    }

    /// Cancel every queued prefetch for `layer` whose expert is not in
    /// `keep` — a fresh speculative guess supersedes stale queued guesses.
    /// Returns the cancelled experts so the caller can drop its own records.
    pub fn cancel_superseded(&mut self, layer: usize, keep: &[usize]) -> Vec<usize> {
        let stale: Vec<usize> = self
            .tracked
            .iter()
            .filter(|(k, p)| k.0 == layer && **p == Priority::Prefetch && !keep.contains(&k.1))
            .map(|(k, _)| k.1)
            .collect();
        stale
            .into_iter()
            .filter(|&e| self.cancel_queued_prefetch(layer, e))
            .collect()
    }

    /// Non-blocking drain of finished transfers.
    pub fn collect_ready(&mut self) -> Vec<FetchedExpert> {
        let mut out = std::mem::take(&mut self.ready_stash);
        for r in &out {
            self.tracked.remove(&(r.layer, r.expert));
        }
        loop {
            match self.res_rx.try_recv() {
                Ok(r) => {
                    self.tracked.remove(&(r.layer, r.expert));
                    self.stats.completed += 1;
                    out.push(r);
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        out
    }

    /// Blocking wait for one specific in-flight key (demand join). Other
    /// results drained along the way are stashed for `collect_ready`.
    /// Returns `None` if the key is not in flight or every worker died.
    pub fn wait_for(&mut self, layer: usize, expert: usize) -> Option<FetchedExpert> {
        let key = (layer, expert);
        if !self.tracked.contains_key(&key) {
            return self
                .ready_stash
                .iter()
                .position(|r| r.layer == layer && r.expert == expert)
                .map(|i| self.ready_stash.swap_remove(i));
        }
        while let Ok(r) = self.res_rx.recv() {
            self.tracked.remove(&(r.layer, r.expert));
            self.stats.completed += 1;
            if r.layer == layer && r.expert == expert {
                return Some(r);
            }
            self.ready_stash.push(r);
        }
        // channel closed: nothing tracked will ever arrive
        self.tracked.clear();
        None
    }

    /// Counters merged with the shared pool's allocation accounting.
    pub fn stats(&self) -> PipelineStats {
        let mut s = self.stats;
        s.pool_allocs = self.pool.allocs();
        s.pool_reuses = self.pool.reuses();
        s
    }

    #[cfg(test)]
    fn queue_lens(&self) -> (usize, usize) {
        let st = self.shared.0.lock().unwrap();
        (st.demand.len(), st.prefetch.len())
    }
}

impl Drop for TransferPipeline {
    fn drop(&mut self) {
        {
            let (lock, cvar) = &*self.shared;
            lock.lock().unwrap().closed = true;
            cvar.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::synth_weights;
    use crate::model::ModelConfig;
    use crate::quant::Scheme;

    fn store() -> Arc<HostExpertStore> {
        let w = synth_weights(ModelConfig::TINY, |_, i| (i % 5) as f32 * 0.02);
        Arc::new(HostExpertStore::build(&w, Scheme::Int8 { block: 16 }).unwrap())
    }

    fn pipeline(workers: usize) -> TransferPipeline {
        TransferPipeline::spawn(store(), BufferPool::new(), workers)
    }

    #[test]
    fn submit_and_wait() {
        let mut p = pipeline(2);
        p.submit_prefetch(0, 3);
        let r = p.wait_for(0, 3).expect("result");
        assert_eq!((r.layer, r.expert), (0, 3));
        assert_eq!(r.w1.len(), 32 * 64);
        assert!(!p.in_flight(0, 3));
    }

    #[test]
    fn collect_ready_eventually_gets_all() {
        let mut p = pipeline(3);
        p.submit_prefetch(0, 1);
        p.submit_prefetch(1, 2);
        p.submit_demand(0, 4);
        let mut got = Vec::new();
        while got.len() < 3 {
            got.extend(p.collect_ready().into_iter().map(|r| (r.layer, r.expert)));
            std::thread::yield_now();
        }
        got.sort_unstable();
        assert_eq!(got, vec![(0, 1), (0, 4), (1, 2)]);
    }

    #[test]
    fn duplicate_submits_coalesce() {
        let mut p = pipeline(1);
        p.submit_prefetch(0, 0);
        p.submit_prefetch(0, 0);
        p.submit_demand(0, 0); // joins, does not refetch
        assert!(p.wait_for(0, 0).is_some());
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(p.collect_ready().is_empty());
        let s = p.stats();
        assert_eq!(s.submitted_prefetch, 1);
        assert_eq!(s.demand_joined_prefetch, 1);
        assert_eq!(s.completed, 1);
    }

    #[test]
    fn wait_for_unknown_is_none() {
        let mut p = pipeline(1);
        assert!(p.wait_for(1, 7).is_none());
    }

    #[test]
    fn wait_stashes_unrelated_results() {
        let mut p = pipeline(1);
        p.submit_prefetch(0, 1);
        p.submit_prefetch(0, 2);
        let r = p.wait_for(0, 2).unwrap();
        assert_eq!(r.expert, 2);
        let rest = p.collect_ready();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].expert, 1);
    }

    #[test]
    fn demand_joins_stashed_result_without_refetch() {
        let mut p = pipeline(1);
        p.submit_prefetch(0, 1);
        p.submit_prefetch(0, 2);
        // waiting for the second stashes the first's result
        assert!(p.wait_for(0, 2).is_some());
        p.submit_demand(0, 1);
        assert!(p.wait_for(0, 1).is_some());
        let s = p.stats();
        assert_eq!(s.submitted_demand, 0, "stashed result must not refetch");
        assert_eq!(s.demand_joined_prefetch, 1);
        assert_eq!(s.completed, 2);
    }

    #[test]
    fn demand_escalates_ahead_of_queued_prefetches() {
        // no workers: queue mechanics are fully deterministic
        let mut p = pipeline(0);
        p.submit_prefetch(0, 1);
        p.submit_prefetch(0, 2);
        p.submit_prefetch(0, 3);
        assert_eq!(p.queue_lens(), (0, 3));
        assert!(p.submit_demand(0, 2), "demand must report the join");
        assert_eq!(p.queue_lens(), (1, 2));
        let s = p.stats();
        assert_eq!(s.demand_joined_prefetch, 1);
        assert_eq!(s.submitted_demand, 0); // a join is not a new submission
        // a fresh demand for an untracked key is a real submission
        assert!(!p.submit_demand(1, 0));
        assert_eq!(p.queue_lens(), (2, 2));
        assert_eq!(p.stats().submitted_demand, 1);
    }

    #[test]
    fn cancel_removes_only_queued_prefetches() {
        let mut p = pipeline(0);
        p.submit_prefetch(0, 1);
        p.submit_prefetch(0, 2);
        p.submit_demand(1, 3);
        assert!(p.cancel_queued_prefetch(0, 1));
        assert!(!p.cancel_queued_prefetch(0, 1), "already cancelled");
        assert!(!p.cancel_queued_prefetch(1, 3), "demand jobs are not cancellable");
        assert_eq!(p.queue_lens(), (1, 1));
        assert!(!p.in_flight(0, 1));
        assert_eq!(p.stats().cancelled_prefetches, 1);
    }

    #[test]
    fn superseded_guesses_are_cancelled() {
        let mut p = pipeline(0);
        p.submit_prefetch(2, 1);
        p.submit_prefetch(2, 5);
        p.submit_prefetch(3, 1); // other layer: untouched
        let mut cancelled = p.cancel_superseded(2, &[5, 7]);
        cancelled.sort_unstable();
        assert_eq!(cancelled, vec![1]);
        assert!(p.in_flight(2, 5));
        assert!(p.in_flight(3, 1));
        assert_eq!(p.stats().cancelled_prefetches, 1);
    }

    #[test]
    fn pool_recycles_buffers() {
        let pool = BufferPool::new();
        let a = pool.acquire(64);
        pool.release(a);
        let b = pool.acquire(64);
        assert_eq!(b.len(), 64);
        assert_eq!(pool.allocs(), 1);
        assert_eq!(pool.reuses(), 1);
        assert_eq!(pool.reuse_rate(), 0.5);
        // resize-on-acquire serves mismatched sizes too
        pool.release(b);
        let c = pool.acquire(16);
        assert_eq!(c.len(), 16);
    }

    #[test]
    fn tiered_store_under_pipeline_matches_ram_and_dedups_disk_reads() {
        use crate::offload::store::HostTierConfig;
        let w = synth_weights(ModelConfig::TINY, |_, i| (i % 5) as f32 * 0.02);
        let scheme = Scheme::Int8 { block: 16 };
        let ram = HostExpertStore::build(&w, scheme).unwrap();
        // RAM budget of 2 entries: the 8-expert sweep churns the tier while
        // 3 workers race promotions through the spill file
        let cfg = HostTierConfig::new(2 * ram.expert_transfer_bytes());
        let tiered = Arc::new(HostExpertStore::build_tiered(&w, scheme, &cfg).unwrap());
        let mut p = TransferPipeline::spawn(Arc::clone(&tiered), BufferPool::new(), 3);
        for round in 0..3 {
            for e in 0..8 {
                if round % 2 == 0 {
                    p.submit_prefetch(1, e);
                } else {
                    p.submit_demand(1, e);
                }
            }
            for e in 0..8 {
                let r = p.wait_for(1, e).expect("worker result");
                let (w1, w3, w2) = ram.fetch(1, e);
                assert_eq!(r.w1, w1, "round {round} expert {e} w1 diverged");
                assert_eq!(r.w3, w3);
                assert_eq!(r.w2, w2);
            }
        }
        let s = tiered.tier_stats();
        assert_eq!(s.host_accesses, 24, "3 rounds × 8 experts");
        assert_eq!(
            s.ram_hits + s.disk_promotions,
            s.host_accesses,
            "every access is a hit or a promotion, even under worker races"
        );
        assert!(s.ram_evictions > 0, "a 2-entry budget must churn");
    }

    #[test]
    fn steady_state_pool_traffic_is_allocation_free() {
        let pool = BufferPool::new();
        let p_store = store();
        let mut p = TransferPipeline::spawn(p_store, Arc::clone(&pool), 2);
        // warmup: 6 distinct transfers, recycled after each round
        for round in 0..20 {
            for e in 0..3 {
                p.submit_prefetch(0, e);
            }
            for e in 0..3 {
                let r = p.wait_for(0, e).unwrap();
                pool.release(r.w1);
                pool.release(r.w3);
                pool.release(r.w2);
            }
            if round == 0 {
                // cold pool: everything allocated
                assert!(pool.allocs() > 0);
            }
        }
        // 20 rounds × 9 buffers; at most the first round (plus transient
        // worker overlap) allocated
        assert!(pool.reuse_rate() > 0.8, "reuse rate {}", pool.reuse_rate());
    }
}
