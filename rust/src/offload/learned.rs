//! Offline-trained cross-layer expert predictor — the paper's §6.1
//! "learning-based prediction" direction taken past the online Markov
//! model in [`crate::offload::predictor`].
//!
//! One tiny logistic model per layer boundary: the model for source layer
//! `l` maps features observable the moment `l` finishes routing to
//! activation probabilities for every expert at the NEXT layer
//! `(l+1) % n_layers` (the wrap-around boundary `L-1 -> 0` predicts the
//! next token's first layer). Feature vector (`5E+1` entries):
//!
//! | slot          | meaning                                             |
//! |---------------|-----------------------------------------------------|
//! | `[0,E)`       | one-hot activated set at the source layer           |
//! | `[E,2E)`      | renormalized gate weights at the source layer       |
//! | `[2E,3E)`     | one-hot of the TARGET layer's previous activated set |
//! | `[3E,4E)`     | fast EWMA (decay 0.8) of target-layer activations   |
//! | `[4E,5E)`     | slow EWMA (decay 0.98) of target-layer activations  |
//! | `5E`          | bias                                                |
//!
//! The target layer's own recent history carries most of the signal (MoE
//! routing is strongly self-correlated across tokens, paper §3.1); the
//! source activation + gates add the cross-layer component that
//! speculative gating exploits. Training is plain deterministic SGD on
//! logistic loss — fixed traversal order, f32 arithmetic, no RNG — so two
//! training runs over the same trace are bit-identical, as are two
//! inference replays (the determinism property tests rely on this).
//!
//! Two consumers share the scores:
//! - prefetch: top-k of the imminent-activation probabilities becomes a
//!   [`crate::offload::prefetch::TaggedGuess`] per upcoming layer
//!   ([`LearnedPredictor::rollout`] chains boundaries for lead time);
//! - eviction: [`crate::cache::learned`] turns the same probabilities
//!   into predicted reuse distances to rank victims, approximating
//!   Belady online.

use crate::metrics::PrecisionRecall;
use crate::trace::Trace;
use crate::util::json::{self, Value};
use anyhow::{anyhow, bail, Result};
use std::path::Path;

/// Format tag in serialized weight files.
pub const WEIGHTS_FORMAT: &str = "moe-predictor-v1";
/// Where committed weights live, relative to the repo root — the default
/// for `train-predictor --out` and for every `--predictor-weights`-less
/// entry point that wants a predictor.
pub const DEFAULT_WEIGHTS_PATH: &str = "data/predictor_weights.json";
/// Fast-history EWMA decay (per target-layer visit).
pub const FAST_DECAY: f32 = 0.8;
/// Slow-history EWMA decay (per target-layer visit).
pub const SLOW_DECAY: f32 = 0.98;

/// Training hyperparameters (the defaults are the values validated in
/// EXPERIMENTS.md; they are serialized alongside the weights for
/// provenance).
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { epochs: 6, lr: 0.1 }
    }
}

/// Per-boundary logistic models over activation features.
#[derive(Clone, Debug, PartialEq)]
pub struct LearnedPredictor {
    n_layers: usize,
    n_experts: usize,
    /// w[src_layer][target_expert][feature].
    w: Vec<Vec<Vec<f32>>>,
}

/// Rolling per-layer activation history consumed as model features.
/// Owned by whoever walks tokens (engine, sim replay, trainer); reset at
/// sequence boundaries so history never bleeds across unrelated prompts.
#[derive(Clone, Debug)]
pub struct LearnedContext {
    prev: Vec<Vec<usize>>,
    hf: Vec<Vec<f32>>,
    hs: Vec<Vec<f32>>,
}

impl LearnedContext {
    pub fn new(n_layers: usize, n_experts: usize) -> Self {
        LearnedContext {
            prev: vec![Vec::new(); n_layers],
            hf: vec![vec![0.0; n_experts]; n_layers],
            hs: vec![vec![0.0; n_experts]; n_layers],
        }
    }

    /// Fold one observed activation set into the history for `layer`.
    pub fn observe(&mut self, layer: usize, activated: &[usize]) {
        debug_assert!(layer < self.hf.len());
        for h in self.hf[layer].iter_mut() {
            *h *= FAST_DECAY;
        }
        for h in self.hs[layer].iter_mut() {
            *h *= SLOW_DECAY;
        }
        for &e in activated {
            self.hf[layer][e] += 1.0 - FAST_DECAY;
            self.hs[layer][e] += 1.0 - SLOW_DECAY;
        }
        self.prev[layer].clear();
        self.prev[layer].extend_from_slice(activated);
    }

    /// Forget everything (sequence boundary).
    pub fn reset(&mut self) {
        for p in self.prev.iter_mut() {
            p.clear();
        }
        for h in self.hf.iter_mut() {
            h.fill(0.0);
        }
        for h in self.hs.iter_mut() {
            h.fill(0.0);
        }
    }
}

/// Stable top-k over f32 scores: k-pass argmax with a strictly-greater
/// comparison over an in-order scan, so exact ties resolve to the lowest
/// index — predictions never flip on float quantization of near-ties.
pub fn top_k_stable(scores: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(scores.len());
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        let mut best = usize::MAX;
        for e in 0..scores.len() {
            if out.contains(&e) {
                continue;
            }
            if best == usize::MAX || scores[e] > scores[best] {
                best = e;
            }
        }
        out.push(best);
    }
    out
}

impl LearnedPredictor {
    /// A predictor with all-zero weights: every probability is exactly
    /// 0.5, which downstream consumers treat as "no information" (the
    /// learned eviction policy degrades to LFU, prefetch to popularity
    /// order).
    pub fn new_zeroed(n_layers: usize, n_experts: usize) -> Result<Self> {
        if n_layers < 2 || n_experts == 0 {
            bail!("predictor needs >= 2 layers and >= 1 expert, got {n_layers}x{n_experts}");
        }
        let f = Self::feature_count(n_experts);
        Ok(LearnedPredictor {
            n_layers,
            n_experts,
            w: vec![vec![vec![0.0; f]; n_experts]; n_layers],
        })
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }
    pub fn n_experts(&self) -> usize {
        self.n_experts
    }
    fn feature_count(n_experts: usize) -> usize {
        5 * n_experts + 1
    }
    /// The layer whose imminent visit source layer `l` predicts.
    pub fn target_layer(&self, src_layer: usize) -> usize {
        (src_layer + 1) % self.n_layers
    }

    /// Assemble the feature vector for the boundary out of `src_layer`
    /// into `out` (resized as needed).
    pub fn features_into(
        &self,
        ctx: &LearnedContext,
        src_layer: usize,
        src_activated: &[usize],
        src_gates: &[f32],
        out: &mut Vec<f32>,
    ) {
        let e_n = self.n_experts;
        let tl = self.target_layer(src_layer);
        out.clear();
        out.resize(Self::feature_count(e_n), 0.0);
        for (i, &e) in src_activated.iter().enumerate() {
            out[e] = 1.0;
            out[e_n + e] = src_gates.get(i).copied().unwrap_or(0.0);
        }
        for &e in &ctx.prev[tl] {
            out[2 * e_n + e] = 1.0;
        }
        out[3 * e_n..4 * e_n].copy_from_slice(&ctx.hf[tl]);
        out[4 * e_n..5 * e_n].copy_from_slice(&ctx.hs[tl]);
        out[5 * e_n] = 1.0;
    }

    /// Logistic forward pass for the boundary out of `src_layer`:
    /// `probs[e]` = predicted probability that expert `e` activates at the
    /// target layer's imminent visit.
    pub fn forward_into(&self, src_layer: usize, features: &[f32], probs: &mut Vec<f32>) {
        probs.clear();
        for row in &self.w[src_layer] {
            let z: f32 = row.iter().zip(features).map(|(w, x)| w * x).sum();
            probs.push(sigmoid(z));
        }
    }

    /// Convenience wrapper: probabilities for the layer after `src_layer`.
    pub fn predict_probs(
        &self,
        ctx: &LearnedContext,
        src_layer: usize,
        src_activated: &[usize],
        src_gates: &[f32],
    ) -> Vec<f32> {
        let mut feat = Vec::new();
        let mut probs = Vec::new();
        self.features_into(ctx, src_layer, src_activated, src_gates, &mut feat);
        self.forward_into(src_layer, &feat, &mut probs);
        probs
    }

    /// Top-k expert guess for the layer after `src_layer`.
    pub fn predict_next(
        &self,
        ctx: &LearnedContext,
        src_layer: usize,
        src_activated: &[usize],
        src_gates: &[f32],
        k: usize,
    ) -> Vec<usize> {
        top_k_stable(&self.predict_probs(ctx, src_layer, src_activated, src_gates), k)
    }

    /// Chain boundary models to guess the expert sets of the next `depth`
    /// layers (wrapping into the next token after layer `L-1`): each step
    /// feeds the previous step's top-k guess back in as a pseudo-activated
    /// set with its renormalized probabilities as pseudo-gates. Returns
    /// `(target_layer, top-k experts)` per step. Accuracy decays with
    /// depth — that is the lead-time trade-off the prefetch lookahead
    /// flag exposes.
    pub fn rollout(
        &self,
        ctx: &LearnedContext,
        src_layer: usize,
        src_activated: &[usize],
        src_gates: &[f32],
        depth: usize,
        k: usize,
    ) -> Vec<(usize, Vec<usize>)> {
        let mut out = Vec::with_capacity(depth);
        let mut layer = src_layer;
        let mut act = src_activated.to_vec();
        let mut gates = src_gates.to_vec();
        for _ in 0..depth {
            let probs = self.predict_probs(ctx, layer, &act, &gates);
            let guess = top_k_stable(&probs, k);
            let tl = self.target_layer(layer);
            let wsum: f32 = guess.iter().map(|&e| probs[e]).sum::<f32>().max(1e-6);
            gates = guess.iter().map(|&e| probs[e] / wsum).collect();
            act.clone_from(&guess);
            out.push((tl, guess));
            layer = tl;
        }
        out
    }

    // -- serialization ------------------------------------------------

    pub fn to_json(&self) -> Value {
        let weights = Value::Arr(
            self.w
                .iter()
                .map(|layer| {
                    Value::Arr(
                        layer
                            .iter()
                            .map(|row| {
                                Value::Arr(
                                    row.iter().map(|&x| Value::Num(x as f64)).collect(),
                                )
                            })
                            .collect(),
                    )
                })
                .collect(),
        );
        Value::obj(vec![
            ("format", WEIGHTS_FORMAT.into()),
            ("n_layers", self.n_layers.into()),
            ("n_experts", self.n_experts.into()),
            ("fast_decay", (FAST_DECAY as f64).into()),
            ("slow_decay", (SLOW_DECAY as f64).into()),
            ("weights", weights),
        ])
    }

    /// Strict deserialization: format tag, dimensions, and every weight
    /// (finite numbers only) are validated, so a truncated or mismatched
    /// weights file is a clean error instead of a panic later.
    pub fn from_json(v: &Value) -> Result<Self> {
        match v.get("format").as_str() {
            Some(WEIGHTS_FORMAT) => {}
            other => bail!("predictor weights: bad format tag {other:?}"),
        }
        let n_layers =
            v.get("n_layers").as_usize().ok_or_else(|| anyhow!("predictor weights: n_layers"))?;
        let n_experts =
            v.get("n_experts").as_usize().ok_or_else(|| anyhow!("predictor weights: n_experts"))?;
        let mut pred = Self::new_zeroed(n_layers, n_experts)?;
        let f = Self::feature_count(n_experts);
        let layers =
            v.get("weights").as_arr().ok_or_else(|| anyhow!("predictor weights: weights"))?;
        if layers.len() != n_layers {
            bail!("predictor weights: {} layer blocks, expected {n_layers}", layers.len());
        }
        for (l, block) in layers.iter().enumerate() {
            let rows = block
                .as_arr()
                .ok_or_else(|| anyhow!("predictor weights: layer {l} not an array"))?;
            if rows.len() != n_experts {
                bail!("predictor weights: layer {l} has {} rows, expected {n_experts}", rows.len());
            }
            for (e, row) in rows.iter().enumerate() {
                let row = row
                    .as_f32_vec()
                    .ok_or_else(|| anyhow!("predictor weights: layer {l} row {e} not numeric"))?;
                if row.len() != f {
                    bail!(
                        "predictor weights: layer {l} row {e} has {} features, expected {f}",
                        row.len()
                    );
                }
                if row.iter().any(|x| !x.is_finite()) {
                    bail!("predictor weights: non-finite value in layer {l} row {e}");
                }
                pred.w[l][e] = row;
            }
        }
        Ok(pred)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        crate::trace::export::write_file(path, &json::to_string(&self.to_json()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading predictor weights {}: {e}", path.display()))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow!("parsing predictor weights {}: {e}", path.display()))?;
        Self::from_json(&v)
    }
}

/// Resolve an optional `--predictor-weights` value the way every entry
/// point (CLI and serve) does. An explicit path must load and match
/// `n_layers`×`n_experts` — a hard error otherwise. Without an explicit
/// path, [`DEFAULT_WEIGHTS_PATH`] is tried only when `wanted` (the
/// learned policy or prefetch source is active), and its absence degrades
/// gracefully with a note on stderr: learned eviction falls back to LFU
/// ordering, learned prefetch stays idle.
pub fn load_optional(
    explicit: Option<&str>,
    wanted: bool,
    n_layers: usize,
    n_experts: usize,
) -> Result<Option<LearnedPredictor>> {
    let path = match explicit {
        Some(p) => Path::new(p).to_path_buf(),
        None if wanted => Path::new(DEFAULT_WEIGHTS_PATH).to_path_buf(),
        None => return Ok(None),
    };
    if explicit.is_none() && !path.is_file() {
        eprintln!(
            "note: {} absent; learned eviction degrades to LFU and learned prefetch is idle \
             (train weights with `moe-offload train-predictor`)",
            path.display()
        );
        return Ok(None);
    }
    let p = LearnedPredictor::load(&path)?;
    if p.n_layers() != n_layers || p.n_experts() != n_experts {
        bail!(
            "predictor weights {} are {}x{} (layers x experts) but the model is {}x{}",
            path.display(),
            p.n_layers(),
            p.n_experts(),
            n_layers,
            n_experts
        );
    }
    Ok(Some(p))
}

fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z.clamp(-30.0, 30.0)).exp())
}

/// Result of [`train_on_trace`].
pub struct TrainOutcome {
    pub predictor: LearnedPredictor,
    /// Boundary samples consumed across all epochs.
    pub samples: u64,
    /// Records dropped for out-of-range expert ids (counted once per
    /// epoch pass that sees them).
    pub skipped_records: u64,
}

/// One (source record -> target set) training/eval sample, or the reasons
/// to skip it. Shared between the trainer and the evaluator so both apply
/// identical boundary semantics.
fn target_of(trace: &Trace, t: usize, tl: usize) -> Option<usize> {
    if tl == 0 {
        // wrap boundary: target is the next token's first layer — skip at
        // the trace end and across sequence boundaries.
        let tt = t + 1;
        if tt >= trace.n_tokens() || trace.is_sequence_start(tt) {
            return None;
        }
        Some(tt)
    } else {
        Some(t)
    }
}

fn record_valid(trace: &Trace, t: usize, l: usize) -> bool {
    trace.at(t, l).activated.iter().all(|&e| e < trace.n_experts)
}

/// Deterministic offline SGD over every boundary sample in the trace.
/// Structural problems (an empty or single-layer trace) are an error;
/// individual records with out-of-range expert ids are skipped and
/// counted, mirroring [`crate::offload::predictor::MarkovPredictor`].
pub fn train_on_trace(trace: &Trace, cfg: &TrainConfig) -> Result<TrainOutcome> {
    if trace.n_tokens() == 0 {
        bail!("train_on_trace: empty trace");
    }
    let mut pred = LearnedPredictor::new_zeroed(trace.n_layers, trace.n_experts)?;
    let mut ctx = LearnedContext::new(trace.n_layers, trace.n_experts);
    let mut feat = Vec::new();
    let mut probs = Vec::new();
    let mut samples = 0u64;
    let mut skipped = 0u64;
    for _ in 0..cfg.epochs {
        ctx.reset();
        for t in 0..trace.n_tokens() {
            if trace.is_sequence_start(t) {
                ctx.reset();
            }
            for l in 0..trace.n_layers {
                let rec = trace.at(t, l);
                if !record_valid(trace, t, l) {
                    skipped += 1;
                    continue;
                }
                let tl = pred.target_layer(l);
                if let Some(tt) = target_of(trace, t, tl) {
                    if record_valid(trace, tt, tl) {
                        pred.features_into(&ctx, l, &rec.activated, &rec.weights, &mut feat);
                        pred.forward_into(l, &feat, &mut probs);
                        let target = &trace.at(tt, tl).activated;
                        for (e, row) in pred.w[l].iter_mut().enumerate() {
                            let y = if target.contains(&e) { 1.0 } else { 0.0 };
                            let g = cfg.lr * (probs[e] - y);
                            for (w, x) in row.iter_mut().zip(&feat) {
                                *w -= g * x;
                            }
                        }
                        samples += 1;
                    } else {
                        skipped += 1;
                    }
                }
                ctx.observe(l, &trace.at(t, l).activated);
            }
        }
    }
    Ok(TrainOutcome { predictor: pred, samples, skipped_records: skipped })
}

/// Guess quality of a trained predictor over a trace.
pub struct LearnedEval {
    pub overall: PrecisionRecall,
    /// Indexed by TARGET layer.
    pub per_layer: Vec<PrecisionRecall>,
    pub skipped_records: u64,
}

/// Walk the trace with a fresh context, scoring top-k guesses at every
/// boundary (same skip rules as training). Errors when the trace and
/// predictor dimensions disagree — the malformed-imported-trace case.
pub fn evaluate_on_trace(
    pred: &LearnedPredictor,
    trace: &Trace,
    k: usize,
) -> Result<LearnedEval> {
    if trace.n_layers != pred.n_layers() || trace.n_experts != pred.n_experts() {
        bail!(
            "evaluate: trace is {}x{} but predictor is {}x{}",
            trace.n_layers,
            trace.n_experts,
            pred.n_layers(),
            pred.n_experts()
        );
    }
    if trace.n_tokens() == 0 {
        bail!("evaluate: empty trace");
    }
    let mut ctx = LearnedContext::new(trace.n_layers, trace.n_experts);
    let mut feat = Vec::new();
    let mut probs = Vec::new();
    let mut overall = PrecisionRecall::default();
    let mut per_layer = vec![PrecisionRecall::default(); trace.n_layers];
    let mut skipped = 0u64;
    for t in 0..trace.n_tokens() {
        if trace.is_sequence_start(t) {
            ctx.reset();
        }
        for l in 0..trace.n_layers {
            let rec = trace.at(t, l);
            if !record_valid(trace, t, l) {
                skipped += 1;
                continue;
            }
            let tl = pred.target_layer(l);
            if let Some(tt) = target_of(trace, t, tl) {
                if record_valid(trace, tt, tl) {
                    pred.features_into(&ctx, l, &rec.activated, &rec.weights, &mut feat);
                    pred.forward_into(l, &feat, &mut probs);
                    let guess = top_k_stable(&probs, k);
                    let target = &trace.at(tt, tl).activated;
                    overall.record(&guess, target);
                    per_layer[tl].record(&guess, target);
                } else {
                    skipped += 1;
                }
            }
            ctx.observe(l, &trace.at(t, l).activated);
        }
    }
    Ok(LearnedEval { overall, per_layer, skipped_records: skipped })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::tracegen::{self, TraceGenConfig};

    fn cycle_trace(n_tokens: usize) -> Trace {
        // layer 1's activated set always equals layer 0's — a perfectly
        // learnable cross-layer dependency.
        let mut t = Trace::new(2, 8, 2);
        for i in 0..n_tokens {
            let set = if i % 2 == 0 { vec![0, 1] } else { vec![2, 3] };
            t.push_token(i as u32);
            for l in 0..2 {
                let rec = t.at_mut(i, l);
                rec.activated = set.clone();
                rec.weights = vec![0.6, 0.4];
            }
        }
        t
    }

    #[test]
    fn zero_weights_predict_half_everywhere() {
        let p = LearnedPredictor::new_zeroed(4, 8).unwrap();
        let ctx = LearnedContext::new(4, 8);
        let probs = p.predict_probs(&ctx, 0, &[1, 2], &[0.7, 0.3]);
        assert_eq!(probs, vec![0.5; 8]);
        // ties resolve to lowest indices
        assert_eq!(top_k_stable(&probs, 3), vec![0, 1, 2]);
    }

    #[test]
    fn learns_copy_dependency_across_layers() {
        let trace = cycle_trace(64);
        let out = train_on_trace(&trace, &TrainConfig::default()).unwrap();
        assert_eq!(out.skipped_records, 0);
        assert!(out.samples > 0);
        let ctx = LearnedContext::new(2, 8);
        // seeing {0,1} at layer 0 must predict {0,1} at layer 1
        let mut g = out.predictor.predict_next(&ctx, 0, &[0, 1], &[0.6, 0.4], 2);
        g.sort_unstable();
        assert_eq!(g, vec![0, 1]);
        let mut g = out.predictor.predict_next(&ctx, 0, &[2, 3], &[0.6, 0.4], 2);
        g.sort_unstable();
        assert_eq!(g, vec![2, 3]);
    }

    #[test]
    fn training_is_deterministic() {
        let trace = tracegen::generate(&TraceGenConfig {
            n_layers: 3,
            n_tokens: 50,
            ..Default::default()
        });
        let a = train_on_trace(&trace, &TrainConfig::default()).unwrap();
        let b = train_on_trace(&trace, &TrainConfig::default()).unwrap();
        assert_eq!(a.predictor, b.predictor); // bitwise f32 equality
        assert_eq!(a.samples, b.samples);
    }

    #[test]
    fn beats_chance_on_generated_trace() {
        let mut trace = tracegen::generate(&TraceGenConfig {
            n_layers: 4,
            n_tokens: 400,
            locality: 0.3,
            ..Default::default()
        });
        let eval_half = trace.records.split_off(200);
        let mut eval_trace = Trace::new(4, 8, 2);
        eval_trace.records = eval_half;
        eval_trace.tokens = trace.tokens.split_off(200);
        let out = train_on_trace(&trace, &TrainConfig::default()).unwrap();
        let eval = evaluate_on_trace(&out.predictor, &eval_trace, 2).unwrap();
        // chance precision for top-2-of-8 = 0.25
        assert!(eval.overall.precision() > 0.3, "precision {}", eval.overall.precision());
        assert_eq!(eval.skipped_records, 0);
        assert_eq!(eval.per_layer.len(), 4);
    }

    #[test]
    fn malformed_records_skip_and_count() {
        let mut trace = cycle_trace(8);
        trace.at_mut(3, 1).activated = vec![0, 99]; // out of range
        let out = train_on_trace(&trace, &TrainConfig { epochs: 1, lr: 0.1 }).unwrap();
        // the bad record is skipped as source AND as target
        assert!(out.skipped_records >= 2, "skipped {}", out.skipped_records);
    }

    #[test]
    fn sequence_boundary_skips_wrap_sample() {
        let mut trace = cycle_trace(8);
        trace.seq_breaks = vec![4];
        let with_break = train_on_trace(&trace, &TrainConfig { epochs: 1, lr: 0.1 }).unwrap();
        trace.seq_breaks.clear();
        let without = train_on_trace(&trace, &TrainConfig { epochs: 1, lr: 0.1 }).unwrap();
        assert_eq!(with_break.samples + 1, without.samples);
    }

    #[test]
    fn weights_round_trip_bitwise() {
        let trace = cycle_trace(32);
        let out = train_on_trace(&trace, &TrainConfig::default()).unwrap();
        let text = json::to_string(&out.predictor.to_json());
        let back = LearnedPredictor::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, out.predictor);
    }

    #[test]
    fn from_json_rejects_malformed() {
        let p = LearnedPredictor::new_zeroed(2, 4).unwrap();
        let mut v = p.to_json();
        assert!(LearnedPredictor::from_json(&v).is_ok());
        // wrong format tag
        if let Value::Obj(o) = &mut v {
            o.insert("format".into(), "nope".into());
        }
        assert!(LearnedPredictor::from_json(&v).is_err());
        // truncated weights
        let mut v = p.to_json();
        if let Value::Obj(o) = &mut v {
            o.insert("weights".into(), Value::Arr(vec![]));
        }
        assert!(LearnedPredictor::from_json(&v).is_err());
        // dimension lies
        let mut v = p.to_json();
        if let Value::Obj(o) = &mut v {
            o.insert("n_experts".into(), 8usize.into());
        }
        assert!(LearnedPredictor::from_json(&v).is_err());
    }

    #[test]
    fn committed_weights_load_and_round_trip() {
        // the checked-in default weights must parse, match the default
        // model geometry (12 layers × 8 experts), and survive a
        // serialize/parse round trip bitwise — CI runs this against the
        // artifact on every checkout.
        let path =
            Path::new(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_WEIGHTS_PATH);
        let p = LearnedPredictor::load(&path).expect("committed weights must load");
        let mc = crate::model::ModelConfig::DEFAULT;
        assert_eq!(p.n_layers(), mc.n_layers);
        assert_eq!(p.n_experts(), mc.n_experts);
        let back = LearnedPredictor::from_json(
            &json::parse(&json::to_string(&p.to_json())).unwrap(),
        )
        .unwrap();
        assert_eq!(back, p);
        // trained weights, not a zeroed placeholder
        assert!(
            p.w.iter().flatten().flatten().any(|&x| x != 0.0),
            "committed weights are all zero"
        );
    }

    #[test]
    fn rollout_covers_requested_depth_and_wraps() {
        let trace = cycle_trace(32);
        let out = train_on_trace(&trace, &TrainConfig::default()).unwrap();
        let ctx = LearnedContext::new(2, 8);
        let ro = out.predictor.rollout(&ctx, 0, &[0, 1], &[0.6, 0.4], 3, 2);
        assert_eq!(ro.len(), 3);
        assert_eq!(ro[0].0, 1); // layer 0 -> 1
        assert_eq!(ro[1].0, 0); // wrap to next token's layer 0
        assert_eq!(ro[2].0, 1);
        for (_, guess) in &ro {
            assert_eq!(guess.len(), 2);
        }
    }
}
