//! Transfer engine: move one expert from the host store onto the device.
//!
//! A transfer has two real halves (dequantize on CPU, upload into a PJRT
//! buffer) plus a simulated half: the time the same bytes would take over
//! the profile's PCIe link, charged by the caller to the
//! [`SimClock`](crate::util::simclock::SimClock) via the returned
//! [`TransferReceipt`]. A serialized bus model lives here too:
//! concurrent transfers (prefetch + demand) queue behind each other, which
//! is exactly the §6.1 "competes for bandwidth" effect.

use crate::metrics::TransferStats;
use crate::offload::pipeline::BufferPool;
use crate::offload::store::HostExpertStore;
use crate::runtime::{Backend, ExpertHandle};
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct TransferReceipt {
    pub bytes: usize,
    /// Wallclock cost actually measured on this host.
    pub dequant_ns: u64,
    pub upload_ns: u64,
}

pub struct TransferEngine {
    pub store: Arc<HostExpertStore>,
    pub stats: TransferStats,
    /// Shared f32 buffer pool: dequant targets come from here and return
    /// here when the cache evicts the resulting `ExpertHandle::Host`.
    pool: Arc<BufferPool>,
    /// Simulated time at which the PCIe bus becomes free.
    bus_free_at: f64,
}

impl TransferEngine {
    pub fn new(store: Arc<HostExpertStore>, pool: Arc<BufferPool>) -> Self {
        TransferEngine { store, stats: TransferStats::default(), pool, bus_free_at: 0.0 }
    }

    /// Perform the real transfer work (dequant into pooled buffers +
    /// upload).
    pub fn fetch(
        &mut self,
        backend: &dyn Backend,
        layer: usize,
        expert: usize,
    ) -> Result<(ExpertHandle, TransferReceipt)> {
        let t0 = Instant::now();
        let (w1, w3, w2) = self.store.fetch_pooled(&self.pool, layer, expert);
        let dequant_ns = t0.elapsed().as_nanos() as u64;

        let t1 = Instant::now();
        let handle = backend.upload_expert(w1, w3, w2)?;
        let upload_ns = t1.elapsed().as_nanos() as u64;

        let bytes = self.store.expert_transfer_bytes();
        self.stats.record(bytes);
        self.stats.dequant_ns += dequant_ns;
        self.stats.upload_ns += upload_ns;
        Ok((handle, TransferReceipt { bytes, dequant_ns, upload_ns }))
    }

    /// Account one expert's bytes at simulated-bus reservation time. Byte
    /// accounting is tied to bus reservations, not dequant completions, so
    /// sync and pipelined runs report identical transfer volume: a
    /// pipelined prefetch records here at issue (even if its queued job is
    /// later cancelled — the bus reservation stands), and a demand that
    /// *joins* it records nothing further.
    pub fn record_scheduled(&mut self) {
        let bytes = self.store.expert_transfer_bytes();
        self.stats.record(bytes);
    }

    /// Account the engine-thread upload half of a pipeline-delivered
    /// transfer (bytes were recorded at reservation time).
    pub fn record_upload_ns(&mut self, upload_ns: u64) {
        self.stats.upload_ns += upload_ns;
    }

    /// Reserve the simulated bus for a transfer of `dur` seconds starting
    /// no earlier than `now`. Returns the completion time.
    pub fn schedule_bus(&mut self, now: f64, dur: f64) -> f64 {
        let start = now.max(self.bus_free_at);
        self.bus_free_at = start + dur;
        self.bus_free_at
    }

    pub fn reset_bus(&mut self) {
        self.bus_free_at = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::synth_weights;
    use crate::model::ModelConfig;
    use crate::quant::Scheme;
    use crate::runtime::native::NativeBackend;

    fn engine() -> (TransferEngine, NativeBackend) {
        let w = Arc::new(synth_weights(ModelConfig::TINY, |_, i| (i % 7) as f32 * 0.01));
        let store = Arc::new(HostExpertStore::build(&w, Scheme::Int8 { block: 16 }).unwrap());
        (TransferEngine::new(store, BufferPool::new()), NativeBackend::new(w))
    }

    #[test]
    fn fetch_returns_handle_and_counts() {
        let (mut te, be) = engine();
        let (handle, receipt) = te.fetch(&be, 0, 3).unwrap();
        assert!(matches!(handle, ExpertHandle::Host { .. }));
        assert_eq!(receipt.bytes, te.store.expert_transfer_bytes());
        assert_eq!(te.stats.transfers, 1);
        assert_eq!(te.stats.bytes, receipt.bytes as u64);
    }

    #[test]
    fn pooled_fetch_recycles_released_buffers() {
        let w = Arc::new(synth_weights(ModelConfig::TINY, |_, i| (i % 7) as f32 * 0.01));
        let store = Arc::new(HostExpertStore::build(&w, Scheme::Int8 { block: 16 }).unwrap());
        let pool = BufferPool::new();
        let mut te = TransferEngine::new(store, Arc::clone(&pool));
        let be = NativeBackend::new(w);
        let (h, _) = te.fetch(&be, 0, 0).unwrap();
        assert_eq!(pool.allocs(), 3);
        // recycle the handle's buffers the way the cache-eviction path does
        let ExpertHandle::Host { w1, w3, w2 } = h else { panic!("native handle") };
        pool.release(w1);
        pool.release(w3);
        pool.release(w2);
        let _ = te.fetch(&be, 0, 1).unwrap();
        assert_eq!(pool.allocs(), 3, "steady state must not allocate");
        assert_eq!(pool.reuses(), 3);
    }

    #[test]
    fn bus_serializes() {
        let (mut te, _) = engine();
        let end1 = te.schedule_bus(0.0, 1.0);
        let end2 = te.schedule_bus(0.5, 1.0); // requested mid-flight: queues
        assert_eq!(end1, 1.0);
        assert_eq!(end2, 2.0);
        let end3 = te.schedule_bus(5.0, 1.0); // idle bus: starts immediately
        assert_eq!(end3, 6.0);
    }
}
