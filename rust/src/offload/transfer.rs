//! Transfer engine: move one expert from the host store onto the device.
//!
//! A transfer has two real halves (dequantize on CPU, upload into a PJRT
//! buffer) plus a simulated half: the time the same bytes would take over
//! the profile's PCIe link, charged by the caller to the
//! [`SimClock`](crate::util::simclock::SimClock) via the returned
//! [`TransferReceipt`]. A serialized bus model lives here too:
//! concurrent transfers (prefetch + demand) queue behind each other, which
//! is exactly the §6.1 "competes for bandwidth" effect.

use crate::metrics::TransferStats;
use crate::offload::pipeline::BufferPool;
use crate::offload::store::HostExpertStore;
use crate::runtime::{Backend, ExpertHandle};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct TransferReceipt {
    pub bytes: usize,
    /// Wallclock cost actually measured on this host.
    pub dequant_ns: u64,
    pub upload_ns: u64,
}

/// The fault to inject on fetches of one `(layer, expert)`: an extra
/// virtual stall before the transfer, a budget of transient failures
/// (consumed one per attempt), or a permanent failure.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultSpec {
    /// Extra simulated seconds the transfer stalls before starting.
    pub delay_s: f64,
    /// Remaining attempts that fail transiently (retryable).
    pub transient_fails: u32,
    /// Every attempt fails (non-retryable).
    pub permanent: bool,
}

/// What the fault layer decided for one fetch attempt.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// Fetch normally, after charging `extra_delay_s` of virtual stall
    /// (0.0 for unfaulted experts).
    Proceed { extra_delay_s: f64 },
    /// This attempt fails; a retry may succeed.
    TransientFail,
    /// Every attempt fails.
    PermanentFail,
}

/// Deterministic fault-injection plan for the transfer path (tests and
/// benches only — the default plan is empty and free). Faults are keyed
/// by `(layer, expert)` and consulted on the engine thread at demand-miss
/// time, so injection is identical under synchronous and pipelined
/// transfers. Built either explicitly (`stall_ms`, `fail_transient`,
/// `fail_permanent`) or pseudo-randomly from the seed (`scatter_transient`)
/// so randomized runs replay exactly.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    faults: HashMap<(usize, usize), FaultSpec>,
}

impl FaultPlan {
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan { seed, faults: HashMap::new() }
    }

    /// Stall every fetch of `(layer, expert)` by `ms` virtual milliseconds.
    pub fn stall_ms(mut self, layer: usize, expert: usize, ms: f64) -> FaultPlan {
        self.faults.entry((layer, expert)).or_default().delay_s = ms / 1e3;
        self
    }

    /// Fail the next `n` fetch attempts of `(layer, expert)` transiently.
    pub fn fail_transient(mut self, layer: usize, expert: usize, n: u32) -> FaultPlan {
        self.faults.entry((layer, expert)).or_default().transient_fails = n;
        self
    }

    /// Fail every fetch attempt of `(layer, expert)`.
    pub fn fail_permanent(mut self, layer: usize, expert: usize) -> FaultPlan {
        self.faults.entry((layer, expert)).or_default().permanent = true;
        self
    }

    /// Seed-derived scatter: mark `count` pseudo-random `(layer, expert)`
    /// pairs to fail their next `fails_each` attempts transiently.
    pub fn scatter_transient(
        mut self,
        n_layers: usize,
        n_experts: usize,
        count: usize,
        fails_each: u32,
    ) -> FaultPlan {
        let mut x = self.seed | 1;
        let mut placed = 0;
        // bounded walk: xorshift64 is a full-period generator, so distinct
        // pairs keep appearing as long as count <= n_layers * n_experts
        while placed < count.min(n_layers * n_experts) {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let key = ((x as usize >> 8) % n_layers, (x as usize >> 40) % n_experts);
            if !self.faults.contains_key(&key) {
                self.faults.insert(key, FaultSpec { transient_fails: fails_each, ..Default::default() });
                placed += 1;
            }
        }
        self
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Decide one fetch attempt of `(layer, expert)`, consuming a
    /// transient-failure budget entry if one is armed.
    pub fn check(&mut self, layer: usize, expert: usize) -> FaultAction {
        match self.faults.get_mut(&(layer, expert)) {
            None => FaultAction::Proceed { extra_delay_s: 0.0 },
            Some(f) if f.permanent => FaultAction::PermanentFail,
            Some(f) if f.transient_fails > 0 => {
                f.transient_fails -= 1;
                FaultAction::TransientFail
            }
            Some(f) => FaultAction::Proceed { extra_delay_s: f.delay_s },
        }
    }

    /// Transient failures still armed for `(layer, expert)`, WITHOUT
    /// consuming any. The engine's deadline gate uses this to price the
    /// retry backoff a fetch would pay before deciding whether to degrade
    /// — a breach must leave the budget untouched.
    pub fn pending_transients(&self, layer: usize, expert: usize) -> u32 {
        self.faults.get(&(layer, expert)).map_or(0, |f| f.transient_fails)
    }

    /// The virtual stall a proceeding fetch of `(layer, expert)` would be
    /// charged, without consuming anything. (Permanently-failing experts
    /// never proceed, so their delay is irrelevant to the estimate.)
    pub fn peek_delay(&self, layer: usize, expert: usize) -> f64 {
        self.faults.get(&(layer, expert)).map_or(0.0, |f| f.delay_s)
    }
}

pub struct TransferEngine {
    pub store: Arc<HostExpertStore>,
    pub stats: TransferStats,
    /// Test/bench fault hook, consulted by the engine on every demand miss
    /// (empty — and free — in production).
    pub fault: FaultPlan,
    /// Shared f32 buffer pool: dequant targets come from here and return
    /// here when the cache evicts the resulting `ExpertHandle::Host`.
    pool: Arc<BufferPool>,
    /// Simulated time at which the PCIe bus becomes free.
    bus_free_at: f64,
}

impl TransferEngine {
    pub fn new(store: Arc<HostExpertStore>, pool: Arc<BufferPool>) -> Self {
        TransferEngine {
            store,
            stats: TransferStats::default(),
            fault: FaultPlan::default(),
            pool,
            bus_free_at: 0.0,
        }
    }

    /// Install a [`FaultPlan`] (replacing any previous one).
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.fault = plan;
    }

    /// Perform the real transfer work (dequant into pooled buffers +
    /// upload).
    pub fn fetch(
        &mut self,
        backend: &dyn Backend,
        layer: usize,
        expert: usize,
    ) -> Result<(ExpertHandle, TransferReceipt)> {
        let t0 = Instant::now();
        let (w1, w3, w2) = self.store.fetch_pooled(&self.pool, layer, expert);
        let dequant_ns = t0.elapsed().as_nanos() as u64;

        let t1 = Instant::now();
        let handle = backend.upload_expert(w1, w3, w2)?;
        let upload_ns = t1.elapsed().as_nanos() as u64;

        let bytes = self.store.expert_transfer_bytes();
        self.stats.record(bytes);
        self.stats.dequant_ns += dequant_ns;
        self.stats.upload_ns += upload_ns;
        Ok((handle, TransferReceipt { bytes, dequant_ns, upload_ns }))
    }

    /// Account one expert's bytes at simulated-bus reservation time. Byte
    /// accounting is tied to bus reservations, not dequant completions, so
    /// sync and pipelined runs report identical transfer volume: a
    /// pipelined prefetch records here at issue (even if its queued job is
    /// later cancelled — the bus reservation stands), and a demand that
    /// *joins* it records nothing further.
    pub fn record_scheduled(&mut self) {
        let bytes = self.store.expert_transfer_bytes();
        self.stats.record(bytes);
    }

    /// Account the engine-thread upload half of a pipeline-delivered
    /// transfer (bytes were recorded at reservation time).
    pub fn record_upload_ns(&mut self, upload_ns: u64) {
        self.stats.upload_ns += upload_ns;
    }

    /// Reserve the simulated bus for a transfer of `dur` seconds starting
    /// no earlier than `now`. Returns the completion time.
    pub fn schedule_bus(&mut self, now: f64, dur: f64) -> f64 {
        let start = now.max(self.bus_free_at);
        self.bus_free_at = start + dur;
        self.bus_free_at
    }

    pub fn reset_bus(&mut self) {
        self.bus_free_at = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::synth_weights;
    use crate::model::ModelConfig;
    use crate::quant::Scheme;
    use crate::runtime::native::NativeBackend;

    fn engine() -> (TransferEngine, NativeBackend) {
        let w = Arc::new(synth_weights(ModelConfig::TINY, |_, i| (i % 7) as f32 * 0.01));
        let store = Arc::new(HostExpertStore::build(&w, Scheme::Int8 { block: 16 }).unwrap());
        (TransferEngine::new(store, BufferPool::new()), NativeBackend::new(w))
    }

    #[test]
    fn fetch_returns_handle_and_counts() {
        let (mut te, be) = engine();
        let (handle, receipt) = te.fetch(&be, 0, 3).unwrap();
        assert!(matches!(handle, ExpertHandle::Host { .. }));
        assert_eq!(receipt.bytes, te.store.expert_transfer_bytes());
        assert_eq!(te.stats.transfers, 1);
        assert_eq!(te.stats.bytes, receipt.bytes as u64);
    }

    #[test]
    fn pooled_fetch_recycles_released_buffers() {
        let w = Arc::new(synth_weights(ModelConfig::TINY, |_, i| (i % 7) as f32 * 0.01));
        let store = Arc::new(HostExpertStore::build(&w, Scheme::Int8 { block: 16 }).unwrap());
        let pool = BufferPool::new();
        let mut te = TransferEngine::new(store, Arc::clone(&pool));
        let be = NativeBackend::new(w);
        let (h, _) = te.fetch(&be, 0, 0).unwrap();
        assert_eq!(pool.allocs(), 3);
        // recycle the handle's buffers the way the cache-eviction path does
        let ExpertHandle::Host { w1, w3, w2 } = h else { panic!("native handle") };
        pool.release(w1);
        pool.release(w3);
        pool.release(w2);
        let _ = te.fetch(&be, 0, 1).unwrap();
        assert_eq!(pool.allocs(), 3, "steady state must not allocate");
        assert_eq!(pool.reuses(), 3);
    }

    #[test]
    fn fault_plan_consumes_transients_then_proceeds() {
        let mut plan = FaultPlan::seeded(7).fail_transient(2, 5, 2).stall_ms(2, 5, 50.0);
        assert_eq!(plan.check(2, 5), FaultAction::TransientFail);
        assert_eq!(plan.check(2, 5), FaultAction::TransientFail);
        // transients exhausted: the stall still applies on the attempt
        // that finally proceeds
        match plan.check(2, 5) {
            FaultAction::Proceed { extra_delay_s } => {
                assert!((extra_delay_s - 0.05).abs() < 1e-12)
            }
            other => panic!("expected Proceed, got {other:?}"),
        }
        // unfaulted experts are free
        assert_eq!(plan.check(0, 0), FaultAction::Proceed { extra_delay_s: 0.0 });
        // permanent failures never clear
        let mut perm = FaultPlan::seeded(0).fail_permanent(1, 1);
        assert_eq!(perm.check(1, 1), FaultAction::PermanentFail);
        assert_eq!(perm.check(1, 1), FaultAction::PermanentFail);
    }

    #[test]
    fn fault_plan_peekers_are_side_effect_free() {
        let plan = FaultPlan::seeded(1).fail_transient(0, 2, 3).stall_ms(0, 2, 25.0);
        assert_eq!(plan.pending_transients(0, 2), 3);
        assert_eq!(plan.pending_transients(0, 2), 3, "peek must not consume");
        assert!((plan.peek_delay(0, 2) - 0.025).abs() < 1e-12);
        assert_eq!(plan.pending_transients(5, 5), 0);
        assert_eq!(plan.peek_delay(5, 5), 0.0);
        // consuming check() drains what the peekers report
        let mut plan = plan;
        let _ = plan.check(0, 2);
        assert_eq!(plan.pending_transients(0, 2), 2);
        assert!((plan.peek_delay(0, 2) - 0.025).abs() < 1e-12);
    }

    #[test]
    fn fault_plan_scatter_is_seed_deterministic() {
        let a = FaultPlan::seeded(42).scatter_transient(12, 8, 5, 2);
        let b = FaultPlan::seeded(42).scatter_transient(12, 8, 5, 2);
        assert_eq!(a.faults.len(), 5);
        for (k, v) in &a.faults {
            let bv = b.faults.get(k).expect("same seed, same keys");
            assert_eq!(v.transient_fails, bv.transient_fails);
        }
        let c = FaultPlan::seeded(43).scatter_transient(12, 8, 5, 2);
        assert!(
            a.faults.keys().any(|k| !c.faults.contains_key(k))
                || a.faults.len() != c.faults.len(),
            "different seeds should scatter differently"
        );
    }

    #[test]
    fn bus_serializes() {
        let (mut te, _) = engine();
        let end1 = te.schedule_bus(0.0, 1.0);
        let end2 = te.schedule_bus(0.5, 1.0); // requested mid-flight: queues
        assert_eq!(end1, 1.0);
        assert_eq!(end2, 2.0);
        let end3 = te.schedule_bus(5.0, 1.0); // idle bus: starts immediately
        assert_eq!(end3, 6.0);
    }
}
