//! Speculative expert pre-fetching (paper §3.2, §4.3).
//!
//! While processing layer *l*, apply layer *l+1*'s gating network to the
//! hidden states that came out of layer *l*'s attention block ("transformer
//! layers are residual … an accurate guess of next layer's experts"). The
//! top-k guesses are transferred ahead of time into layer *l+1*'s cache,
//! where — if correct — the demand lookup one layer later hits without a
//! stall. Wrong guesses cost bandwidth and cache space, the trade-off the
//! paper's §6.1 discusses.

use crate::metrics::PrecisionRecall;
use crate::model::sampler::top_k;
use crate::runtime::Backend;
use anyhow::Result;

#[derive(Clone, Copy, Debug)]
pub struct PrefetchConfig {
    pub enabled: bool,
    /// How many experts to guess per layer (paper: K = top_k = 2).
    pub k: usize,
}

/// A speculative guess tagged with the decode session that issued it.
///
/// Under concurrent serving, tokens from different sessions interleave on
/// one engine; the tag keeps each guess scored against the activations of
/// the session that produced the hidden states, so speculative
/// precision/recall stays meaningful per session (and in aggregate).
#[derive(Clone, Debug)]
pub struct TaggedGuess {
    pub session: u64,
    /// Layer the guess is *for* (the issuing layer + 1).
    pub layer: usize,
    pub experts: Vec<usize>,
}

/// An in-flight prefetch transfer on the simulated bus, tagged with the
/// session that issued it. When a *different* session's demand lookup lands
/// on the prefetched expert, that is a cross-session prefetch hit — the
/// shared-cache amortization effect the serve layer reports.
#[derive(Clone, Copy, Debug)]
pub struct PendingPrefetch {
    pub session: u64,
    pub layer: usize,
    pub expert: usize,
    /// Simulated completion time on the bus.
    pub done_at: f64,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig { enabled: false, k: 2 }
    }
}

/// Tracks guesses so they can be scored against the truth one layer later.
#[derive(Default)]
pub struct SpeculativeScorer {
    pub pr: PrecisionRecall,
}

impl SpeculativeScorer {
    /// Score a guess once the true activations for that layer are known.
    pub fn settle(&mut self, guessed: &[usize], activated: &[usize]) {
        self.pr.record(guessed, activated);
    }
}

/// Compute the speculative guess for `next_layer` from `x_res` (the hidden
/// states after the current layer's attention+MoE residual).
pub fn guess_next_layer(
    backend: &dyn Backend,
    next_layer: usize,
    x_res: &[f32],
    k: usize,
) -> Result<Vec<usize>> {
    let probs = backend.spec_router(next_layer, x_res)?;
    Ok(top_k(&probs, k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::generate_weights;
    use crate::model::ModelConfig;
    use crate::runtime::native::NativeBackend;
    use std::sync::Arc;

    #[test]
    fn guess_is_valid_topk() {
        let w = Arc::new(generate_weights(ModelConfig::TINY, 5));
        let be = NativeBackend::new(w);
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.3).sin()).collect();
        let g = guess_next_layer(&be, 1, &x, 2).unwrap();
        assert_eq!(g.len(), 2);
        assert_ne!(g[0], g[1]);
        assert!(g.iter().all(|&e| e < 8));
    }

    #[test]
    fn guess_matches_actual_router_on_same_input() {
        // structural identity: spec_router(l, x) == router(l, x).probs,
        // so guessing with the true next-layer input is always perfect.
        let w = Arc::new(generate_weights(ModelConfig::TINY, 6));
        let be = NativeBackend::new(w);
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.7).cos()).collect();
        use crate::runtime::Backend as _;
        let (_, probs) = be.router(1, &x).unwrap();
        let direct = top_k(&probs, 2);
        let guessed = guess_next_layer(&be, 1, &x, 2).unwrap();
        assert_eq!(direct, guessed);
    }

    #[test]
    fn scorer_accumulates() {
        let mut s = SpeculativeScorer::default();
        s.settle(&[1, 2], &[2, 3]);
        s.settle(&[4, 5], &[4, 5]);
        assert_eq!(s.pr.tp, 3);
        assert_eq!(s.pr.fp, s.pr.fn_);
    }
}
