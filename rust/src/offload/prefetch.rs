//! Speculative expert pre-fetching (paper §3.2, §4.3).
//!
//! While processing layer *l*, apply layer *l+1*'s gating network to the
//! hidden states that came out of layer *l*'s attention block ("transformer
//! layers are residual … an accurate guess of next layer's experts"). The
//! top-k guesses are transferred ahead of time into layer *l+1*'s cache,
//! where — if correct — the demand lookup one layer later hits without a
//! stall. Wrong guesses cost bandwidth and cache space, the trade-off the
//! paper's §6.1 discusses.

use crate::metrics::PrecisionRecall;
use crate::model::sampler::top_k;
use crate::runtime::Backend;
use anyhow::Result;

#[derive(Clone, Copy, Debug)]
pub struct PrefetchConfig {
    pub enabled: bool,
    /// How many experts to guess per layer (paper: K = top_k = 2).
    pub k: usize,
}

/// Which signal drives prefetch guesses (`--prefetch-source`). All three
/// feed the same issue/settle pipeline and the same pending-transfer
/// records, so their hit rates are directly comparable in `/metrics`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefetchSource {
    /// Speculative gating (paper §3.2): apply layer *l+1*'s gate to layer
    /// *l*'s hidden states. Most accurate, one layer of lead.
    Gate,
    /// Online first-order Markov model ([`crate::offload::predictor`]):
    /// whole-token lead, no model access, learns as it serves.
    Markov,
    /// Offline-trained cross-layer model ([`crate::offload::learned`]):
    /// whole-token lead from committed weights, shared with the learned
    /// eviction policy's scoreboard.
    Learned,
}

impl PrefetchSource {
    pub const ALL: [PrefetchSource; 3] =
        [PrefetchSource::Gate, PrefetchSource::Markov, PrefetchSource::Learned];

    pub fn parse(s: &str) -> Option<PrefetchSource> {
        match s.to_ascii_lowercase().as_str() {
            "gate" | "spec" | "speculative" => Some(PrefetchSource::Gate),
            "markov" => Some(PrefetchSource::Markov),
            "learned" => Some(PrefetchSource::Learned),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            PrefetchSource::Gate => "gate",
            PrefetchSource::Markov => "markov",
            PrefetchSource::Learned => "learned",
        }
    }
    /// Dense index for per-source counter arrays.
    pub fn idx(&self) -> usize {
        match self {
            PrefetchSource::Gate => 0,
            PrefetchSource::Markov => 1,
            PrefetchSource::Learned => 2,
        }
    }
}

/// A speculative guess tagged with the decode session that issued it.
///
/// Under concurrent serving, tokens from different sessions interleave on
/// one engine; the tag keeps each guess scored against the activations of
/// the session that produced the hidden states, so speculative
/// precision/recall stays meaningful per session (and in aggregate).
#[derive(Clone, Debug)]
pub struct TaggedGuess {
    pub session: u64,
    /// Layer the guess is *for* (the issuing layer + 1).
    pub layer: usize,
    pub experts: Vec<usize>,
}

/// An in-flight prefetch transfer on the simulated bus, tagged with the
/// session that issued it. When a *different* session's demand lookup lands
/// on the prefetched expert, that is a cross-session prefetch hit — the
/// shared-cache amortization effect the serve layer reports.
#[derive(Clone, Copy, Debug)]
pub struct PendingPrefetch {
    pub session: u64,
    pub layer: usize,
    pub expert: usize,
    /// Which guesser paid for this transfer — per-source hit attribution
    /// in `/metrics` rides on the tag surviving until the hit lands.
    pub source: PrefetchSource,
    /// Simulated completion time on the bus.
    pub done_at: f64,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig { enabled: false, k: 2 }
    }
}

/// Tracks guesses so they can be scored against the truth one layer later.
#[derive(Default)]
pub struct SpeculativeScorer {
    pub pr: PrecisionRecall,
}

impl SpeculativeScorer {
    /// Score a guess once the true activations for that layer are known.
    pub fn settle(&mut self, guessed: &[usize], activated: &[usize]) {
        self.pr.record(guessed, activated);
    }
}

/// Compute the speculative guess for `next_layer` from `x_res` (the hidden
/// states after the current layer's attention+MoE residual).
pub fn guess_next_layer(
    backend: &dyn Backend,
    next_layer: usize,
    x_res: &[f32],
    k: usize,
) -> Result<Vec<usize>> {
    let probs = backend.spec_router(next_layer, x_res)?;
    Ok(top_k(&probs, k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::generate_weights;
    use crate::model::ModelConfig;
    use crate::runtime::native::NativeBackend;
    use std::sync::Arc;

    #[test]
    fn guess_is_valid_topk() {
        let w = Arc::new(generate_weights(ModelConfig::TINY, 5));
        let be = NativeBackend::new(w);
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.3).sin()).collect();
        let g = guess_next_layer(&be, 1, &x, 2).unwrap();
        assert_eq!(g.len(), 2);
        assert_ne!(g[0], g[1]);
        assert!(g.iter().all(|&e| e < 8));
    }

    #[test]
    fn guess_matches_actual_router_on_same_input() {
        // structural identity: spec_router(l, x) == router(l, x).probs,
        // so guessing with the true next-layer input is always perfect.
        let w = Arc::new(generate_weights(ModelConfig::TINY, 6));
        let be = NativeBackend::new(w);
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.7).cos()).collect();
        use crate::runtime::Backend as _;
        let (_, probs) = be.router(1, &x).unwrap();
        let direct = top_k(&probs, 2);
        let guessed = guess_next_layer(&be, 1, &x, 2).unwrap();
        assert_eq!(direct, guessed);
    }

    #[test]
    fn source_parse_and_names() {
        assert_eq!(PrefetchSource::parse("GATE"), Some(PrefetchSource::Gate));
        assert_eq!(PrefetchSource::parse("speculative"), Some(PrefetchSource::Gate));
        assert_eq!(PrefetchSource::parse("markov"), Some(PrefetchSource::Markov));
        assert_eq!(PrefetchSource::parse("learned"), Some(PrefetchSource::Learned));
        assert_eq!(PrefetchSource::parse("psychic"), None);
        for (i, s) in PrefetchSource::ALL.iter().enumerate() {
            assert_eq!(s.idx(), i);
            assert_eq!(PrefetchSource::parse(s.name()), Some(*s));
        }
    }

    #[test]
    fn scorer_accumulates() {
        let mut s = SpeculativeScorer::default();
        s.settle(&[1, 2], &[2, 3]);
        s.settle(&[4, 5], &[4, 5]);
        assert_eq!(s.pr.tp, 3);
        assert_eq!(s.pr.fp, s.pr.fn_);
    }
}
