//! MoE offloading: the host-side expert store (quantized "main memory"),
//! the transfer engine that moves experts onto the (simulated) device, the
//! speculative prefetcher (paper §3.2), and the multi-worker transfer
//! pipeline that overlaps dequantization with compute (§6.1) without
//! letting speculation compete with demand misses for workers.

pub mod learned;
pub mod pipeline;
pub mod predictor;
pub mod prefetch;
pub mod store;
pub mod transfer;
