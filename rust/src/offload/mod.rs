//! MoE offloading: the host-side expert store (quantized "main memory"),
//! the transfer engine that moves experts onto the (simulated) device, the
//! speculative prefetcher (paper §3.2), and the overlap worker (§6.1).

pub mod overlap;
pub mod predictor;
pub mod prefetch;
pub mod store;
pub mod transfer;
