//! Learning-based expert prediction — the paper's §6.1 "learning-based
//! prediction trained from a large dataset of activation history"
//! direction, implemented as an online first-order Markov model.
//!
//! Per layer it maintains transition counts `C[prev][next]` between the
//! expert sets of consecutive tokens plus global popularity counts, and
//! predicts the next token's top-k experts as the argmax of
//!
//!   score(e) = (1-λ)·P(e | prev activated set) + λ·P(e)
//!
//! Unlike speculative gating (which needs the live hidden states and is
//! nearly free but layer-by-layer), the Markov predictor can prefetch for
//! ALL layers as soon as the previous token finishes — trading accuracy
//! for lead time. `sim::cachesim`-style replay + the cache explorer use it
//! to quantify that trade-off.

use crate::model::sampler::top_k;

pub struct MarkovPredictor {
    n_layers: usize,
    n_experts: usize,
    /// trans[layer][prev][next] transition counts.
    trans: Vec<Vec<Vec<f64>>>,
    /// pop[layer][e] global activation counts.
    pop: Vec<Vec<f64>>,
    /// prev[layer] last activated set.
    prev: Vec<Vec<usize>>,
    /// Blend between transition and popularity terms.
    pub lambda: f64,
    /// Additive smoothing.
    pub alpha: f64,
}

impl MarkovPredictor {
    pub fn new(n_layers: usize, n_experts: usize) -> Self {
        MarkovPredictor {
            n_layers,
            n_experts,
            trans: vec![vec![vec![0.0; n_experts]; n_experts]; n_layers],
            pop: vec![vec![0.0; n_experts]; n_layers],
            prev: vec![Vec::new(); n_layers],
            lambda: 0.3,
            alpha: 0.5,
        }
    }

    /// Observe the activated set at (layer) for the current token.
    pub fn observe(&mut self, layer: usize, activated: &[usize]) {
        debug_assert!(layer < self.n_layers, "layer {layer} out of range");
        for &e in activated {
            self.pop[layer][e] += 1.0;
            for &p in &self.prev[layer] {
                self.trans[layer][p][e] += 1.0;
            }
        }
        self.prev[layer] = activated.to_vec();
    }

    /// Predict the top-k experts for the NEXT token at `layer`.
    pub fn predict(&self, layer: usize, k: usize) -> Vec<usize> {
        let mut score = vec![0.0f64; self.n_experts];
        // popularity term
        let pop_total: f64 = self.pop[layer].iter().sum::<f64>() + self.alpha * self.n_experts as f64;
        for e in 0..self.n_experts {
            score[e] += self.lambda * (self.pop[layer][e] + self.alpha) / pop_total;
        }
        // transition term from the previous activated set
        if !self.prev[layer].is_empty() {
            let w = (1.0 - self.lambda) / self.prev[layer].len() as f64;
            for &p in &self.prev[layer] {
                let row = &self.trans[layer][p];
                let row_total: f64 = row.iter().sum::<f64>() + self.alpha * self.n_experts as f64;
                for e in 0..self.n_experts {
                    score[e] += w * (row[e] + self.alpha) / row_total;
                }
            }
        }
        let f32s: Vec<f32> = score.iter().map(|&s| s as f32).collect();
        top_k(&f32s, k)
    }

    pub fn reset_context(&mut self) {
        for p in self.prev.iter_mut() {
            p.clear();
        }
    }
}

/// Replay a trace through the predictor, measuring prediction quality
/// (the §6.1 comparison: learned predictor vs speculative gating).
pub fn evaluate_on_trace(trace: &crate::trace::Trace, k: usize) -> crate::metrics::PrecisionRecall {
    let mut pred = MarkovPredictor::new(trace.n_layers, trace.n_experts);
    let mut pr = crate::metrics::PrecisionRecall::default();
    for t in 0..trace.n_tokens() {
        for l in 0..trace.n_layers {
            let activated = &trace.at(t, l).activated;
            if t > 0 {
                let guess = pred.predict(l, k);
                pr.record(&guess, activated);
            }
            pred.observe(l, activated);
        }
    }
    pr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::tracegen::{self, TraceGenConfig};

    #[test]
    fn learns_a_deterministic_cycle() {
        // experts alternate {0,1} -> {2,3} -> {0,1} ...
        let mut p = MarkovPredictor::new(1, 8);
        for t in 0..40 {
            let set: Vec<usize> = if t % 2 == 0 { vec![0, 1] } else { vec![2, 3] };
            p.observe(0, &set);
        }
        // last observed was odd ({2,3}); next should be {0,1}
        let mut g = p.predict(0, 2);
        g.sort_unstable();
        assert_eq!(g, vec![0, 1]);
    }

    #[test]
    fn beats_chance_on_skewed_trace() {
        let trace = tracegen::generate(&TraceGenConfig {
            n_layers: 4,
            n_tokens: 300,
            ..Default::default()
        });
        let pr = evaluate_on_trace(&trace, 2);
        // chance precision for top-2-of-8 = 0.25
        assert!(pr.precision() > 0.3, "precision {}", pr.precision());
        // equal-cardinality identity holds here too
        assert_eq!(pr.fp, pr.fn_);
    }

    #[test]
    fn prediction_is_valid_topk() {
        let p = MarkovPredictor::new(2, 8);
        let g = p.predict(1, 3); // cold start: pure smoothed popularity
        assert_eq!(g.len(), 3);
        let mut s = g.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn reset_clears_context_not_history() {
        let mut p = MarkovPredictor::new(1, 4);
        for _ in 0..10 {
            p.observe(0, &[3]);
        }
        p.reset_context();
        // popularity survives: 3 should still rank first
        assert_eq!(p.predict(0, 1), vec![3]);
    }
}
