//! Learning-based expert prediction — the paper's §6.1 "learning-based
//! prediction trained from a large dataset of activation history"
//! direction, implemented as an online first-order Markov model.
//!
//! Per layer it maintains transition counts `C[prev][next]` between the
//! expert sets of consecutive tokens plus global popularity counts, and
//! predicts the next token's top-k experts as the argmax of
//!
//!   score(e) = (1-λ)·P(e | prev activated set) + λ·P(e)
//!
//! Unlike speculative gating (which needs the live hidden states and is
//! nearly free but layer-by-layer), the Markov predictor can prefetch for
//! ALL layers as soon as the previous token finishes — trading accuracy
//! for lead time. `sim::cachesim`-style replay + the cache explorer use it
//! to quantify that trade-off. The offline-trained cross-layer model lives
//! in [`crate::offload::learned`]; this one needs no training pass.

use crate::metrics::PrecisionRecall;
use anyhow::{bail, Result};

pub struct MarkovPredictor {
    n_layers: usize,
    n_experts: usize,
    /// trans[layer][prev][next] transition counts.
    trans: Vec<Vec<Vec<f64>>>,
    /// pop[layer][e] global activation counts.
    pop: Vec<Vec<f64>>,
    /// prev[layer] last activated set.
    prev: Vec<Vec<usize>>,
    /// Scratch score buffer reused across [`Self::predict`] calls (the
    /// prefetch hot path calls it once per layer per token).
    scratch: Vec<f64>,
    /// Records dropped by [`Self::observe`] because their layer or expert
    /// ids were out of range for this predictor's dimensions.
    skipped_records: u64,
    /// Blend between transition and popularity terms.
    pub lambda: f64,
    /// Additive smoothing.
    pub alpha: f64,
}

impl MarkovPredictor {
    pub fn new(n_layers: usize, n_experts: usize) -> Self {
        MarkovPredictor {
            n_layers,
            n_experts,
            trans: vec![vec![vec![0.0; n_experts]; n_experts]; n_layers],
            pop: vec![vec![0.0; n_experts]; n_layers],
            prev: vec![Vec::new(); n_layers],
            scratch: vec![0.0; n_experts],
            skipped_records: 0,
            lambda: 0.3,
            alpha: 0.5,
        }
    }

    /// Observe the activated set at (layer) for the current token.
    ///
    /// Records with an out-of-range layer or expert id (e.g. from a
    /// malformed or dimension-mismatched imported trace) are skipped and
    /// counted in [`Self::skipped_records`] instead of panicking deep in
    /// `Vec` indexing. Returns whether the record was accepted.
    pub fn observe(&mut self, layer: usize, activated: &[usize]) -> bool {
        if layer >= self.n_layers || activated.iter().any(|&e| e >= self.n_experts) {
            self.skipped_records += 1;
            return false;
        }
        for &e in activated {
            self.pop[layer][e] += 1.0;
            for &p in &self.prev[layer] {
                self.trans[layer][p][e] += 1.0;
            }
        }
        self.prev[layer] = activated.to_vec();
        true
    }

    /// Predict the top-k experts for the NEXT token at `layer`.
    ///
    /// Selection happens in f64 — the same precision the scores are
    /// computed in — with a stable lowest-index tiebreak, so near-ties
    /// never flip on float quantization.
    pub fn predict(&mut self, layer: usize, k: usize) -> Vec<usize> {
        let score = &mut self.scratch;
        score.fill(0.0);
        // popularity term
        let pop_total: f64 = self.pop[layer].iter().sum::<f64>() + self.alpha * self.n_experts as f64;
        for e in 0..self.n_experts {
            score[e] += self.lambda * (self.pop[layer][e] + self.alpha) / pop_total;
        }
        // transition term from the previous activated set
        if !self.prev[layer].is_empty() {
            let w = (1.0 - self.lambda) / self.prev[layer].len() as f64;
            for &p in &self.prev[layer] {
                let row = &self.trans[layer][p];
                let row_total: f64 = row.iter().sum::<f64>() + self.alpha * self.n_experts as f64;
                for e in 0..self.n_experts {
                    score[e] += w * (row[e] + self.alpha) / row_total;
                }
            }
        }
        // k-pass argmax: strictly-greater comparison over an in-order scan
        // gives the lowest index on exact ties, with no extra allocation.
        let k = k.min(self.n_experts);
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            let mut best = usize::MAX;
            for e in 0..self.n_experts {
                if out.contains(&e) {
                    continue;
                }
                if best == usize::MAX || score[e] > score[best] {
                    best = e;
                }
            }
            out.push(best);
        }
        out
    }

    pub fn reset_context(&mut self) {
        for p in self.prev.iter_mut() {
            p.clear();
        }
    }

    /// How many malformed records [`Self::observe`] has dropped.
    pub fn skipped_records(&self) -> u64 {
        self.skipped_records
    }
}

/// Outcome of [`evaluate_on_trace`]: guess quality plus how many records
/// were dropped as malformed.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalReport {
    pub pr: PrecisionRecall,
    pub skipped_records: u64,
}

/// Replay a trace through the predictor, measuring prediction quality
/// (the §6.1 comparison: learned predictor vs speculative gating).
///
/// The predictor context is reset at every sequence boundary recorded in
/// the trace, and no guess is scored for a sequence's first token —
/// without this, transition context bleeds across independent sequences
/// and inflates measured accuracy. Structural problems (an empty trace)
/// are an error; individually malformed records are skipped and counted.
pub fn evaluate_on_trace(trace: &crate::trace::Trace, k: usize) -> Result<EvalReport> {
    if trace.n_tokens() == 0 || trace.n_layers == 0 || trace.n_experts == 0 {
        bail!(
            "evaluate_on_trace: empty trace ({} tokens, {} layers, {} experts)",
            trace.n_tokens(),
            trace.n_layers,
            trace.n_experts
        );
    }
    let mut pred = MarkovPredictor::new(trace.n_layers, trace.n_experts);
    let mut pr = PrecisionRecall::default();
    for t in 0..trace.n_tokens() {
        let seq_start = trace.is_sequence_start(t);
        if seq_start {
            pred.reset_context();
        }
        for l in 0..trace.n_layers {
            let activated = &trace.at(t, l).activated;
            if !seq_start {
                let guess = pred.predict(l, k);
                pr.record(&guess, activated);
            }
            pred.observe(l, activated);
        }
    }
    Ok(EvalReport { pr, skipped_records: pred.skipped_records() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::tracegen::{self, TraceGenConfig};

    #[test]
    fn learns_a_deterministic_cycle() {
        // experts alternate {0,1} -> {2,3} -> {0,1} ...
        let mut p = MarkovPredictor::new(1, 8);
        for t in 0..40 {
            let set: Vec<usize> = if t % 2 == 0 { vec![0, 1] } else { vec![2, 3] };
            p.observe(0, &set);
        }
        // last observed was odd ({2,3}); next should be {0,1}
        let mut g = p.predict(0, 2);
        g.sort_unstable();
        assert_eq!(g, vec![0, 1]);
    }

    #[test]
    fn beats_chance_on_skewed_trace() {
        let trace = tracegen::generate(&TraceGenConfig {
            n_layers: 4,
            n_tokens: 300,
            ..Default::default()
        });
        let report = evaluate_on_trace(&trace, 2).unwrap();
        // chance precision for top-2-of-8 = 0.25
        assert!(report.pr.precision() > 0.3, "precision {}", report.pr.precision());
        // equal-cardinality identity holds here too
        assert_eq!(report.pr.fp, report.pr.fn_);
        assert_eq!(report.skipped_records, 0);
    }

    #[test]
    fn prediction_is_valid_topk() {
        let mut p = MarkovPredictor::new(2, 8);
        let g = p.predict(1, 3); // cold start: pure smoothed popularity
        assert_eq!(g.len(), 3);
        let mut s = g.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn cold_start_ties_resolve_to_lowest_indices() {
        // with no history every expert scores exactly alpha-smoothed
        // uniform in f64; the documented tiebreak must pick 0,1,2.
        let mut p = MarkovPredictor::new(1, 8);
        assert_eq!(p.predict(0, 3), vec![0, 1, 2]);
    }

    #[test]
    fn reset_clears_context_not_history() {
        let mut p = MarkovPredictor::new(1, 4);
        for _ in 0..10 {
            p.observe(0, &[3]);
        }
        p.reset_context();
        // popularity survives: 3 should still rank first
        assert_eq!(p.predict(0, 1), vec![3]);
    }

    #[test]
    fn malformed_records_are_skipped_and_counted() {
        let mut p = MarkovPredictor::new(2, 4);
        assert!(p.observe(0, &[0, 1]));
        assert!(!p.observe(0, &[0, 4])); // expert out of range
        assert!(!p.observe(2, &[0])); // layer out of range
        assert_eq!(p.skipped_records(), 2);
        // the bad records left no trace in the counts: context is still {0,1}
        let mut g = p.predict(0, 2);
        g.sort_unstable();
        assert_eq!(g, vec![0, 1]);
    }

    #[test]
    fn evaluate_errors_on_empty_trace() {
        let trace = crate::trace::Trace::new(2, 4, 2);
        assert!(evaluate_on_trace(&trace, 2).is_err());
    }

    #[test]
    fn sequence_boundary_reset_deflates_accuracy() {
        // Two concatenated sequences continuing the same {0,1}<->{2,3}
        // cycle in phase. Without boundaries the predictor scores a
        // "correct" guess across the seam that it had no right to make;
        // with boundaries that guess is excluded and the context reset.
        let mut trace = crate::trace::Trace::new(1, 8, 2);
        let mut push = |trace: &mut crate::trace::Trace, phase: usize| {
            let set = if phase % 2 == 0 { vec![0, 1] } else { vec![2, 3] };
            trace.push_token(phase as u32);
            trace.at_mut(trace.n_tokens() - 1, 0).activated = set;
        };
        for t in 0..8 {
            push(&mut trace, t);
        }
        let mut with_boundary = trace.clone();
        with_boundary.mark_sequence_boundary();
        for t in 0..8 {
            push(&mut trace, t);
            push(&mut with_boundary, t);
        }
        let inflated = evaluate_on_trace(&trace, 2).unwrap().pr;
        let corrected = evaluate_on_trace(&with_boundary, 2).unwrap().pr;
        // one token's guesses (k=2) are excluded, and they were "correct"
        assert_eq!(corrected.tp + 2, inflated.tp);
        assert!(
            corrected.precision() < inflated.precision(),
            "corrected {} !< inflated {}",
            corrected.precision(),
            inflated.precision()
        );
    }
}
