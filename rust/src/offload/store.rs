//! Host expert store — the paper's "experts stored in main memory".
//!
//! All expert tensors are re-encoded once at startup with the configured
//! quantization scheme (paper: HQQ 2-bit group-16; here: block-wise int4 /
//! int8 / f32, DESIGN.md §3) and held in host memory. A cache miss
//! dequantizes (`fetch` -> f32) and uploads; the quantized byte count is
//! what crosses the simulated PCIe bus.

use crate::model::Weights;
use crate::offload::pipeline::BufferPool;
use crate::quant::{QTensor, Scheme};
use anyhow::Result;

pub struct ExpertEntry {
    pub w1: QTensor,
    pub w3: QTensor,
    pub w2: QTensor,
}

impl ExpertEntry {
    pub fn storage_bytes(&self) -> usize {
        self.w1.storage_bytes() + self.w3.storage_bytes() + self.w2.storage_bytes()
    }
}

pub struct HostExpertStore {
    pub scheme: Scheme,
    pub n_layers: usize,
    pub n_experts: usize,
    /// entries[layer * n_experts + expert]
    entries: Vec<ExpertEntry>,
    /// Worst-case dequantization error bound across all experts.
    pub max_error_bound: f32,
}

impl HostExpertStore {
    /// Quantize every expert in `weights` into host storage.
    pub fn build(weights: &Weights, scheme: Scheme) -> Result<HostExpertStore> {
        let c = &weights.config;
        let mut entries = Vec::with_capacity(c.n_layers * c.n_experts);
        let mut max_err = 0.0f32;
        for l in 0..c.n_layers {
            for e in 0..c.n_experts {
                let entry = ExpertEntry {
                    w1: QTensor::quantize(weights.expert(l, e, "w1")?, scheme),
                    w3: QTensor::quantize(weights.expert(l, e, "w3")?, scheme),
                    w2: QTensor::quantize(weights.expert(l, e, "w2")?, scheme),
                };
                max_err = max_err
                    .max(entry.w1.max_abs_error_bound())
                    .max(entry.w3.max_abs_error_bound())
                    .max(entry.w2.max_abs_error_bound());
                entries.push(entry);
            }
        }
        Ok(HostExpertStore {
            scheme,
            n_layers: c.n_layers,
            n_experts: c.n_experts,
            entries,
            max_error_bound: max_err,
        })
    }

    pub fn entry(&self, layer: usize, expert: usize) -> &ExpertEntry {
        &self.entries[layer * self.n_experts + expert]
    }

    /// Dequantize one expert to f32 (the CPU half of a transfer),
    /// allocating fresh buffers. Prefer [`HostExpertStore::fetch_into`] with
    /// pooled buffers on the hot path.
    pub fn fetch(&self, layer: usize, expert: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let e = self.entry(layer, expert);
        (e.w1.dequantize(), e.w3.dequantize(), e.w2.dequantize())
    }

    /// Dequantize one expert into buffers acquired from `pool` — the
    /// allocation-free transfer path shared by the synchronous engine, the
    /// pipeline workers, and the benches. The returned buffers go back to
    /// the pool via `release` (or via the cache's eviction path once they
    /// become an `ExpertHandle::Host`).
    pub fn fetch_pooled(
        &self,
        pool: &BufferPool,
        layer: usize,
        expert: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let e = self.entry(layer, expert);
        let mut w1 = pool.acquire(e.w1.len);
        let mut w3 = pool.acquire(e.w3.len);
        let mut w2 = pool.acquire(e.w2.len);
        // exact-length pooled buffers make fetch_into's resize a no-op
        self.fetch_into(layer, expert, &mut w1, &mut w3, &mut w2);
        (w1, w3, w2)
    }

    /// Dequantize one expert into caller-provided buffers (resized to fit;
    /// a no-op after warmup when the buffers come from a
    /// [`BufferPool`]). This is the resize-tolerant variant of
    /// [`HostExpertStore::fetch_pooled`].
    pub fn fetch_into(
        &self,
        layer: usize,
        expert: usize,
        w1: &mut Vec<f32>,
        w3: &mut Vec<f32>,
        w2: &mut Vec<f32>,
    ) {
        let e = self.entry(layer, expert);
        e.w1.dequantize_resize(w1);
        e.w3.dequantize_resize(w3);
        e.w2.dequantize_resize(w2);
    }

    /// Quantized bytes of one expert — the unit of PCIe traffic.
    pub fn expert_transfer_bytes(&self) -> usize {
        self.entries.first().map_or(0, |e| e.storage_bytes())
    }

    /// Total host memory held by the store.
    pub fn total_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.storage_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::synth_weights;
    use crate::model::{ModelConfig, Weights};

    fn weights() -> Weights {
        synth_weights(ModelConfig::TINY, |name, i| {
            ((name.len() + i) % 13) as f32 * 0.01 - 0.06
        })
    }

    #[test]
    fn builds_all_experts() {
        let w = weights();
        let s = HostExpertStore::build(&w, Scheme::Int8 { block: 16 }).unwrap();
        assert_eq!(s.n_layers, 2);
        assert_eq!(s.n_experts, 8);
        let (w1, w3, w2) = s.fetch(1, 7);
        assert_eq!(w1.len(), 32 * 64);
        assert_eq!(w3.len(), 32 * 64);
        assert_eq!(w2.len(), 64 * 32);
    }

    #[test]
    fn fetch_into_matches_fetch() {
        let w = weights();
        let s = HostExpertStore::build(&w, Scheme::Int4 { block: 16 }).unwrap();
        let (a1, a3, a2) = s.fetch(1, 2);
        // deliberately mis-sized buffers: fetch_into resizes
        let (mut b1, mut b3, mut b2) = (Vec::new(), vec![0.0f32; 7], vec![1.0f32; 9999]);
        s.fetch_into(1, 2, &mut b1, &mut b3, &mut b2);
        assert_eq!(a1, b1);
        assert_eq!(a3, b3);
        assert_eq!(a2, b2);
    }

    #[test]
    fn f32_store_roundtrips_exactly() {
        let w = weights();
        let s = HostExpertStore::build(&w, Scheme::F32).unwrap();
        let (w1, _, _) = s.fetch(0, 0);
        assert_eq!(&w1[..], w.expert(0, 0, "w1").unwrap());
    }

    #[test]
    fn int4_within_error_bound() {
        let w = weights();
        let s = HostExpertStore::build(&w, Scheme::Int4 { block: 16 }).unwrap();
        let (dq, _, _) = s.fetch(0, 3);
        let orig = w.expert(0, 3, "w1").unwrap();
        for (a, b) in dq.iter().zip(orig) {
            assert!((a - b).abs() <= s.max_error_bound * 1.001);
        }
    }

    #[test]
    fn transfer_bytes_shrink_with_scheme() {
        let w = weights();
        let f32b = HostExpertStore::build(&w, Scheme::F32).unwrap().expert_transfer_bytes();
        let i8b = HostExpertStore::build(&w, Scheme::Int8 { block: 64 })
            .unwrap()
            .expert_transfer_bytes();
        let i4b = HostExpertStore::build(&w, Scheme::Int4 { block: 16 })
            .unwrap()
            .expert_transfer_bytes();
        assert!(f32b > i8b && i8b > i4b);
    }

    #[test]
    fn total_bytes_is_sum() {
        let w = weights();
        let s = HostExpertStore::build(&w, Scheme::Int8 { block: 64 }).unwrap();
        assert_eq!(s.total_bytes(), 16 * s.expert_transfer_bytes());
    }
}
