//! Host expert store — the paper's "experts stored in main memory", with
//! an optional disk tier underneath (DESIGN.md §10).
//!
//! All expert tensors are re-encoded once at startup with the configured
//! quantization scheme (paper: HQQ 2-bit group-16; here: block-wise int4 /
//! int8 / f32, DESIGN.md §3). With the default all-RAM backing every
//! quantized expert lives in host memory; with [`HostTierConfig`] the
//! quantized bytes are spilled to disk instead and only a
//! `--host-cache-mb`-bounded working set is promoted into RAM on demand,
//! evicted by any online `cache/` policy. A cache miss dequantizes
//! (`fetch` -> f32) and uploads; the quantized byte count is what crosses
//! the simulated PCIe bus, and — in tiered mode — what crosses the real
//! disk first.
//!
//! Promotion is concurrency-safe under the multi-worker transfer
//! pipeline: a per-key loading set dedups in-flight disk reads (the first
//! thread preads outside the tier lock, later arrivals wait on a condvar
//! and take the promoted entry as a RAM hit), so demand and speculative
//! fetches of the same expert never read the spill twice.

use crate::cache::{LayerCache, PolicyKind};
use crate::metrics::{HostTierStats, LatencyHisto};
use crate::model::Weights;
use crate::offload::pipeline::BufferPool;
use crate::quant::{QTensor, Scheme};
use anyhow::{bail, Context, Result};
use std::collections::HashSet;
use std::fs::File;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

pub struct ExpertEntry {
    pub w1: QTensor,
    pub w3: QTensor,
    pub w2: QTensor,
}

impl ExpertEntry {
    pub fn storage_bytes(&self) -> usize {
        self.w1.storage_bytes() + self.w3.storage_bytes() + self.w2.storage_bytes()
    }

    /// Spill-file image: the three tensors' [`QTensor::to_bytes`] forms
    /// back to back (w1, w3, w2). Exactly [`ExpertEntry::storage_bytes`].
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = self.w1.to_bytes();
        out.extend_from_slice(&self.w3.to_bytes());
        out.extend_from_slice(&self.w2.to_bytes());
        out
    }
}

/// Configuration for the RAM→disk host tier ([`HostExpertStore::build_tiered`]).
#[derive(Clone, Debug)]
pub struct HostTierConfig {
    /// RAM budget for promoted experts, in bytes (`--host-cache-mb` × 2²⁰).
    /// Rounded down to whole entries, floor one entry.
    pub ram_budget_bytes: usize,
    /// Eviction policy at the host tier — any online `cache/` policy
    /// (Belady is rejected: the host tier has no future trace).
    pub policy: PolicyKind,
    pub seed: u64,
    /// Directory for the spill file; the system temp dir when `None`. The
    /// file is unlinked right after opening on unix (private scratch).
    pub spill_dir: Option<PathBuf>,
}

impl HostTierConfig {
    pub fn new(ram_budget_bytes: usize) -> HostTierConfig {
        HostTierConfig {
            ram_budget_bytes,
            policy: PolicyKind::Lru,
            seed: 0,
            spill_dir: None,
        }
    }
}

/// Positioned reads over the spill file. One trait so the backing can be
/// swapped (pread today; an mmap reader would slot in here) and so tests
/// can fault-inject. `read_at` must be callable concurrently — the
/// transfer pipeline's workers promote in parallel.
pub trait ExpertReader: Send + Sync {
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> std::io::Result<()>;
}

/// pread-backed reader. On unix `read_exact_at` needs no seek state, so
/// concurrent reads share the bare fd; elsewhere a mutexed seek+read
/// fallback keeps the same contract.
pub struct SpillReader {
    #[cfg(unix)]
    file: File,
    #[cfg(not(unix))]
    file: Mutex<File>,
}

impl SpillReader {
    pub fn new(file: File) -> SpillReader {
        #[cfg(unix)]
        {
            SpillReader { file }
        }
        #[cfg(not(unix))]
        {
            SpillReader { file: Mutex::new(file) }
        }
    }
}

impl ExpertReader for SpillReader {
    #[cfg(unix)]
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> std::io::Result<()> {
        std::os::unix::fs::FileExt::read_exact_at(&self.file, buf, offset)
    }

    #[cfg(not(unix))]
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> std::io::Result<()> {
        use std::io::{Read, Seek, SeekFrom};
        let mut f = self.file.lock().unwrap();
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(buf)
    }
}

/// RAM cache + loading set. The cache's own `CacheStats` are ignored —
/// the tier's atomics below are the source of truth (they also count the
/// waits that resolve as hits after a peer's promotion).
struct TierState {
    /// Flattened key `layer * n_experts + expert` → promoted entry.
    cache: LayerCache<Arc<ExpertEntry>>,
    /// Keys with a disk read in flight (in-flight join dedup).
    loading: HashSet<usize>,
}

struct DiskTier {
    reader: Box<dyn ExpertReader>,
    state: Mutex<TierState>,
    /// Signalled after every promotion completes (or fails over).
    loaded: Condvar,
    ram_hits: AtomicU64,
    disk_promotions: AtomicU64,
    ram_evictions: AtomicU64,
    disk_read_ns: AtomicU64,
    host_accesses: AtomicU64,
    read_histo: LatencyHisto,
}

enum Backing {
    /// Every quantized expert resident (the original unbounded store).
    Ram(Vec<ExpertEntry>),
    /// Spill file + budgeted RAM cache.
    Tiered(DiskTier),
}

/// Resolved entry for one fetch: a borrow from the RAM backing, or a
/// promoted (possibly shared) entry pinned for the duration of the fetch.
enum EntryRef<'a> {
    Ram(&'a ExpertEntry),
    Promoted(Arc<ExpertEntry>),
}

impl EntryRef<'_> {
    fn get(&self) -> &ExpertEntry {
        match self {
            EntryRef::Ram(e) => e,
            EntryRef::Promoted(a) => a,
        }
    }
}

pub struct HostExpertStore {
    pub scheme: Scheme,
    pub n_layers: usize,
    pub n_experts: usize,
    backing: Backing,
    /// Worst-case dequantization error bound across all experts.
    pub max_error_bound: f32,
    /// Quantized bytes of one expert (all experts share a shape).
    entry_bytes: usize,
    /// f32 element counts of (w1, w3, w2) — reconstructs spill entries.
    lens: (usize, usize, usize),
}

impl HostExpertStore {
    /// Quantize every expert in `weights` into host storage (all-RAM).
    pub fn build(weights: &Weights, scheme: Scheme) -> Result<HostExpertStore> {
        let c = &weights.config;
        let mut entries = Vec::with_capacity(c.n_layers * c.n_experts);
        let mut max_err = 0.0f32;
        for l in 0..c.n_layers {
            for e in 0..c.n_experts {
                let entry = quantize_expert(weights, l, e, scheme)?;
                max_err = max_err
                    .max(entry.w1.max_abs_error_bound())
                    .max(entry.w3.max_abs_error_bound())
                    .max(entry.w2.max_abs_error_bound());
                entries.push(entry);
            }
        }
        let entry_bytes = entries.first().map_or(0, |e| e.storage_bytes());
        let lens = entries
            .first()
            .map_or((0, 0, 0), |e| (e.w1.len, e.w3.len, e.w2.len));
        Ok(HostExpertStore {
            scheme,
            n_layers: c.n_layers,
            n_experts: c.n_experts,
            backing: Backing::Ram(entries),
            max_error_bound: max_err,
            entry_bytes,
            lens,
        })
    }

    /// Quantize every expert straight to a disk spill file and keep only a
    /// `ram_budget_bytes`-bounded RAM cache, promoted on demand. The spill
    /// is written expert by expert, so peak build memory is one expert —
    /// the corpus never lives in RAM.
    pub fn build_tiered(
        weights: &Weights,
        scheme: Scheme,
        tier: &HostTierConfig,
    ) -> Result<HostExpertStore> {
        if matches!(tier.policy, PolicyKind::Belady) {
            bail!("belady needs the future trace; the host tier evicts online");
        }
        let c = &weights.config;
        let dir = tier.spill_dir.clone().unwrap_or_else(std::env::temp_dir);
        // pid + sequence: unique across processes AND across stores built
        // concurrently inside one process (tests build many)
        static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);
        let path = dir.join(format!(
            "moe-experts-{}-{}-{}.spill",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed),
            scheme.name()
        ));
        let mut out = std::io::BufWriter::new(
            File::create(&path)
                .with_context(|| format!("create spill file {}", path.display()))?,
        );
        let mut max_err = 0.0f32;
        let mut entry_bytes = 0usize;
        let mut lens = (0usize, 0usize, 0usize);
        for l in 0..c.n_layers {
            for e in 0..c.n_experts {
                let entry = quantize_expert(weights, l, e, scheme)?;
                max_err = max_err
                    .max(entry.w1.max_abs_error_bound())
                    .max(entry.w3.max_abs_error_bound())
                    .max(entry.w2.max_abs_error_bound());
                let bytes = entry.to_bytes();
                if l == 0 && e == 0 {
                    entry_bytes = bytes.len();
                    lens = (entry.w1.len, entry.w3.len, entry.w2.len);
                } else {
                    // fixed stride is what makes pread offsets trivial
                    assert_eq!(bytes.len(), entry_bytes, "expert shapes must match");
                }
                out.write_all(&bytes)
                    .with_context(|| format!("write spill file {}", path.display()))?;
            }
        }
        out.flush()
            .with_context(|| format!("flush spill file {}", path.display()))?;
        drop(out);
        let file = File::open(&path)
            .with_context(|| format!("reopen spill file {}", path.display()))?;
        // private scratch: on unix the open fd keeps the data readable
        // after unlink and the kernel reclaims the space when we exit
        #[cfg(unix)]
        let _ = std::fs::remove_file(&path);
        let capacity = if entry_bytes == 0 {
            1
        } else {
            (tier.ram_budget_bytes / entry_bytes).max(1)
        };
        Ok(HostExpertStore {
            scheme,
            n_layers: c.n_layers,
            n_experts: c.n_experts,
            backing: Backing::Tiered(DiskTier {
                reader: Box::new(SpillReader::new(file)),
                state: Mutex::new(TierState {
                    cache: LayerCache::new(
                        capacity,
                        tier.policy.build(tier.seed, None),
                    ),
                    loading: HashSet::new(),
                }),
                loaded: Condvar::new(),
                ram_hits: AtomicU64::new(0),
                disk_promotions: AtomicU64::new(0),
                ram_evictions: AtomicU64::new(0),
                disk_read_ns: AtomicU64::new(0),
                host_accesses: AtomicU64::new(0),
                read_histo: LatencyHisto::default(),
            }),
            max_error_bound: max_err,
            entry_bytes,
            lens,
        })
    }

    fn resolve(&self, layer: usize, expert: usize) -> EntryRef<'_> {
        match &self.backing {
            Backing::Ram(entries) => {
                EntryRef::Ram(&entries[layer * self.n_experts + expert])
            }
            Backing::Tiered(t) => EntryRef::Promoted(self.promote(t, layer, expert)),
        }
    }

    /// One host-tier access: RAM hit, in-flight join, or disk promotion.
    /// Exactly one of `ram_hits`/`disk_promotions` is incremented per call,
    /// so `ram_hits + disk_promotions == host_accesses` is an invariant.
    fn promote(&self, t: &DiskTier, layer: usize, expert: usize) -> Arc<ExpertEntry> {
        let key = layer * self.n_experts + expert;
        t.host_accesses.fetch_add(1, Ordering::Relaxed);
        let mut st = t.state.lock().unwrap();
        loop {
            if let Some(e) = st.cache.access(key) {
                t.ram_hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(e);
            }
            if st.loading.insert(key) {
                break; // we are the loader for this key
            }
            // a peer is reading this key from disk: wait, then re-check
            // (usually a hit; a miss means it was already evicted and we
            // become the next loader)
            st = t.loaded.wait(st).unwrap();
        }
        drop(st); // the pread runs outside the tier lock
        let mut buf = vec![0u8; self.entry_bytes];
        let t0 = std::time::Instant::now();
        let read = t
            .reader
            .read_at((key * self.entry_bytes) as u64, &mut buf);
        let ns = t0.elapsed().as_nanos() as u64;
        let entry = match read {
            Ok(()) => Arc::new(self.entry_from_bytes(&buf)),
            Err(e) => {
                // unblock waiters before dying: they must not deadlock on
                // a loader that will never notify
                let mut st = t.state.lock().unwrap();
                st.loading.remove(&key);
                drop(st);
                t.loaded.notify_all();
                panic!("spill read (layer {layer}, expert {expert}): {e}");
            }
        };
        t.disk_promotions.fetch_add(1, Ordering::Relaxed);
        t.disk_read_ns.fetch_add(ns, Ordering::Relaxed);
        t.read_histo.record_ns(ns);
        let mut st = t.state.lock().unwrap();
        if st.cache.insert(key, Arc::clone(&entry)).is_some() {
            t.ram_evictions.fetch_add(1, Ordering::Relaxed);
        }
        st.loading.remove(&key);
        drop(st);
        t.loaded.notify_all();
        entry
    }

    fn entry_from_bytes(&self, bytes: &[u8]) -> ExpertEntry {
        let (l1, l3, l2) = self.lens;
        let b1 = self.scheme.storage_bytes(l1);
        let b3 = self.scheme.storage_bytes(l3);
        let b2 = self.scheme.storage_bytes(l2);
        ExpertEntry {
            w1: QTensor::from_bytes(self.scheme, l1, &bytes[..b1]),
            w3: QTensor::from_bytes(self.scheme, l3, &bytes[b1..b1 + b3]),
            w2: QTensor::from_bytes(self.scheme, l2, &bytes[b1 + b3..b1 + b3 + b2]),
        }
    }

    /// Dequantize one expert to f32 (the CPU half of a transfer),
    /// allocating fresh buffers. Prefer [`HostExpertStore::fetch_into`] with
    /// pooled buffers on the hot path.
    pub fn fetch(&self, layer: usize, expert: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let r = self.resolve(layer, expert);
        let e = r.get();
        (e.w1.dequantize(), e.w3.dequantize(), e.w2.dequantize())
    }

    /// Dequantize one expert into buffers acquired from `pool` — the
    /// allocation-free transfer path shared by the synchronous engine, the
    /// pipeline workers, and the benches. The returned buffers go back to
    /// the pool via `release` (or via the cache's eviction path once they
    /// become an `ExpertHandle::Host`). In tiered mode this is where the
    /// disk read stage runs, ahead of dequant, for whichever worker or
    /// engine thread got here first.
    pub fn fetch_pooled(
        &self,
        pool: &BufferPool,
        layer: usize,
        expert: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let r = self.resolve(layer, expert);
        let e = r.get();
        let mut w1 = pool.acquire(e.w1.len);
        let mut w3 = pool.acquire(e.w3.len);
        let mut w2 = pool.acquire(e.w2.len);
        // exact-length pooled buffers make the resize a no-op
        e.w1.dequantize_resize(&mut w1);
        e.w3.dequantize_resize(&mut w3);
        e.w2.dequantize_resize(&mut w2);
        (w1, w3, w2)
    }

    /// Dequantize one expert into caller-provided buffers (resized to fit;
    /// a no-op after warmup when the buffers come from a
    /// [`BufferPool`]). This is the resize-tolerant variant of
    /// [`HostExpertStore::fetch_pooled`].
    pub fn fetch_into(
        &self,
        layer: usize,
        expert: usize,
        w1: &mut Vec<f32>,
        w3: &mut Vec<f32>,
        w2: &mut Vec<f32>,
    ) {
        let r = self.resolve(layer, expert);
        let e = r.get();
        e.w1.dequantize_resize(w1);
        e.w3.dequantize_resize(w3);
        e.w2.dequantize_resize(w2);
    }

    /// Quantized bytes of one expert — the unit of PCIe traffic (and, in
    /// tiered mode, of disk traffic).
    pub fn expert_transfer_bytes(&self) -> usize {
        self.entry_bytes
    }

    /// Total quantized bytes of the whole corpus — host memory held by the
    /// all-RAM backing, spill-file size for the tiered one.
    pub fn total_bytes(&self) -> usize {
        self.n_layers * self.n_experts * self.entry_bytes
    }

    /// Whether a disk tier backs this store.
    pub fn is_tiered(&self) -> bool {
        matches!(self.backing, Backing::Tiered(_))
    }

    /// Experts the RAM tier may hold at once (the whole corpus when
    /// unbounded).
    pub fn ram_capacity_entries(&self) -> usize {
        match &self.backing {
            Backing::Ram(_) => self.n_layers * self.n_experts,
            Backing::Tiered(t) => t.state.lock().unwrap().cache.capacity(),
        }
    }

    /// Side-effect-free residency probe: would fetching `(layer, expert)`
    /// be served from RAM right now? Always true for the all-RAM backing;
    /// does not count as an access and never touches disk. The engine uses
    /// this to charge the sim clock for the disk stage.
    pub fn ram_resident(&self, layer: usize, expert: usize) -> bool {
        match &self.backing {
            Backing::Ram(_) => true,
            Backing::Tiered(t) => {
                let key = layer * self.n_experts + expert;
                t.state.lock().unwrap().cache.peek(key).is_some()
            }
        }
    }

    /// Host-tier counters (all zero for the all-RAM backing).
    pub fn tier_stats(&self) -> HostTierStats {
        match &self.backing {
            Backing::Ram(_) => HostTierStats::default(),
            Backing::Tiered(t) => HostTierStats {
                ram_hits: t.ram_hits.load(Ordering::Relaxed),
                disk_promotions: t.disk_promotions.load(Ordering::Relaxed),
                ram_evictions: t.ram_evictions.load(Ordering::Relaxed),
                disk_read_ns: t.disk_read_ns.load(Ordering::Relaxed),
                disk_read_p99_ns: t.read_histo.percentile_ns(0.99),
                host_accesses: t.host_accesses.load(Ordering::Relaxed),
            },
        }
    }
}

fn quantize_expert(
    weights: &Weights,
    layer: usize,
    expert: usize,
    scheme: Scheme,
) -> Result<ExpertEntry> {
    Ok(ExpertEntry {
        w1: QTensor::quantize(weights.expert(layer, expert, "w1")?, scheme),
        w3: QTensor::quantize(weights.expert(layer, expert, "w3")?, scheme),
        w2: QTensor::quantize(weights.expert(layer, expert, "w2")?, scheme),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::synth_weights;
    use crate::model::{ModelConfig, Weights};

    fn weights() -> Weights {
        synth_weights(ModelConfig::TINY, |name, i| {
            ((name.len() + i) % 13) as f32 * 0.01 - 0.06
        })
    }

    fn tiered(w: &Weights, scheme: Scheme, budget_entries: usize) -> HostExpertStore {
        let probe = HostExpertStore::build(w, scheme).unwrap();
        let cfg = HostTierConfig::new(budget_entries * probe.expert_transfer_bytes());
        HostExpertStore::build_tiered(w, scheme, &cfg).unwrap()
    }

    #[test]
    fn builds_all_experts() {
        let w = weights();
        let s = HostExpertStore::build(&w, Scheme::Int8 { block: 16 }).unwrap();
        assert_eq!(s.n_layers, 2);
        assert_eq!(s.n_experts, 8);
        let (w1, w3, w2) = s.fetch(1, 7);
        assert_eq!(w1.len(), 32 * 64);
        assert_eq!(w3.len(), 32 * 64);
        assert_eq!(w2.len(), 64 * 32);
    }

    #[test]
    fn fetch_into_matches_fetch() {
        let w = weights();
        let s = HostExpertStore::build(&w, Scheme::Int4 { block: 16 }).unwrap();
        let (a1, a3, a2) = s.fetch(1, 2);
        // deliberately mis-sized buffers: fetch_into resizes
        let (mut b1, mut b3, mut b2) = (Vec::new(), vec![0.0f32; 7], vec![1.0f32; 9999]);
        s.fetch_into(1, 2, &mut b1, &mut b3, &mut b2);
        assert_eq!(a1, b1);
        assert_eq!(a3, b3);
        assert_eq!(a2, b2);
    }

    #[test]
    fn f32_store_roundtrips_exactly() {
        let w = weights();
        let s = HostExpertStore::build(&w, Scheme::F32).unwrap();
        let (w1, _, _) = s.fetch(0, 0);
        assert_eq!(&w1[..], w.expert(0, 0, "w1").unwrap());
    }

    #[test]
    fn int4_within_error_bound() {
        let w = weights();
        let s = HostExpertStore::build(&w, Scheme::Int4 { block: 16 }).unwrap();
        let (dq, _, _) = s.fetch(0, 3);
        let orig = w.expert(0, 3, "w1").unwrap();
        for (a, b) in dq.iter().zip(orig) {
            assert!((a - b).abs() <= s.max_error_bound * 1.001);
        }
    }

    #[test]
    fn transfer_bytes_shrink_with_scheme() {
        let w = weights();
        let f32b = HostExpertStore::build(&w, Scheme::F32).unwrap().expert_transfer_bytes();
        let i8b = HostExpertStore::build(&w, Scheme::Int8 { block: 64 })
            .unwrap()
            .expert_transfer_bytes();
        let i4b = HostExpertStore::build(&w, Scheme::Int4 { block: 16 })
            .unwrap()
            .expert_transfer_bytes();
        assert!(f32b > i8b && i8b > i4b);
    }

    #[test]
    fn total_bytes_is_sum() {
        let w = weights();
        let s = HostExpertStore::build(&w, Scheme::Int8 { block: 64 }).unwrap();
        assert_eq!(s.total_bytes(), 16 * s.expert_transfer_bytes());
    }

    #[test]
    fn tiered_fetch_is_bit_identical_to_ram() {
        let w = weights();
        for scheme in [Scheme::F32, Scheme::Int8 { block: 16 }, Scheme::Int4 { block: 16 }] {
            let ram = HostExpertStore::build(&w, scheme).unwrap();
            let t = tiered(&w, scheme, 2); // far below the 16-expert corpus
            assert!(t.is_tiered() && !ram.is_tiered());
            assert_eq!(t.expert_transfer_bytes(), ram.expert_transfer_bytes());
            assert_eq!(t.total_bytes(), ram.total_bytes());
            assert_eq!(t.ram_capacity_entries(), 2);
            for l in 0..ram.n_layers {
                for e in 0..ram.n_experts {
                    let (a1, a3, a2) = ram.fetch(l, e);
                    let (b1, b3, b2) = t.fetch(l, e);
                    let same = |x: &[f32], y: &[f32]| {
                        x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
                    };
                    assert!(
                        same(&a1, &b1) && same(&a3, &b3) && same(&a2, &b2),
                        "{scheme:?} ({l},{e}) diverged across tiers"
                    );
                }
            }
        }
    }

    #[test]
    fn tier_counters_obey_access_invariant() {
        let w = weights();
        let t = tiered(&w, Scheme::Int8 { block: 16 }, 3);
        // sweep twice: first pass promotes (with evictions past capacity 3),
        // second pass mixes hits and re-promotions
        for _ in 0..2 {
            for l in 0..t.n_layers {
                for e in 0..t.n_experts {
                    let _ = t.fetch(l, e);
                }
            }
        }
        let s = t.tier_stats();
        assert_eq!(s.host_accesses, 32);
        assert_eq!(s.ram_hits + s.disk_promotions, s.host_accesses);
        assert!(s.disk_promotions >= 16, "cold sweep must touch disk");
        assert!(s.ram_evictions > 0, "capacity 3 over 16 experts must evict");
        assert!(s.disk_read_ns > 0);
        assert!(s.disk_read_p99_ns > 0);
    }

    #[test]
    fn residency_probe_is_side_effect_free() {
        let w = weights();
        let t = tiered(&w, Scheme::F32, 2);
        assert!(!t.ram_resident(0, 0));
        assert_eq!(t.tier_stats().host_accesses, 0, "probe must not count");
        let _ = t.fetch(0, 0);
        assert!(t.ram_resident(0, 0));
        let before = t.tier_stats();
        assert!(t.ram_resident(0, 0));
        assert_eq!(t.tier_stats().host_accesses, before.host_accesses);
    }

    #[test]
    fn concurrent_promotions_dedup_in_flight() {
        let w = weights();
        let t = Arc::new(tiered(&w, Scheme::Int4 { block: 16 }, 16));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let t = Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                for l in 0..t.n_layers {
                    for e in 0..t.n_experts {
                        let (w1, _, _) = t.fetch(l, e);
                        assert_eq!(w1.len(), 32 * 64);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = t.tier_stats();
        assert_eq!(s.host_accesses, 8 * 32);
        assert_eq!(s.ram_hits + s.disk_promotions, s.host_accesses);
        // capacity covers the corpus: each expert reads disk at most once
        // per loader; in-flight joins + residency make most accesses hits
        assert_eq!(s.ram_evictions, 0);
        assert_eq!(s.disk_promotions, 32, "capacity >= corpus: one read each");
    }

    #[test]
    fn pathologically_small_budget_still_serves() {
        let w = weights();
        // a zero-byte budget floors at one resident entry
        let t = HostExpertStore::build_tiered(
            &w,
            Scheme::Int4 { block: 16 },
            &HostTierConfig::new(0),
        )
        .unwrap();
        assert_eq!(t.ram_capacity_entries(), 1);
        let ram = HostExpertStore::build(&w, Scheme::Int4 { block: 16 }).unwrap();
        for l in 0..t.n_layers {
            for e in 0..t.n_experts {
                assert_eq!(t.fetch(l, e).0, ram.fetch(l, e).0);
            }
        }
        let s = t.tier_stats();
        assert_eq!(s.ram_hits + s.disk_promotions, s.host_accesses);
    }

    #[test]
    fn belady_rejected_at_host_tier() {
        let w = weights();
        let cfg = HostTierConfig {
            policy: PolicyKind::Belady,
            ..HostTierConfig::new(1 << 20)
        };
        let err = HostExpertStore::build_tiered(&w, Scheme::F32, &cfg).unwrap_err();
        assert!(err.to_string().contains("belady"), "{err}");
    }
}
