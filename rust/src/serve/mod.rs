//! HTTP serving front: bounded request queue + session scheduler over one
//! engine worker.
//!
//! Architecture (DESIGN.md §6): a thread pool accepts connections and
//! parses requests; decode work is funneled through a BOUNDED mpsc queue to
//! ONE engine worker that owns the (non-`Send`) backend and the shared
//! expert cache. The worker runs the [`scheduler`]: up to `max_sessions`
//! decode sessions are interleaved round-robin, one token each per round,
//! all hitting the same per-layer expert cache — the paper's persistent
//! cache, contended (and amortized) across sessions. When the queue is
//! full, `/generate` answers 503 immediately (backpressure) instead of
//! buffering unboundedly.
//!
//! API:
//!   POST /generate   {"prompt": str, "n_tokens": int, "temperature"?: f,
//!                     "top_p"?: f, "greedy"?: bool}
//!                    -> text + per-session cache/speculation stats
//!   GET  /metrics    aggregate + per-session counters over the ONE shared
//!                    expert cache (JSON)
//!   GET  /healthz

pub mod http;
pub mod scheduler;

use crate::model::sampler::Sampling;
use crate::util::cliargs::Args;
use crate::util::json::{self, Value};
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use self::scheduler::{run_scheduler, SchedulerConfig, ServeSnapshot};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};

pub struct GenRequest {
    pub prompt: String,
    pub n_tokens: usize,
    pub sampling: Sampling,
    pub resp: Sender<Result<GenResponse, GenError>>,
}

/// A failed generation, classified for the HTTP layer: 400-class statuses
/// are the client's fault (validation), 500-class the server's (engine
/// failure mid-decode).
#[derive(Clone, Debug)]
pub struct GenError {
    pub status: u16,
    pub message: String,
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    pub text: String,
    pub n_prompt: usize,
    pub n_generated: usize,
    pub wall_s: f64,
    /// Tokens/s on the simulated clock over this session's lifetime —
    /// includes contention from concurrently decoded sessions.
    pub sim_tokens_per_s: f64,
    /// This session's share of the shared cache's traffic.
    pub cache_hit_rate: f64,
    pub session_id: u64,
    pub session_hits: u64,
    pub session_misses: u64,
    /// Speculative-prefetch quality for this session's own guesses.
    pub spec_precision: f64,
    pub spec_recall: f64,
}

/// Serve-layer knobs (queue + concurrency; the engine has its own config).
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Threads accepting/parsing HTTP connections. Each in-flight
    /// `/generate` pins one worker until its decode completes, so the
    /// server always provisions at least `max_sessions + 2` workers —
    /// otherwise the scheduler could never reach its session concurrency
    /// and `/metrics`/`/healthz` would queue behind blocked decodes.
    pub http_workers: usize,
    /// Decode sessions interleaved concurrently on the engine worker.
    pub max_sessions: usize,
    /// Bounded request-queue depth; beyond it, `/generate` answers 503.
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { http_workers: 4, max_sessions: 8, queue_depth: 64 }
    }
}

/// Serve-level counters, shared between HTTP workers and `/metrics`.
#[derive(Default)]
pub struct ServerMetrics {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub rejected_backpressure: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub queue_depth: AtomicU64,
}

/// Render `/metrics`: serve counters + the scheduler's latest snapshot.
/// The `shared_cache` object is singular by design — all sessions run over
/// ONE expert cache; `sessions[*]` partitions its traffic.
pub fn metrics_json(metrics: &ServerMetrics, snap: &ServeSnapshot) -> Value {
    let sessions: Vec<Value> = snap
        .sessions
        .iter()
        .map(|s| {
            Value::obj(vec![
                ("id", Value::from(s.id as f64)),
                ("state", Value::from(s.state)),
                ("n_prompt", Value::from(s.n_prompt)),
                ("generated", Value::from(s.generated)),
                ("target", Value::from(s.target)),
                ("tokens", Value::from(s.tally.tokens as f64)),
                ("hits", Value::from(s.tally.hits as f64)),
                ("misses", Value::from(s.tally.misses as f64)),
                ("hit_rate", Value::from(s.tally.hit_rate())),
                ("spec_precision", Value::from(s.tally.spec_pr.precision())),
                ("spec_recall", Value::from(s.tally.spec_pr.recall())),
                ("wasted_prefetches", Value::from(s.tally.wasted_prefetches as f64)),
            ])
        })
        .collect();
    Value::obj(vec![
        ("requests", Value::from(metrics.requests.load(Ordering::Relaxed) as f64)),
        ("errors", Value::from(metrics.errors.load(Ordering::Relaxed) as f64)),
        (
            "rejected_backpressure",
            Value::from(metrics.rejected_backpressure.load(Ordering::Relaxed) as f64),
        ),
        (
            "tokens_generated",
            Value::from(metrics.tokens_generated.load(Ordering::Relaxed) as f64),
        ),
        ("queue_depth", Value::from(metrics.queue_depth.load(Ordering::Relaxed) as f64)),
        ("active_sessions", Value::from(snap.active_sessions)),
        ("completed_sessions", Value::from(snap.completed_sessions as f64)),
        ("failed_sessions", Value::from(snap.failed_sessions as f64)),
        (
            "shared_cache",
            Value::obj(vec![
                ("policy", Value::from(snap.policy.clone())),
                ("capacity_per_layer", Value::from(snap.capacity_per_layer)),
                ("n_layers", Value::from(snap.n_layers)),
                ("hits", Value::from(snap.cache.hits as f64)),
                ("misses", Value::from(snap.cache.misses as f64)),
                ("evictions", Value::from(snap.cache.evictions as f64)),
                ("hit_rate", Value::from(snap.cache.hit_rate())),
                ("prefetch_hits", Value::from(snap.cache.prefetch_hits as f64)),
                (
                    "cross_session_prefetch_hits",
                    Value::from(snap.cross_session_prefetch_hits as f64),
                ),
            ]),
        ),
        (
            "transfer_pipeline",
            Value::obj(vec![
                ("workers", Value::from(snap.pipeline.workers as f64)),
                ("submitted_demand", Value::from(snap.pipeline.submitted_demand as f64)),
                ("submitted_prefetch", Value::from(snap.pipeline.submitted_prefetch as f64)),
                ("completed", Value::from(snap.pipeline.completed as f64)),
                (
                    "demand_joined_prefetch",
                    Value::from(snap.pipeline.demand_joined_prefetch as f64),
                ),
                (
                    "cancelled_prefetches",
                    Value::from(snap.pipeline.cancelled_prefetches as f64),
                ),
                ("peak_in_flight", Value::from(snap.pipeline.peak_in_flight as f64)),
                ("pool_allocs", Value::from(snap.pipeline.pool_allocs as f64)),
                ("pool_reuses", Value::from(snap.pipeline.pool_reuses as f64)),
                ("pool_reuse_rate", Value::from(snap.pipeline.pool_reuse_rate())),
            ]),
        ),
        (
            "speculation",
            Value::obj(vec![
                ("tp", Value::from(snap.spec.tp as f64)),
                ("fp", Value::from(snap.spec.fp as f64)),
                ("fn", Value::from(snap.spec.fn_ as f64)),
                ("precision", Value::from(snap.spec.precision())),
                ("recall", Value::from(snap.spec.recall())),
            ]),
        ),
        ("sessions", Value::Arr(sessions)),
    ])
}

/// Parse the /generate request body.
pub fn parse_gen_request(body: &[u8]) -> Result<(String, usize, Sampling), String> {
    let v = json::parse(std::str::from_utf8(body).map_err(|e| e.to_string())?)
        .map_err(|e| e.to_string())?;
    let prompt = v
        .get("prompt")
        .as_str()
        .ok_or("missing 'prompt'")?
        .to_string();
    let n = v.get("n_tokens").as_usize().unwrap_or(32);
    if n == 0 || n > 4096 {
        return Err(format!("n_tokens {n} out of range"));
    }
    let sampling = if v.get("greedy").as_bool() == Some(true) {
        Sampling::Greedy
    } else {
        Sampling::TopP {
            temperature: v.get("temperature").as_f64().unwrap_or(0.9) as f32,
            top_p: v.get("top_p").as_f64().unwrap_or(0.9) as f32,
        }
    };
    Ok((prompt, n, sampling))
}

pub fn gen_response_json(r: &GenResponse) -> String {
    json::to_string(&Value::obj(vec![
        ("text", Value::from(r.text.clone())),
        ("n_prompt", Value::from(r.n_prompt)),
        ("n_generated", Value::from(r.n_generated)),
        ("wall_s", Value::from(r.wall_s)),
        ("sim_tokens_per_s", Value::from(r.sim_tokens_per_s)),
        ("cache_hit_rate", Value::from(r.cache_hit_rate)),
        ("session_id", Value::from(r.session_id as f64)),
        ("session_hits", Value::from(r.session_hits as f64)),
        ("session_misses", Value::from(r.session_misses as f64)),
        ("spec_precision", Value::from(r.spec_precision)),
        ("spec_recall", Value::from(r.spec_recall)),
    ]))
}

/// Run the server until `shutdown` flips (or forever). Engine construction
/// is deferred to the worker thread because the PJRT backend is not `Send`.
pub fn serve<F>(
    listener: TcpListener,
    make_engine: F,
    cfg: ServeConfig,
    shutdown: Arc<AtomicBool>,
) -> Result<()>
where
    F: FnOnce() -> Result<crate::engine::InferenceEngine> + Send + 'static,
{
    let metrics = Arc::new(ServerMetrics::default());
    let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
    let (queue_tx, queue_rx) = sync_channel::<GenRequest>(cfg.queue_depth.max(1));
    // liveness for /healthz: flips false when the engine worker exits
    // (init failure or retirement) so orchestrators stop routing traffic
    // to a server that can only answer 503
    let engine_up = Arc::new(AtomicBool::new(true));

    // engine worker: owns the engine and runs the session scheduler
    let worker_metrics = Arc::clone(&metrics);
    let worker_snapshot = Arc::clone(&snapshot);
    let worker_up = Arc::clone(&engine_up);
    let max_sessions = cfg.max_sessions;
    let engine_worker = std::thread::Builder::new()
        .name("engine-worker".into())
        .spawn(move || {
            let engine = match make_engine() {
                Ok(e) => e,
                Err(e) => {
                    worker_up.store(false, Ordering::Relaxed);
                    eprintln!("engine init failed: {e:#}");
                    return;
                }
            };
            run_scheduler(
                engine,
                queue_rx,
                SchedulerConfig { max_sessions },
                worker_metrics,
                worker_snapshot,
            );
            worker_up.store(false, Ordering::Relaxed);
        })?;

    // see ServeConfig::http_workers: one blocked worker per in-flight
    // decode, plus headroom for /metrics and /healthz under load
    let pool = ThreadPool::new(cfg.http_workers.max(cfg.max_sessions + 2));
    listener.set_nonblocking(true)?;
    println!(
        "serving on {} (max {} concurrent sessions, queue depth {})",
        listener.local_addr()?,
        cfg.max_sessions,
        cfg.queue_depth
    );
    loop {
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        match listener.accept() {
            Ok((mut stream, _)) => {
                stream.set_nonblocking(false).ok();
                let metrics = Arc::clone(&metrics);
                let snapshot = Arc::clone(&snapshot);
                let engine_up = Arc::clone(&engine_up);
                let queue_tx = queue_tx.clone();
                pool.execute(move || {
                    handle_conn(&mut stream, &metrics, &snapshot, &engine_up, &queue_tx);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => {
                eprintln!("accept error: {e}");
                break;
            }
        }
    }
    drop(pool); // joins HTTP workers, dropping their queue_tx clones
    drop(queue_tx); // closes the queue; the scheduler drains and exits
    let _ = engine_worker.join();
    Ok(())
}

fn handle_conn(
    stream: &mut std::net::TcpStream,
    metrics: &ServerMetrics,
    snapshot: &Mutex<ServeSnapshot>,
    engine_up: &AtomicBool,
    queue_tx: &SyncSender<GenRequest>,
) {
    let req = match http::read_request(stream) {
        Ok(r) => r,
        Err(_) => {
            let _ = http::write_response(stream, 400, "text/plain", b"bad request");
            return;
        }
    };
    metrics.requests.fetch_add(1, Ordering::Relaxed);
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            if engine_up.load(Ordering::Relaxed) {
                let _ = http::write_response(stream, 200, "text/plain", b"ok");
            } else {
                let _ = http::write_response(stream, 503, "text/plain", b"engine down");
            }
        }
        ("GET", "/metrics") => {
            let snap = snapshot.lock().unwrap().clone();
            let body = json::to_string(&metrics_json(metrics, &snap));
            let _ = http::write_response(stream, 200, "application/json", body.as_bytes());
        }
        ("POST", "/generate") => match parse_gen_request(&req.body) {
            Ok((prompt, n, sampling)) => {
                let (tx, rx) = channel();
                // increment BEFORE send so the scheduler's decrement can
                // never observe the gauge at zero for an enqueued request
                metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                match queue_tx.try_send(GenRequest { prompt, n_tokens: n, sampling, resp: tx }) {
                    Ok(()) => {}
                    Err(TrySendError::Full(_)) => {
                        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                        metrics.rejected_backpressure.fetch_add(1, Ordering::Relaxed);
                        let _ = http::write_response(
                            stream,
                            503,
                            "text/plain",
                            b"queue full (backpressure); retry later",
                        );
                        return;
                    }
                    Err(TrySendError::Disconnected(_)) => {
                        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = http::write_response(stream, 503, "text/plain", b"engine down");
                        return;
                    }
                }
                match rx.recv() {
                    Ok(Ok(resp)) => {
                        let body = gen_response_json(&resp);
                        let _ =
                            http::write_response(stream, 200, "application/json", body.as_bytes());
                    }
                    Ok(Err(ge)) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let body = json::to_string(&Value::obj(vec![(
                            "error",
                            Value::from(ge.message),
                        )]));
                        let _ = http::write_response(
                            stream,
                            ge.status,
                            "application/json",
                            body.as_bytes(),
                        );
                    }
                    Err(_) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = http::write_response(stream, 500, "text/plain", b"worker died");
                    }
                }
            }
            Err(msg) => {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                let body =
                    json::to_string(&Value::obj(vec![("error", Value::from(msg))]));
                let _ = http::write_response(stream, 400, "application/json", body.as_bytes());
            }
        },
        _ => {
            let _ = http::write_response(stream, 404, "text/plain", b"not found");
        }
    }
}

/// `moe-offload serve` entrypoint.
///
/// `--synthetic` serves seeded synthetic weights over the native backend so
/// the whole serve stack runs from a clean checkout (no artifacts, no
/// PJRT); without it, artifacts are loaded as in production.
pub fn cmd_serve(args: &Args) -> Result<()> {
    use crate::offload::store::HostExpertStore;
    use crate::runtime::artifacts::Artifacts;

    let port = args.usize_or("port", 7080)?;
    let dir = args.str_or("artifacts", "artifacts");
    let backend_kind = args.str_or("backend", "pjrt");
    let policy = crate::cache::PolicyKind::parse(&args.str_or("policy", "lfu"))
        .ok_or_else(|| anyhow::anyhow!("bad --policy"))?;
    let capacity = args.usize_or("capacity", 4)?;
    let quant = crate::quant::Scheme::parse(&args.str_or("quant", "int4"))
        .ok_or_else(|| anyhow::anyhow!("bad --quant"))?;
    let spec = args.bool("spec");
    let transfer_workers = crate::engine::EngineConfig::transfer_workers_from(args)?;
    let synthetic = args.bool("synthetic");
    let seed = args.usize_or("seed", 0)? as u64;
    let profile = crate::sim::hardware::by_name(&args.str_or("profile", "A100"))
        .ok_or_else(|| anyhow::anyhow!("bad --profile"))?;
    let serve_cfg = ServeConfig {
        http_workers: args.usize_or("http-workers", 4)?,
        max_sessions: args.usize_or("max-sessions", 8)?,
        queue_depth: args.usize_or("queue-depth", 64)?,
    };

    let listener = TcpListener::bind(("0.0.0.0", port as u16))?;
    let shutdown = Arc::new(AtomicBool::new(false));
    serve(
        listener,
        move || {
            let (weights, artifacts) = if synthetic {
                let w = Arc::new(crate::model::weights::generate_weights(
                    crate::model::ModelConfig::DEFAULT,
                    seed,
                ));
                (w, None)
            } else {
                let a = Artifacts::load(std::path::Path::new(&dir))?;
                let w = Arc::new(crate::model::Weights::load(&a.weights_path)?);
                (w, Some(a))
            };
            let backend: Box<dyn crate::runtime::Backend> = match &artifacts {
                Some(a) if backend_kind != "native" => {
                    Box::new(crate::runtime::pjrt::PjrtBackend::new(a, &weights)?)
                }
                _ => Box::new(crate::runtime::native::NativeBackend::new(Arc::clone(&weights))),
            };
            let store = Arc::new(HostExpertStore::build(&weights, quant)?);
            let mut cfg = crate::engine::EngineConfig::serving(capacity, policy, spec);
            cfg.transfer_workers = transfer_workers;
            cfg.profile = profile;
            cfg.seed = seed;
            Ok(crate::engine::InferenceEngine::new(backend, store, cfg))
        },
        serve_cfg,
        shutdown,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{CacheStats, PipelineStats, PrecisionRecall, SessionTally};
    use super::scheduler::SessionView;

    #[test]
    fn parse_gen_request_ok() {
        let (p, n, s) =
            parse_gen_request(br#"{"prompt":"hi","n_tokens":8,"greedy":true}"#).unwrap();
        assert_eq!(p, "hi");
        assert_eq!(n, 8);
        assert_eq!(s, Sampling::Greedy);
    }

    #[test]
    fn parse_gen_request_defaults() {
        let (_, n, s) = parse_gen_request(br#"{"prompt":"x"}"#).unwrap();
        assert_eq!(n, 32);
        assert!(matches!(s, Sampling::TopP { .. }));
    }

    #[test]
    fn parse_gen_request_rejects() {
        assert!(parse_gen_request(b"{}").is_err());
        assert!(parse_gen_request(b"not json").is_err());
        assert!(parse_gen_request(br#"{"prompt":"x","n_tokens":0}"#).is_err());
    }

    #[test]
    fn response_json_shape() {
        let r = GenResponse {
            text: "abc".into(),
            n_prompt: 4,
            n_generated: 3,
            wall_s: 0.5,
            sim_tokens_per_s: 12.25,
            cache_hit_rate: 0.75,
            session_id: 9,
            session_hits: 30,
            session_misses: 10,
            spec_precision: 0.5,
            spec_recall: 0.5,
        };
        let v = json::parse(&gen_response_json(&r)).unwrap();
        assert_eq!(v.get("text").as_str(), Some("abc"));
        assert_eq!(v.get("n_generated").as_usize(), Some(3));
        assert_eq!(v.get("cache_hit_rate").as_f64(), Some(0.75));
        assert_eq!(v.get("session_id").as_usize(), Some(9));
        assert_eq!(v.get("session_hits").as_usize(), Some(30));
        assert_eq!(v.get("spec_precision").as_f64(), Some(0.5));
    }

    #[test]
    fn metrics_json_reports_single_shared_cache_with_sessions() {
        let metrics = ServerMetrics::default();
        metrics.requests.store(7, Ordering::Relaxed);
        let mut snap = ServeSnapshot {
            policy: "lfu".into(),
            capacity_per_layer: 4,
            n_layers: 12,
            active_sessions: 2,
            completed_sessions: 5,
            failed_sessions: 1,
            cache: CacheStats { hits: 90, misses: 10, ..Default::default() },
            spec: PrecisionRecall { tp: 8, fp: 2, fn_: 2 },
            cross_session_prefetch_hits: 3,
            pipeline: PipelineStats {
                workers: 2,
                demand_joined_prefetch: 4,
                cancelled_prefetches: 1,
                pool_allocs: 10,
                pool_reuses: 90,
                ..Default::default()
            },
            sessions: Vec::new(),
        };
        for id in 1..=2u64 {
            snap.sessions.push(SessionView {
                id,
                state: "active",
                n_prompt: 5,
                generated: 3,
                target: 8,
                tally: SessionTally { tokens: 8, hits: 45, misses: 5, ..Default::default() },
            });
        }
        let v = metrics_json(&metrics, &snap);
        assert_eq!(v.get("requests").as_usize(), Some(7));
        assert_eq!(v.get("failed_sessions").as_usize(), Some(1));
        let cache = v.get("shared_cache");
        assert_eq!(cache.get("policy").as_str(), Some("lfu"));
        assert_eq!(cache.get("hits").as_usize(), Some(90));
        assert_eq!(cache.get("cross_session_prefetch_hits").as_usize(), Some(3));
        let pipe = v.get("transfer_pipeline");
        assert_eq!(pipe.get("workers").as_usize(), Some(2));
        assert_eq!(pipe.get("demand_joined_prefetch").as_usize(), Some(4));
        assert_eq!(pipe.get("cancelled_prefetches").as_usize(), Some(1));
        assert_eq!(pipe.get("pool_reuse_rate").as_f64(), Some(0.9));
        let sessions = v.get("sessions").as_arr().unwrap();
        assert_eq!(sessions.len(), 2);
        assert_eq!(sessions[0].get("hits").as_usize(), Some(45));
        // per-session traffic partitions the single shared cache's totals
        let part: usize = sessions
            .iter()
            .map(|s| s.get("hits").as_usize().unwrap() + s.get("misses").as_usize().unwrap())
            .sum();
        assert_eq!(
            part,
            cache.get("hits").as_usize().unwrap() + cache.get("misses").as_usize().unwrap()
        );
    }
}
