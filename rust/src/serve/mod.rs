//! HTTP serving front: completion-routed request flow with real admission
//! control over N engine replicas.
//!
//! Architecture (DESIGN.md §6, §12): HTTP workers only parse,
//! admission-check, and enqueue — they never block on a decode. An
//! accepted `/generate` carries its client socket through the bounded
//! [`AdmissionQueue`] into a scheduler ([`scheduler`]): with
//! `--engine-workers N` the server runs N engine replicas, each owning
//! its own scheduler loop, (non-`Send`) backend, device expert cache, and
//! KV, all pulling from the ONE admission queue through a
//! [`ReplicaRouter`] that assigns sessions to the least-loaded alive
//! replica (with optional client-pinned session affinity) while every
//! replica shares the ONE `HostExpertStore` — disk promotions and the
//! host RAM budget stay global. Each scheduler continuously batches up to
//! `max_sessions` sessions on its replica — per round at most one decode
//! token per session plus at most one prefill chunk (`--prefill-chunk`),
//! under an optional total-token round budget (`--round-budget-tokens`)
//! with deficit carry-over. Finished generations are posted to a
//! completion channel and a small responder set writes the HTTP
//! responses, so a worker is freed the moment a request is admitted and
//! `queue_depth` is the true bound on buffered work. A replica that exits
//! or panics quarantines only itself (its in-flight sessions answer 500,
//! `engine_replicas_alive` decrements, the queue stays open); the queue
//! closes when the LAST replica dies.
//!
//! Admission control, in the order a request meets it:
//!   1. in-flight session cap (`--max-inflight-sessions`): accepted but
//!      unfinished requests (queued + decoding + awaiting a responder
//!      write) are bounded; beyond the cap `/generate` answers 503 +
//!      `Retry-After` immediately;
//!   2. bounded queue (`--queue-depth`): when full, 503 + `Retry-After`
//!      (backpressure, not buffering);
//!   3. queue-age shed (`--queue-timeout-ms`): a request that waited past
//!      its deadline is shed with 503 + `Retry-After` at dequeue, before
//!      it consumes a single engine step.
//!
//! API:
//!   POST /generate   {"prompt": str, "n_tokens": int, "temperature"?: f,
//!                     "top_p"?: f, "greedy"?: bool}
//!                    -> text + per-session cache/speculation stats
//!                    `?stream=1` streams the decoded text as chunked
//!                    transfer frames instead (DESIGN.md §9); the
//!                    concatenated chunks equal the buffered `text` field
//!                    byte for byte. `?priority=batch` (or an
//!                    `x-priority: batch` header) opts into the
//!                    throughput tier; default is `interactive`.
//!   GET  /metrics    serve counters (rejected/shed/queue-wait percentiles)
//!                    + aggregate and per-session counters over the ONE
//!                    shared expert cache (JSON)
//!   GET  /healthz

pub mod http;
pub mod scheduler;

use crate::model::sampler::Sampling;
use crate::util::cliargs::Args;
use crate::util::json::{self, Value};
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use self::scheduler::{run_scheduler, SchedulerConfig, ServeSnapshot};
use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

pub use crate::metrics::ServeMetrics;

/// Per-syscall socket timeout on client connections (SO_RCVTIMEO /
/// SO_SNDTIMEO). A completely stalled peer unblocks within this.
/// Drip-feeding peers are bounded separately: reads by the absolute
/// per-request deadline inside [`http::read_request`], writes by response
/// bodies being far smaller than the kernel send buffer (a `write_all`
/// lands in the buffer without waiting on the client's read rate).
const CLIENT_IO_TIMEOUT: Duration = Duration::from_secs(10);

/// `Retry-After` seconds advertised on admission-control 503s.
pub const RETRY_AFTER_S: u64 = 1;

/// Result of one generation, as delivered to the reply path.
pub type GenResult = std::result::Result<GenResponse, GenError>;

/// Request priority class (DESIGN.md §9). `Interactive` (the default)
/// outranks `Batch` at admission pop and inside the scheduler's round
/// budget, and is the only class allowed to degrade under a demand-miss
/// deadline; `Batch` trades latency for never-degraded output, with an
/// anti-starvation promotion bounding how long it can be outranked.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Priority {
    #[default]
    Interactive,
    Batch,
}

impl Priority {
    pub fn parse(s: &str) -> Option<Priority> {
        match s.trim().to_ascii_lowercase().as_str() {
            "interactive" => Some(Priority::Interactive),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

/// Where a finished (or refused) generation is delivered.
pub enum ReplyTo {
    /// In-process channel — tests, benches, offline drivers. Delivered
    /// inline by the scheduler (a channel send cannot block).
    Channel(Sender<GenResult>),
    /// Completion-routed: the client socket rides through the scheduler
    /// and a responder thread writes the buffered HTTP response.
    Socket(TcpStream),
    /// Streamed (`/generate?stream=1`): the scheduler appends decoded text
    /// to the connection's buffer as tokens land and posts
    /// [`Completion::Chunk`] flush events; a responder writes the chunked
    /// frames. Delivery of the final result marks end-of-stream.
    Stream(Arc<StreamConn>),
}

impl ReplyTo {
    /// Deliver `result`: inline for channels, via the completion channel
    /// (and thus a responder thread) for sockets and streams — the
    /// scheduler must never write to a client socket itself.
    pub fn deliver(self, result: GenResult, completions: &Sender<Completion>) {
        match self {
            ReplyTo::Channel(tx) => {
                let _ = tx.send(result);
            }
            ReplyTo::Socket(stream) => {
                let _ = completions.send(Completion::Done { stream, result });
            }
            ReplyTo::Stream(conn) => {
                conn.finish(result.err());
                let _ = completions.send(Completion::Chunk { conn });
            }
        }
    }
}

/// A unit of responder work.
pub enum Completion {
    /// A finished buffered generation routed back to its client socket.
    Done { stream: TcpStream, result: GenResult },
    /// A streamed session has pending text (or its end-of-stream marker)
    /// to flush. The text itself rides the connection's shared buffer, so
    /// N responders draining one session cannot reorder it.
    Chunk { conn: Arc<StreamConn> },
}

/// A streamed `/generate` connection, shared between the scheduler (which
/// appends text and eventually the final result) and the responder set
/// (which writes chunked frames). The `stream` mutex serializes writers;
/// `state` carries the undelivered text and the stream's lifecycle flags.
pub struct StreamConn {
    stream: Mutex<TcpStream>,
    state: Mutex<StreamState>,
    /// Latched true by a failed write or an EOF peek — the scheduler's
    /// disconnect sweep reads it without touching the socket again.
    disconnected: AtomicBool,
}

struct StreamState {
    /// Decoded-but-unflushed text (appended by the scheduler, drained by
    /// whichever responder handles the next flush event).
    buf: String,
    /// The scheduler delivered the final result; flush the tail and
    /// terminate (or report `error`).
    ended: bool,
    error: Option<GenError>,
    headers_sent: bool,
    /// Terminal: the response is fully written (or abandoned) and the
    /// in-flight slot released. Later flush events are no-ops.
    finished: bool,
}

impl StreamConn {
    pub fn new(stream: TcpStream) -> Arc<StreamConn> {
        Arc::new(StreamConn {
            stream: Mutex::new(stream),
            state: Mutex::new(StreamState {
                buf: String::new(),
                ended: false,
                error: None,
                headers_sent: false,
                finished: false,
            }),
            disconnected: AtomicBool::new(false),
        })
    }

    /// Scheduler side: append newly decoded text. A
    /// [`Completion::Chunk`] event must follow for a responder to flush
    /// it.
    pub fn push_text(&self, text: &str) {
        self.state.lock().unwrap().buf.push_str(text);
    }

    /// Scheduler side: mark the stream ended, carrying the failure (if
    /// any) for the responder to report.
    pub fn finish(&self, error: Option<GenError>) {
        let mut st = self.state.lock().unwrap();
        st.ended = true;
        st.error = error;
    }

    /// Is the client known (failed write) or observed (zero-byte peek =
    /// EOF) to be gone? Non-blocking — the scheduler calls this every
    /// round for its disconnect sweep; a responder holding the stream
    /// lock mid-write just means "alive as far as we know".
    pub fn client_gone(&self) -> bool {
        if self.disconnected.load(Ordering::Relaxed) {
            return true;
        }
        let Ok(stream) = self.stream.try_lock() else {
            return false;
        };
        if stream.set_nonblocking(true).is_err() {
            return false;
        }
        let mut buf = [0u8; 1];
        let gone = match stream.peek(&mut buf) {
            Ok(0) => true, // orderly shutdown from the peer
            Ok(_) => false,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
            Err(_) => true,
        };
        let _ = stream.set_nonblocking(false);
        if gone {
            self.disconnected.store(true, Ordering::Relaxed);
        }
        gone
    }
}

pub struct GenRequest {
    pub prompt: String,
    pub n_tokens: usize,
    pub sampling: Sampling,
    pub priority: Priority,
    pub reply: ReplyTo,
    /// Session-affinity key (`?affinity=` / `x-session-affinity`): requests
    /// with the same key decode on the same engine replica while it stays
    /// alive (KV/cache warmth for conversation-style clients). `None`
    /// routes by least load.
    pub affinity: Option<u64>,
    /// When the request entered the admission queue; queue-age shedding
    /// and the queue-wait percentiles both measure from here.
    pub enqueued: Instant,
}

/// A failed generation, classified for the HTTP layer: 400-class statuses
/// are the client's fault (validation), 500-class the server's (engine
/// failure mid-decode), 503 is admission control (shed / engine down).
#[derive(Clone, Debug)]
pub struct GenError {
    pub status: u16,
    pub message: String,
    /// `Retry-After` seconds to advertise (admission-control 503s).
    pub retry_after: Option<u64>,
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    pub text: String,
    pub n_prompt: usize,
    pub n_generated: usize,
    pub wall_s: f64,
    /// Tokens/s on the simulated clock over this session's lifetime —
    /// includes contention from concurrently decoded sessions.
    pub sim_tokens_per_s: f64,
    /// This session's share of the shared cache's traffic.
    pub cache_hit_rate: f64,
    pub session_id: u64,
    pub session_hits: u64,
    pub session_misses: u64,
    /// Speculative-prefetch quality for this session's own guesses.
    pub spec_precision: f64,
    pub spec_recall: f64,
}

/// Serve-layer knobs (queue + concurrency; the engine has its own config).
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Threads parsing HTTP requests and running admission checks. Workers
    /// never block on decodes (completion routing), so this needs no
    /// relation to `max_sessions` — it only sizes parse throughput.
    pub http_workers: usize,
    /// Decode sessions interleaved concurrently on the engine worker.
    pub max_sessions: usize,
    /// Bounded admission-queue depth; beyond it, `/generate` answers 503.
    pub queue_depth: usize,
    /// Responder threads writing completed responses to client sockets.
    pub responders: usize,
    /// Shed queued requests older than this with 503 + `Retry-After`
    /// instead of a stale decode (0 = never shed).
    pub queue_timeout_ms: u64,
    /// Cap on accepted-but-unfinished requests (queued + decoding +
    /// awaiting a responder write); beyond it, `/generate` answers 503.
    /// Distinct from `queue_depth`, which bounds only the waiting queue.
    pub max_inflight_sessions: usize,
    /// Chunked prefill: split each prompt into chunks of this many tokens,
    /// at most one chunk per scheduler round, rotated across prefilling
    /// sessions — a long prompt can no longer head-of-line block other
    /// sessions' first tokens. `0` = legacy one-token-per-session rounds.
    pub prefill_chunk: usize,
    /// Cap on total tokens (decode + prefill) the scheduler advances per
    /// round, with deficit carry-over for candidates it had to skip.
    /// `0` = unbounded.
    pub round_budget_tokens: usize,
    /// Round-level expert batching (on by default): each scheduler round
    /// dispatches all its tokens through one engine round so sessions
    /// routing to the same `(layer, expert)` share a single transfer +
    /// dequant + batched FFN pass. `--round-batching off` falls back to
    /// the bit-identical per-session step loop.
    pub round_batching: bool,
    /// Seconds advertised in the `Retry-After` header of EVERY
    /// admission-control 503 — backpressure (queue full), in-flight cap,
    /// and scheduler sheds all quote this one value (`--retry-after-s`),
    /// so clients see a single consistent back-off policy.
    pub retry_after: u64,
    /// Engine replicas (`--engine-workers`): each runs its own scheduler
    /// loop, backend, device expert cache, and KV over the shared
    /// admission queue and the ONE shared host expert store.
    /// `max_sessions` is per replica.
    pub engine_workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            http_workers: 4,
            max_sessions: 8,
            queue_depth: 64,
            responders: 2,
            queue_timeout_ms: 0,
            max_inflight_sessions: 128,
            prefill_chunk: 0,
            round_budget_tokens: 0,
            round_batching: true,
            retry_after: RETRY_AFTER_S,
            engine_workers: 1,
        }
    }
}

// ---------------------------------------------------------------------------
// replica router
// ---------------------------------------------------------------------------

/// Assigns sessions to engine replicas (DESIGN.md §12). One slot per
/// replica tracks liveness, current load (active sessions, reported by the
/// replica's scheduler), and lifetime admissions. Routing is evaluated at
/// claim time under the admission queue's lock
/// ([`AdmissionQueue::pop_routed`]):
///
/// * a request with an affinity key is claimable only by the ONE alive
///   replica the key pins to ([`ReplicaRouter::affinity_target`]);
/// * a request without one is claimable by any alive replica at minimum
///   load — ties mean whoever takes the queue lock first wins.
///
/// Liveness: an idle replica (zero active sessions) is always at minimum
/// load, so an eligible claimant exists for every unpinned request while
/// any replica lives; affinity keys remap over the alive set when a
/// replica dies, so no request can pin to a corpse.
pub struct ReplicaRouter {
    slots: Vec<ReplicaSlot>,
}

struct ReplicaSlot {
    alive: AtomicBool,
    /// Sessions currently decoding on the replica (scheduler-reported).
    active: AtomicUsize,
    /// Sessions the replica has admitted over its lifetime.
    admitted: AtomicU64,
}

impl ReplicaRouter {
    pub fn new(n: usize) -> Arc<ReplicaRouter> {
        Arc::new(ReplicaRouter {
            slots: (0..n.max(1))
                .map(|_| ReplicaSlot {
                    alive: AtomicBool::new(true),
                    active: AtomicUsize::new(0),
                    admitted: AtomicU64::new(0),
                })
                .collect(),
        })
    }

    /// Configured replica count (alive or not).
    pub fn n(&self) -> usize {
        self.slots.len()
    }

    pub fn alive_count(&self) -> usize {
        self.slots.iter().filter(|s| s.alive.load(Ordering::Relaxed)).count()
    }

    pub fn is_alive(&self, id: usize) -> bool {
        self.slots[id].alive.load(Ordering::Relaxed)
    }

    /// Quarantine `id`; returns how many replicas remain alive. Idempotent
    /// — a clean scheduler exit and the worker guard both land here.
    pub fn mark_dead(&self, id: usize) -> usize {
        self.slots[id].alive.store(false, Ordering::Relaxed);
        self.alive_count()
    }

    /// Load report: replica `id` currently decodes `active` sessions. An
    /// absolute store (not a delta) so the gauge cannot drift.
    pub fn set_active(&self, id: usize, active: usize) {
        self.slots[id].active.store(active, Ordering::Relaxed);
    }

    pub fn note_admitted(&self, id: usize) {
        self.slots[id].admitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Lifetime sessions admitted per replica (`/metrics` `replicas[*]`,
    /// and the bench's per-replica session counts).
    pub fn admitted_counts(&self) -> Vec<u64> {
        self.slots.iter().map(|s| s.admitted.load(Ordering::Relaxed)).collect()
    }

    /// May replica `id` claim a request carrying `affinity`? Pinned
    /// requests route to their target; unpinned ones to any alive replica
    /// at minimum load.
    pub fn routes_to(&self, id: usize, affinity: Option<u64>) -> bool {
        if !self.is_alive(id) {
            return false;
        }
        match affinity {
            Some(k) => self.affinity_target(k) == Some(id),
            None => {
                let mine = self.slots[id].active.load(Ordering::Relaxed);
                self.slots
                    .iter()
                    .filter(|s| s.alive.load(Ordering::Relaxed))
                    .map(|s| s.active.load(Ordering::Relaxed))
                    .min()
                    .is_some_and(|least| mine <= least)
            }
        }
    }

    /// The alive replica an affinity key pins to: position `key mod
    /// alive_count` of the alive set — stable while membership is stable,
    /// remapped automatically when a replica dies. `None` only when no
    /// replica lives (the queue is closing anyway).
    pub fn affinity_target(&self, key: u64) -> Option<usize> {
        let alive: Vec<usize> =
            (0..self.slots.len()).filter(|&i| self.is_alive(i)).collect();
        if alive.is_empty() {
            None
        } else {
            Some(alive[(key % alive.len() as u64) as usize])
        }
    }
}

// ---------------------------------------------------------------------------
// bounded admission queue
// ---------------------------------------------------------------------------

/// Outcome of a rejected [`AdmissionQueue::try_push`]; the request is
/// handed back so the caller can answer its client.
pub enum PushRejected {
    /// The queue is at `depth`; 503 backpressure.
    Full(GenRequest),
    /// The queue was closed (engine down / shutdown).
    Closed(GenRequest),
}

/// Outcome of an [`AdmissionQueue::pop`].
pub enum Popped {
    Req(GenRequest),
    /// Nothing queued (non-blocking pop only).
    Empty,
    /// Closed AND drained — no request will ever arrive again.
    Closed,
}

/// The bounded admission queue between HTTP workers and the scheduler.
///
/// Unlike a `sync_channel`, the queue is inspectable: the scheduler sheds
/// aged requests ([`AdmissionQueue::take_aged`]) every round without
/// admitting them, and the `queue_depth` gauge is maintained under the
/// queue lock so it is exact — it can never exceed `depth`.
pub struct AdmissionQueue {
    state: Mutex<QueueState>,
    available: Condvar,
    depth: usize,
    metrics: Arc<ServeMetrics>,
}

struct QueueState {
    q: VecDeque<GenRequest>,
    closed: bool,
}

impl AdmissionQueue {
    pub fn new(depth: usize, metrics: Arc<ServeMetrics>) -> Arc<AdmissionQueue> {
        Arc::new(AdmissionQueue {
            state: Mutex::new(QueueState { q: VecDeque::new(), closed: false }),
            available: Condvar::new(),
            depth: depth.max(1),
            metrics,
        })
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit `req` unless the queue is full or closed.
    pub fn try_push(&self, req: GenRequest) -> std::result::Result<(), PushRejected> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(PushRejected::Closed(req));
        }
        if st.q.len() >= self.depth {
            return Err(PushRejected::Full(req));
        }
        st.q.push_back(req);
        self.metrics.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
        // notify_all, not notify_one: consumers are *selective* under
        // multi-replica routing (an affinity-pinned request is claimable by
        // exactly one replica), so waking one arbitrary sleeper could wake
        // a replica that must leave this request in place.
        self.available.notify_all();
        Ok(())
    }

    /// Pop the oldest *interactive* request, falling back to the oldest
    /// request of any class — FIFO within a priority class, interactive
    /// ahead of batch across classes. Under shed pressure this is the SLO
    /// tiering: batch requests wait longer and therefore age out first.
    /// With `block`, waits until a request arrives or the queue closes;
    /// otherwise returns [`Popped::Empty`] right away.
    pub fn pop(&self, block: bool) -> Popped {
        let mut st = self.state.lock().unwrap();
        loop {
            let idx = st
                .q
                .iter()
                .position(|r| r.priority == Priority::Interactive)
                .or(if st.q.is_empty() { None } else { Some(0) });
            if let Some(i) = idx {
                let r = st.q.remove(i).unwrap();
                self.metrics.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
                return Popped::Req(r);
            }
            if st.closed {
                return Popped::Closed;
            }
            if !block {
                return Popped::Empty;
            }
            st = self.available.wait(st).unwrap();
        }
    }

    /// Remove and return every queued request older than `max_age`,
    /// preserving arrival order — the scheduler's shed sweep.
    pub fn take_aged(&self, max_age: Duration) -> Vec<GenRequest> {
        let mut st = self.state.lock().unwrap();
        let mut out = Vec::new();
        let mut i = 0;
        while i < st.q.len() {
            if st.q[i].enqueued.elapsed() > max_age {
                out.push(st.q.remove(i).unwrap());
            } else {
                i += 1;
            }
        }
        if !out.is_empty() {
            self.metrics.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
        }
        out
    }

    /// Pop the oldest request routable to `replica`, interactive class
    /// first ([`AdmissionQueue::pop`]'s SLO tiering), *after* removing
    /// every aged request — claim and shed are decided under ONE
    /// acquisition of the queue lock, so with N schedulers popping
    /// concurrently a request can never be both claimed by one replica
    /// and shed by another (the exactly-once invariant).
    ///
    /// Returns the claim outcome plus the aged requests this sweep
    /// removed; the caller owns shedding them. On `(Popped::Empty, aged)`
    /// with a non-empty `aged` a blocking caller gets control back to
    /// shed before re-blocking, so sheds are never delayed behind a wait.
    pub fn pop_routed(
        &self,
        replica: usize,
        router: &ReplicaRouter,
        block: bool,
        max_age: Option<Duration>,
    ) -> (Popped, Vec<GenRequest>) {
        let mut st = self.state.lock().unwrap();
        let mut aged = Vec::new();
        if let Some(max_age) = max_age {
            let mut i = 0;
            while i < st.q.len() {
                if st.q[i].enqueued.elapsed() > max_age {
                    aged.push(st.q.remove(i).unwrap());
                } else {
                    i += 1;
                }
            }
        }
        loop {
            let eligible = |r: &GenRequest| router.routes_to(replica, r.affinity);
            let idx = st
                .q
                .iter()
                .position(|r| r.priority == Priority::Interactive && eligible(r))
                .or_else(|| st.q.iter().position(eligible));
            if let Some(i) = idx {
                let r = st.q.remove(i).unwrap();
                self.metrics.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
                return (Popped::Req(r), aged);
            }
            if !aged.is_empty() {
                self.metrics.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
                return (Popped::Empty, aged);
            }
            if st.closed {
                return (Popped::Closed, aged);
            }
            if !block {
                return (Popped::Empty, aged);
            }
            st = self.available.wait(st).unwrap();
        }
    }

    /// Wake every blocked consumer so it re-evaluates routing — called
    /// when replica membership changes (a death remaps affinity targets,
    /// making requests claimable by survivors that previously had to
    /// leave them in place).
    pub fn wake_all(&self) {
        let _st = self.state.lock().unwrap();
        self.available.notify_all();
    }

    /// Close the queue: pending requests can still be popped, new pushes
    /// are refused, and blocked pops wake.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.available.notify_all();
    }
}

// ---------------------------------------------------------------------------
// /metrics rendering
// ---------------------------------------------------------------------------

/// Render `/metrics`: serve counters + the scheduler's latest snapshot.
/// The `shared_cache` object is singular by design — all sessions run over
/// ONE expert cache; `sessions[*]` partitions its traffic.
pub fn metrics_json(metrics: &ServeMetrics, snap: &ServeSnapshot) -> Value {
    let sessions: Vec<Value> = snap
        .sessions
        .iter()
        .map(|s| {
            Value::obj(vec![
                ("id", Value::from(s.id as f64)),
                ("state", Value::from(s.state)),
                ("n_prompt", Value::from(s.n_prompt)),
                ("generated", Value::from(s.generated)),
                ("target", Value::from(s.target)),
                ("tokens", Value::from(s.tally.tokens as f64)),
                ("hits", Value::from(s.tally.hits as f64)),
                ("misses", Value::from(s.tally.misses as f64)),
                ("hit_rate", Value::from(s.tally.hit_rate())),
                ("spec_precision", Value::from(s.tally.spec_pr.precision())),
                ("spec_recall", Value::from(s.tally.spec_pr.recall())),
                ("wasted_prefetches", Value::from(s.tally.wasted_prefetches as f64)),
            ])
        })
        .collect();
    Value::obj(vec![
        ("requests", Value::from(metrics.requests.load(Ordering::Relaxed) as f64)),
        ("errors", Value::from(metrics.errors.load(Ordering::Relaxed) as f64)),
        (
            "client_disconnects",
            Value::from(metrics.client_disconnects.load(Ordering::Relaxed) as f64),
        ),
        ("write_errors", Value::from(metrics.write_errors.load(Ordering::Relaxed) as f64)),
        (
            "cancelled_sessions",
            Value::from(metrics.cancelled_sessions.load(Ordering::Relaxed) as f64),
        ),
        ("rejected_total", Value::from(metrics.rejected_total() as f64)),
        (
            "rejected_backpressure",
            Value::from(metrics.rejected_backpressure.load(Ordering::Relaxed) as f64),
        ),
        (
            "rejected_inflight",
            Value::from(metrics.rejected_inflight.load(Ordering::Relaxed) as f64),
        ),
        ("shed_total", Value::from(metrics.shed_total.load(Ordering::Relaxed) as f64)),
        (
            "tokens_generated",
            Value::from(metrics.tokens_generated.load(Ordering::Relaxed) as f64),
        ),
        (
            "tokens_prefill",
            Value::from(metrics.tokens_prefill.load(Ordering::Relaxed) as f64),
        ),
        ("degraded_tokens", Value::from(snap.degraded_tokens as f64)),
        ("fetch_retries", Value::from(snap.fetch_retries as f64)),
        ("prefill_backlog", Value::from(snap.prefill_backlog)),
        ("queue_depth", Value::from(metrics.queue_depth.load(Ordering::Relaxed) as f64)),
        (
            "inflight_sessions",
            Value::from(metrics.inflight_sessions.load(Ordering::Relaxed) as f64),
        ),
        (
            "engine_replicas_alive",
            Value::from(metrics.engine_replicas_alive.load(Ordering::Relaxed) as f64),
        ),
        (
            "queue_wait_ns",
            Value::obj(vec![
                ("count", Value::from(metrics.queue_wait.count() as f64)),
                ("p50", Value::from(metrics.queue_wait.percentile_ns(0.50) as f64)),
                ("p99", Value::from(metrics.queue_wait.percentile_ns(0.99) as f64)),
            ]),
        ),
        (
            "ttft_ns",
            Value::obj(vec![
                ("count", Value::from(metrics.ttft.count() as f64)),
                ("p50", Value::from(metrics.ttft.percentile_ns(0.50) as f64)),
                ("p99", Value::from(metrics.ttft.percentile_ns(0.99) as f64)),
            ]),
        ),
        (
            "ttft_interactive_ns",
            Value::obj(vec![
                ("count", Value::from(metrics.ttft_interactive.count() as f64)),
                ("p50", Value::from(metrics.ttft_interactive.percentile_ns(0.50) as f64)),
                ("p99", Value::from(metrics.ttft_interactive.percentile_ns(0.99) as f64)),
            ]),
        ),
        (
            "ttft_batch_ns",
            Value::obj(vec![
                ("count", Value::from(metrics.ttft_batch.count() as f64)),
                ("p50", Value::from(metrics.ttft_batch.percentile_ns(0.50) as f64)),
                ("p99", Value::from(metrics.ttft_batch.percentile_ns(0.99) as f64)),
            ]),
        ),
        ("active_sessions", Value::from(snap.active_sessions)),
        ("completed_sessions", Value::from(snap.completed_sessions as f64)),
        ("failed_sessions", Value::from(snap.failed_sessions as f64)),
        (
            "shared_cache",
            Value::obj(vec![
                ("policy", Value::from(snap.policy.clone())),
                ("capacity_per_layer", Value::from(snap.capacity_per_layer)),
                ("n_layers", Value::from(snap.n_layers)),
                ("hits", Value::from(snap.cache.hits as f64)),
                ("misses", Value::from(snap.cache.misses as f64)),
                ("evictions", Value::from(snap.cache.evictions as f64)),
                ("hit_rate", Value::from(snap.cache.hit_rate())),
                ("prefetch_hits", Value::from(snap.cache.prefetch_hits as f64)),
                (
                    "cross_session_prefetch_hits",
                    Value::from(snap.cross_session_prefetch_hits as f64),
                ),
            ]),
        ),
        (
            "transfer_pipeline",
            Value::obj(vec![
                ("workers", Value::from(snap.pipeline.workers as f64)),
                ("submitted_demand", Value::from(snap.pipeline.submitted_demand as f64)),
                ("submitted_prefetch", Value::from(snap.pipeline.submitted_prefetch as f64)),
                ("completed", Value::from(snap.pipeline.completed as f64)),
                (
                    "demand_joined_prefetch",
                    Value::from(snap.pipeline.demand_joined_prefetch as f64),
                ),
                (
                    "cancelled_prefetches",
                    Value::from(snap.pipeline.cancelled_prefetches as f64),
                ),
                ("peak_in_flight", Value::from(snap.pipeline.peak_in_flight as f64)),
                ("pool_allocs", Value::from(snap.pipeline.pool_allocs as f64)),
                ("pool_reuses", Value::from(snap.pipeline.pool_reuses as f64)),
                ("pool_reuse_rate", Value::from(snap.pipeline.pool_reuse_rate())),
            ]),
        ),
        (
            "round_batching",
            Value::obj(vec![
                ("rounds", Value::from(snap.round_batching.rounds as f64)),
                (
                    "distinct_experts",
                    Value::from(snap.round_batching.distinct_experts as f64),
                ),
                ("dedup_joins", Value::from(snap.round_batching.dedup_joins as f64)),
                ("batched_rows", Value::from(snap.round_batching.batched_rows as f64)),
                ("join_rate", Value::from(snap.round_batching.join_rate())),
            ]),
        ),
        (
            "speculation",
            Value::obj(vec![
                ("tp", Value::from(snap.spec.tp as f64)),
                ("fp", Value::from(snap.spec.fp as f64)),
                ("fn", Value::from(snap.spec.fn_ as f64)),
                ("precision", Value::from(snap.spec.precision())),
                ("recall", Value::from(snap.spec.recall())),
            ]),
        ),
        (
            "predictor",
            Value::obj(vec![
                ("active", Value::from(snap.predictor_active)),
                ("tp", Value::from(snap.predictor.tp as f64)),
                ("fp", Value::from(snap.predictor.fp as f64)),
                ("fn", Value::from(snap.predictor.fn_ as f64)),
                ("precision", Value::from(snap.predictor.precision())),
                ("recall", Value::from(snap.predictor.recall())),
                (
                    "skipped_records",
                    Value::from(snap.predictor_skipped_records as f64),
                ),
                (
                    "prefetch_hits_by_source",
                    Value::obj(vec![
                        ("gate", Value::from(snap.prefetch_hits_by_source[0] as f64)),
                        ("markov", Value::from(snap.prefetch_hits_by_source[1] as f64)),
                        ("learned", Value::from(snap.prefetch_hits_by_source[2] as f64)),
                    ]),
                ),
            ]),
        ),
        (
            "host_tier",
            Value::obj(vec![
                ("host_accesses", Value::from(snap.host_tier.host_accesses as f64)),
                ("ram_hits", Value::from(snap.host_tier.ram_hits as f64)),
                ("ram_hit_rate", Value::from(snap.host_tier.ram_hit_rate())),
                ("disk_promotions", Value::from(snap.host_tier.disk_promotions as f64)),
                ("ram_evictions", Value::from(snap.host_tier.ram_evictions as f64)),
                ("disk_read_ns", Value::from(snap.host_tier.disk_read_ns as f64)),
                ("disk_read_p99_ns", Value::from(snap.host_tier.disk_read_p99_ns as f64)),
            ]),
        ),
        ("sessions", Value::Arr(sessions)),
    ])
}

/// Render `/metrics` for a replicated engine: the per-replica snapshots
/// are merged ([`ServeSnapshot::merged`] — shared-store stats taken once,
/// per-replica stats summed) and rendered through [`metrics_json`], then
/// a `replicas` array with per-replica liveness, admissions, and cache
/// traffic is appended so operators can see skew, not just totals.
pub fn metrics_json_replicated(
    metrics: &ServeMetrics,
    snaps: &[ServeSnapshot],
    router: &ReplicaRouter,
) -> Value {
    let merged = ServeSnapshot::merged(snaps);
    let mut v = metrics_json(metrics, &merged);
    let admitted = router.admitted_counts();
    let replicas: Vec<Value> = snaps
        .iter()
        .enumerate()
        .map(|(i, s)| {
            Value::obj(vec![
                ("id", Value::from(i as f64)),
                ("alive", Value::from(router.is_alive(i))),
                ("admitted", Value::from(admitted.get(i).copied().unwrap_or(0) as f64)),
                ("active_sessions", Value::from(s.active_sessions)),
                ("completed_sessions", Value::from(s.completed_sessions as f64)),
                ("failed_sessions", Value::from(s.failed_sessions as f64)),
                ("cache_hits", Value::from(s.cache.hits as f64)),
                ("cache_misses", Value::from(s.cache.misses as f64)),
                ("cache_hit_rate", Value::from(s.cache.hit_rate())),
            ])
        })
        .collect();
    if let Value::Obj(map) = &mut v {
        map.insert("replicas".to_string(), Value::Arr(replicas));
    }
    v
}

/// Parse the /generate request body.
pub fn parse_gen_request(body: &[u8]) -> std::result::Result<(String, usize, Sampling), String> {
    let v = json::parse(std::str::from_utf8(body).map_err(|e| e.to_string())?)
        .map_err(|e| e.to_string())?;
    let prompt = v
        .get("prompt")
        .as_str()
        .ok_or("missing 'prompt'")?
        .to_string();
    let n = v.get("n_tokens").as_usize().unwrap_or(32);
    if n == 0 || n > 4096 {
        return Err(format!("n_tokens {n} out of range"));
    }
    let sampling = if v.get("greedy").as_bool() == Some(true) {
        Sampling::Greedy
    } else {
        Sampling::TopP {
            temperature: v.get("temperature").as_f64().unwrap_or(0.9) as f32,
            top_p: v.get("top_p").as_f64().unwrap_or(0.9) as f32,
        }
    };
    Ok((prompt, n, sampling))
}

pub fn gen_response_json(r: &GenResponse) -> String {
    json::to_string(&Value::obj(vec![
        ("text", Value::from(r.text.clone())),
        ("n_prompt", Value::from(r.n_prompt)),
        ("n_generated", Value::from(r.n_generated)),
        ("wall_s", Value::from(r.wall_s)),
        ("sim_tokens_per_s", Value::from(r.sim_tokens_per_s)),
        ("cache_hit_rate", Value::from(r.cache_hit_rate)),
        ("session_id", Value::from(r.session_id as f64)),
        ("session_hits", Value::from(r.session_hits as f64)),
        ("session_misses", Value::from(r.session_misses as f64)),
        ("spec_precision", Value::from(r.spec_precision)),
        ("spec_recall", Value::from(r.spec_recall)),
    ]))
}

// ---------------------------------------------------------------------------
// control plane: /metrics and /healthz on a dedicated non-pooled thread
// ---------------------------------------------------------------------------

enum ControlPath {
    Healthz,
    Metrics,
}

/// A connection owned by the control plane.
struct ControlConn {
    stream: TcpStream,
    path: ControlPath,
    /// `true` when routed straight from the accept loop (the request
    /// bytes are still unread and the control thread parses them itself,
    /// under [`CONTROL_PARSE_DEADLINE`]); `false` when an HTTP worker
    /// already consumed the request and only the render + write remain.
    raw: bool,
}

/// Absolute parse deadline for sniff-routed control requests — they are
/// single-line GETs whose bytes have normally arrived in full before the
/// control plane even picks them up, so anything slower is a drip-feeder
/// that must not monopolize a control thread. Also used as the per-read
/// socket timeout on those connections, bounding one malicious sniffed
/// socket's wedge to ~2× this value.
const CONTROL_PARSE_DEADLINE: Duration = Duration::from_millis(250);

/// Control-plane threads. Two, so one drip-fed control connection cannot
/// serialize every probe behind its (bounded) parse. Sustained
/// adversarial flooding of the control path itself is out of scope —
/// the guarantee is that *decode and parse load can never starve
/// `/metrics` and `/healthz`*.
const CONTROL_THREADS: usize = 2;

/// The dedicated control plane: `/metrics` and `/healthz` are answered
/// here, off the worker pool. Probes that send their request promptly
/// (every real orchestrator and scraper) are recognized by the
/// first-bytes sniff ([`sniff_once`], at accept or in the sniffer
/// thread) and never touch the pool at all, so they stay responsive even
/// when every pool worker is wedged mid-parse by slow clients AND every
/// decode slot is saturated. Per-request work is strictly bounded: at
/// most a [`CONTROL_PARSE_DEADLINE`]-bounded parse, a snapshot lock, a
/// JSON render, one socket write.
fn spawn_control_plane(
    rx: Receiver<ControlConn>,
    metrics: Arc<ServeMetrics>,
    snapshots: Arc<Vec<Arc<Mutex<ServeSnapshot>>>>,
    router: Arc<ReplicaRouter>,
    engine_up: Arc<AtomicBool>,
) -> Vec<std::thread::JoinHandle<()>> {
    let rx = Arc::new(Mutex::new(rx));
    (0..CONTROL_THREADS)
        .map(|i| {
            let rx = Arc::clone(&rx);
            let metrics = Arc::clone(&metrics);
            let snapshots = Arc::clone(&snapshots);
            let router = Arc::clone(&router);
            let engine_up = Arc::clone(&engine_up);
            std::thread::Builder::new()
                .name(format!("control-plane-{i}"))
                .spawn(move || loop {
                    let conn = match rx.lock().unwrap().recv() {
                        Ok(c) => c,
                        Err(_) => break, // every sender gone: shutdown
                    };
                    serve_control(conn, &metrics, &snapshots, &router, &engine_up);
                })
                .expect("spawn control plane")
        })
        .collect()
}

fn serve_control(
    conn: ControlConn,
    metrics: &ServeMetrics,
    snapshots: &[Arc<Mutex<ServeSnapshot>>],
    router: &ReplicaRouter,
    engine_up: &AtomicBool,
) {
    let mut stream = conn.stream;
    if conn.raw {
        // consume the (sniffed) request off the socket; the path is
        // already known from the sniff
        match http::read_request_bounded(&mut stream, CONTROL_PARSE_DEADLINE) {
            Ok(_) => {
                metrics.requests.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                // parity with the pool's parse-failure path: answer 400
                // rather than silently dropping the socket
                let _ = http::write_response(&mut stream, 400, "text/plain", b"bad request");
                return;
            }
        }
    }
    match conn.path {
        ControlPath::Healthz => {
            if engine_up.load(Ordering::Relaxed) {
                let _ = http::write_response(&mut stream, 200, "text/plain", b"ok");
            } else {
                let _ = http::write_response(&mut stream, 503, "text/plain", b"engine down");
            }
        }
        ControlPath::Metrics => {
            // clone each replica's snapshot under its own lock (no lock is
            // held across the render), then merge + render
            let snaps: Vec<ServeSnapshot> =
                snapshots.iter().map(|s| s.lock().unwrap().clone()).collect();
            let body = json::to_string(&metrics_json_replicated(metrics, &snaps, router));
            let _ = http::write_response(&mut stream, 200, "application/json", body.as_bytes());
        }
    }
}

// ---------------------------------------------------------------------------
// connection routing: first-bytes sniff + park-and-poll sniffer
// ---------------------------------------------------------------------------

/// One non-blocking route sniff of a connection's first bytes.
enum Sniff {
    /// The first bytes spell a control request line exactly.
    Control(ControlPath),
    /// Anything else — including EOF and socket errors, which the pool's
    /// bounded request read fails fast.
    Ordinary,
    /// First bytes not yet available (or still an ambiguous prefix of a
    /// control request line).
    Undecided,
}

/// Peek a (non-blocking) socket's first bytes once, without ever waiting:
/// `GET /healthz ` / `GET /metrics ` route to the control plane, any
/// other prefix to the pool, and a socket with no decisive bytes yet is
/// `Undecided` — the caller parks it with the sniffer instead of
/// sleeping.
fn sniff_once(stream: &TcpStream) -> Sniff {
    const HEALTHZ: &[u8] = b"GET /healthz ";
    const METRICS: &[u8] = b"GET /metrics ";
    let mut buf = [0u8; HEALTHZ.len()];
    match stream.peek(&mut buf) {
        Ok(n) if n >= buf.len() => {
            if &buf[..] == HEALTHZ {
                Sniff::Control(ControlPath::Healthz)
            } else if &buf[..] == METRICS {
                Sniff::Control(ControlPath::Metrics)
            } else {
                Sniff::Ordinary
            }
        }
        // EOF: the peer is gone; let the pool fail it fast
        Ok(0) => Sniff::Ordinary,
        Ok(n) => {
            if HEALTHZ.starts_with(&buf[..n]) || METRICS.starts_with(&buf[..n]) {
                Sniff::Undecided
            } else {
                Sniff::Ordinary
            }
        }
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Sniff::Undecided,
        Err(_) => Sniff::Ordinary,
    }
}

/// How long the sniffer waits for a connection's first bytes before
/// giving up and handing it to the pool (whose bounded request read
/// takes it from there). Parking adds no latency to such a connection —
/// nothing could parse it before its bytes arrive anyway.
const SNIFF_DEADLINE: Duration = Duration::from_secs(1);

/// Parked-connection cap: a connect-and-say-nothing flood must not grow
/// memory; overflow spills to the pool immediately.
const SNIFF_PENDING_CAP: usize = 1024;

/// Routes one accepted connection to its lane. Cloneable so the accept
/// loop and the sniffer thread share it.
#[derive(Clone)]
struct Dispatcher {
    pool: Arc<ThreadPool>,
    metrics: Arc<ServeMetrics>,
    queue: Arc<AdmissionQueue>,
    ctl_tx: Sender<ControlConn>,
    max_inflight: usize,
    /// `Retry-After` seconds for every admission-control 503 this
    /// dispatcher's workers write (`ServeConfig.retry_after`).
    retry_after: u64,
}

impl Dispatcher {
    fn dispatch(&self, stream: TcpStream, sniffed: Option<ControlPath>) {
        stream.set_nonblocking(false).ok();
        match sniffed {
            Some(path) => {
                // control probe: bypass the pool entirely. The read
                // timeout is the control parse deadline, NOT the general
                // client timeout: one stalled sniffed socket may wedge a
                // control thread for at most ~2×CONTROL_PARSE_DEADLINE.
                let _ = stream.set_read_timeout(Some(CONTROL_PARSE_DEADLINE));
                let _ = stream.set_write_timeout(Some(CLIENT_IO_TIMEOUT));
                let _ = self.ctl_tx.send(ControlConn { stream, path, raw: true });
            }
            None => {
                let metrics = Arc::clone(&self.metrics);
                let queue = Arc::clone(&self.queue);
                let ctl_tx = self.ctl_tx.clone();
                let max_inflight = self.max_inflight;
                let retry_after = self.retry_after;
                self.pool.execute(move || {
                    handle_conn(stream, &metrics, &ctl_tx, &queue, max_inflight, retry_after);
                });
            }
        }
    }
}

/// The park-and-poll sniffer: connections whose first bytes haven't
/// arrived yet are parked here and re-peeked every millisecond, so the
/// accept loop NEVER sleeps per connection and pool workers only ever
/// receive connections whose bytes are ready (or that outwaited
/// [`SNIFF_DEADLINE`]). This is what closes the accept-vs-first-byte
/// race for control probes without serializing accepts.
fn spawn_sniffer(rx: Receiver<TcpStream>, dispatcher: Dispatcher) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("sniffer".into())
        .spawn(move || {
            let mut pending: Vec<(TcpStream, Instant)> = Vec::new();
            loop {
                if pending.is_empty() {
                    // idle: block until a connection arrives or shutdown
                    match rx.recv() {
                        Ok(s) => pending.push((s, Instant::now())),
                        Err(_) => break,
                    }
                }
                while let Ok(s) = rx.try_recv() {
                    pending.push((s, Instant::now()));
                }
                while pending.len() > SNIFF_PENDING_CAP {
                    let (s, _) = pending.remove(0);
                    dispatcher.dispatch(s, None);
                }
                let mut i = 0;
                while i < pending.len() {
                    let route = match sniff_once(&pending[i].0) {
                        Sniff::Control(path) => Some(Some(path)),
                        Sniff::Ordinary => Some(None),
                        Sniff::Undecided => (pending[i].1.elapsed() > SNIFF_DEADLINE)
                            .then_some(None),
                    };
                    match route {
                        Some(r) => {
                            let (s, _) = pending.swap_remove(i);
                            dispatcher.dispatch(s, r);
                        }
                        None => i += 1,
                    }
                }
                if !pending.is_empty() {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        })
        .expect("spawn sniffer")
}

// ---------------------------------------------------------------------------
// responders: write completed responses to client sockets
// ---------------------------------------------------------------------------

fn spawn_responders(
    n: usize,
    rx: Receiver<Completion>,
    metrics: Arc<ServeMetrics>,
) -> Vec<std::thread::JoinHandle<()>> {
    let rx = Arc::new(Mutex::new(rx));
    (0..n.max(1))
        .map(|i| {
            let rx = Arc::clone(&rx);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new()
                .name(format!("responder-{i}"))
                .spawn(move || loop {
                    let c = match rx.lock().unwrap().recv() {
                        Ok(c) => c,
                        Err(_) => break, // scheduler gone and channel drained
                    };
                    respond(c, &metrics);
                })
                .expect("spawn responder")
        })
        .collect()
}

/// Handle one responder work unit: write a buffered completion, or flush
/// a streamed session's pending chunks. Write failures are classified
/// (`client_disconnects` vs `write_errors`) but never retried — the
/// decode already happened; there is nobody left to tell.
fn respond(c: Completion, metrics: &ServeMetrics) {
    match c {
        Completion::Done { stream, result } => respond_done(stream, result, metrics),
        Completion::Chunk { conn } => flush_stream(&conn, metrics),
    }
}

/// Write one buffered completion to its client socket and release its
/// in-flight slot.
fn respond_done(mut stream: TcpStream, result: GenResult, metrics: &ServeMetrics) {
    match result {
        Ok(resp) => {
            let body = gen_response_json(&resp);
            if let Err(e) =
                http::write_response(&mut stream, 200, "application/json", body.as_bytes())
            {
                count_write_failure(&e, false, metrics);
            }
        }
        Err(ge) => {
            // admission-control 503s are counted by their own counters
            // (shed_total / rejected_*), not as errors
            if ge.status != 503 {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
            }
            let body = json::to_string(&Value::obj(vec![(
                "error",
                Value::from(ge.message),
            )]));
            let extra: Vec<(&str, String)> = ge
                .retry_after
                .map(|s| ("Retry-After", s.to_string()))
                .into_iter()
                .collect();
            if let Err(e) = http::write_response_with_headers(
                &mut stream,
                ge.status,
                "application/json",
                &extra,
                body.as_bytes(),
            ) {
                count_write_failure(&e, false, metrics);
            }
        }
    }
    release_inflight(metrics);
}

/// Classify one failed client write. After the response body started
/// flowing (`mid_stream`), any failure means the client hung up — that is
/// their prerogative, not a server error. Before that, only io error
/// kinds that positively identify a vanished peer count as disconnects;
/// the rest (timeouts, local socket trouble) are server-side
/// `write_errors`.
fn count_write_failure(err: &anyhow::Error, mid_stream: bool, metrics: &ServeMetrics) {
    use std::io::ErrorKind::{BrokenPipe, ConnectionAborted, ConnectionReset, UnexpectedEof};
    let disconnect = mid_stream
        || err.downcast_ref::<std::io::Error>().is_some_and(|e| {
            matches!(e.kind(), BrokenPipe | ConnectionReset | ConnectionAborted | UnexpectedEof)
        });
    if disconnect {
        metrics.client_disconnects.fetch_add(1, Ordering::Relaxed);
    } else {
        metrics.write_errors.fetch_add(1, Ordering::Relaxed);
    }
}

/// Flush a streamed session: send the chunked head with (or before) the
/// first data, one chunk frame per flush, and — once the scheduler has
/// delivered the final result — either the terminator or an error. An
/// error before any bytes went out becomes the same buffered error
/// response the non-streamed path writes; after the head is out the
/// status cannot change, so a mid-stream failure cuts the stream without
/// the terminator and the client sees the truncation. Exactly-once: the
/// in-flight slot is released on the transition to `finished`, whichever
/// path gets there first.
fn flush_stream(conn: &StreamConn, metrics: &ServeMetrics) {
    // the stream lock serializes concurrent responders flushing the same
    // session; text order is preserved because text rides the shared
    // buffer, not the flush events
    let mut stream = conn.stream.lock().unwrap();
    let (data, ended, error, headers_sent) = {
        let mut st = conn.state.lock().unwrap();
        if st.finished {
            return;
        }
        (std::mem::take(&mut st.buf), st.ended, st.error.clone(), st.headers_sent)
    };
    if let (Some(ge), false) = (&error, headers_sent) {
        if ge.status != 503 {
            metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
        let body =
            json::to_string(&Value::obj(vec![("error", Value::from(ge.message.clone()))]));
        let extra: Vec<(&str, String)> = ge
            .retry_after
            .map(|s| ("Retry-After", s.to_string()))
            .into_iter()
            .collect();
        if let Err(e) = http::write_response_with_headers(
            &mut stream,
            ge.status,
            "application/json",
            &extra,
            body.as_bytes(),
        ) {
            conn.disconnected.store(true, Ordering::Relaxed);
            count_write_failure(&e, false, metrics);
        }
        finish_stream(conn, metrics);
        return;
    }
    if !headers_sent {
        if data.is_empty() && !ended {
            return; // nothing to say yet
        }
        if let Err(e) = http::write_chunked_head(&mut stream, 200, "text/plain; charset=utf-8") {
            conn.disconnected.store(true, Ordering::Relaxed);
            count_write_failure(&e, false, metrics);
            finish_stream(conn, metrics);
            return;
        }
        conn.state.lock().unwrap().headers_sent = true;
    }
    if !data.is_empty() {
        if let Err(e) = http::write_chunk(&mut stream, data.as_bytes()) {
            conn.disconnected.store(true, Ordering::Relaxed);
            count_write_failure(&e, true, metrics);
            finish_stream(conn, metrics);
            return;
        }
    }
    if ended {
        match &error {
            Some(ge) => {
                // headers are out: the status cannot change. Count the
                // server-side failure and cut the stream unterminated.
                if ge.status != 503 {
                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                }
            }
            None => {
                if let Err(e) = http::write_chunked_end(&mut stream) {
                    conn.disconnected.store(true, Ordering::Relaxed);
                    count_write_failure(&e, true, metrics);
                }
            }
        }
        finish_stream(conn, metrics);
    }
}

/// Idempotently mark a streamed session terminal and release its
/// in-flight slot exactly once.
fn finish_stream(conn: &StreamConn, metrics: &ServeMetrics) {
    let mut st = conn.state.lock().unwrap();
    if !st.finished {
        st.finished = true;
        drop(st);
        release_inflight(metrics);
    }
}

/// Release the in-flight slot reserved at admission (saturating: the
/// gauge must never wrap).
fn release_inflight(metrics: &ServeMetrics) {
    let _ = metrics
        .inflight_sessions
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
}

/// Engine-worker exit guard. Runs on every exit path — clean scheduler
/// return, engine-init failure, or a panic unwinding out of the scheduler.
///
/// With replicas the guard is a *quarantine*, not a shutdown: it marks
/// only its own replica dead in the [`ReplicaRouter`] (in-flight sessions
/// were already shed with 500s by `ActiveSet`'s own drop, which unwinds
/// first), updates the `engine_replicas_alive` gauge, and wakes blocked
/// survivors so affinity keys remap onto them. The queue stays open —
/// surviving replicas keep admitting. Only the LAST replica's guard
/// closes the admission queue, flips `/healthz` to down, and answers
/// every still-queued request with 503 so no client is left hanging on a
/// dead engine. The refused requests are counted in `errors` (they are
/// server-side failures, unlike the admission-control 503s with their own
/// counters), keeping the per-request accounting exhaustive even on the
/// panic path.
struct WorkerGuard {
    replica: usize,
    router: Arc<ReplicaRouter>,
    queue: Arc<AdmissionQueue>,
    completions: Sender<Completion>,
    up: Arc<AtomicBool>,
    metrics: Arc<ServeMetrics>,
    snapshot: Arc<Mutex<ServeSnapshot>>,
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        // the dying replica's published snapshot must not advertise its
        // in-flight sessions as active forever: the scheduler unwind is
        // 500-ing them right now, so fold them into `failed` and zero the
        // live gauges (lock via into_inner: a panic can leave it poisoned)
        {
            let mut snap = self
                .snapshot
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            let mut failed = 0u64;
            for s in &mut snap.sessions {
                if s.state == "active" {
                    s.state = "failed";
                    failed += 1;
                }
            }
            snap.failed_sessions += failed;
            snap.active_sessions = 0;
            snap.prefill_backlog = 0;
        }
        let remaining = self.router.mark_dead(self.replica);
        self.metrics
            .engine_replicas_alive
            .store(remaining as u64, Ordering::Relaxed);
        if remaining > 0 {
            // Quarantined, not dead: survivors re-evaluate routing (this
            // replica's affinity keys now map to them) and the queue
            // stays open at reduced capacity.
            self.queue.wake_all();
            return;
        }
        self.up.store(false, Ordering::Relaxed);
        self.queue.close();
        while let Popped::Req(r) = self.queue.pop(false) {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
            r.reply.deliver(
                Err(GenError {
                    status: 503,
                    message: "engine down".into(),
                    retry_after: None,
                }),
                &self.completions,
            );
        }
    }
}

// ---------------------------------------------------------------------------
// server
// ---------------------------------------------------------------------------

/// Run the server until `shutdown` flips (or forever). Engine construction
/// is deferred to the worker threads because the PJRT backend is not
/// `Send`; `make_engine` is called once per replica with the replica id
/// and must hand every replica the SAME `Arc<HostExpertStore>` for the
/// shared-host-tier guarantees to hold (a per-call store still works, but
/// each replica then budgets its RAM independently).
pub fn serve<F>(
    listener: TcpListener,
    make_engine: F,
    cfg: ServeConfig,
    shutdown: Arc<AtomicBool>,
) -> Result<()>
where
    F: Fn(usize) -> Result<crate::engine::InferenceEngine> + Send + Sync + 'static,
{
    let metrics = Arc::new(ServeMetrics::default());
    let n_replicas = cfg.engine_workers.max(1);
    let router = ReplicaRouter::new(n_replicas);
    metrics.engine_replicas_alive.store(n_replicas as u64, Ordering::Relaxed);
    // one snapshot slot per replica; /metrics merges them at render time
    // (shared-store stats read once, per-replica stats summed)
    let snapshots: Arc<Vec<Arc<Mutex<ServeSnapshot>>>> = Arc::new(
        (0..n_replicas)
            .map(|_| Arc::new(Mutex::new(ServeSnapshot::default())))
            .collect(),
    );
    let queue = AdmissionQueue::new(cfg.queue_depth, Arc::clone(&metrics));
    let (completion_tx, completion_rx) = channel::<Completion>();
    // liveness for /healthz: flips false when the LAST engine worker exits
    // (init failure or retirement) so orchestrators stop routing traffic
    // to a server that can only answer 503
    let engine_up = Arc::new(AtomicBool::new(true));

    // engine workers: each owns one replica (engine + scheduler loop),
    // pulls routed work from the shared admission queue, posts
    // completions; their senders are the ONLY completion senders once
    // serve() drops its own below, so responders exit exactly when the
    // last worker does (after every completion drained). A WorkerGuard
    // runs on EVERY worker exit — clean return, init failure, or panic
    // inside the scheduler — quarantining that replica, and closing the
    // queue only at the last death so clients can never be left hanging
    // on a dead engine.
    let sched_cfg = SchedulerConfig {
        max_sessions: cfg.max_sessions,
        queue_timeout: (cfg.queue_timeout_ms > 0)
            .then(|| Duration::from_millis(cfg.queue_timeout_ms)),
        prefill_chunk: cfg.prefill_chunk,
        round_budget_tokens: cfg.round_budget_tokens,
        round_batching: cfg.round_batching,
        retry_after: cfg.retry_after,
    };
    let make_engine = Arc::new(make_engine);
    let mut engine_workers = Vec::with_capacity(n_replicas);
    for r in 0..n_replicas {
        let make_engine = Arc::clone(&make_engine);
        let worker_metrics = Arc::clone(&metrics);
        let worker_snapshot = Arc::clone(&snapshots[r]);
        let worker_queue = Arc::clone(&queue);
        let worker_router = Arc::clone(&router);
        let worker_completions = completion_tx.clone();
        let guard = WorkerGuard {
            replica: r,
            router: Arc::clone(&router),
            queue: Arc::clone(&queue),
            completions: completion_tx.clone(),
            up: Arc::clone(&engine_up),
            metrics: Arc::clone(&metrics),
            snapshot: Arc::clone(&snapshots[r]),
        };
        engine_workers.push(
            std::thread::Builder::new()
                .name(format!("engine-worker-{r}"))
                .spawn(move || {
                    let _guard = guard;
                    let engine = match make_engine(r) {
                        Ok(e) => e,
                        Err(e) => {
                            eprintln!("engine replica {r} init failed: {e:#}");
                            return; // guard quarantines this replica
                        }
                    };
                    let _ = run_replica(
                        crate::engine::EngineReplica::new(r, engine),
                        worker_queue,
                        worker_completions,
                        sched_cfg,
                        worker_metrics,
                        worker_snapshot,
                        worker_router,
                    );
                })?,
        );
    }
    // the workers' senders (threads + guards) are now the only ones
    drop(completion_tx);

    let responders = spawn_responders(cfg.responders, completion_rx, Arc::clone(&metrics));

    // /metrics and /healthz answer on their own threads, not the pool
    let (ctl_tx, ctl_rx) = channel::<ControlConn>();
    let control_plane = spawn_control_plane(
        ctl_rx,
        Arc::clone(&metrics),
        Arc::clone(&snapshots),
        Arc::clone(&router),
        Arc::clone(&engine_up),
    );

    // workers never hold a connection across a decode, so the pool is
    // sized for parse throughput only
    let pool = Arc::new(ThreadPool::new(cfg.http_workers.max(1)));
    let dispatcher = Dispatcher {
        pool: Arc::clone(&pool),
        metrics: Arc::clone(&metrics),
        queue: Arc::clone(&queue),
        ctl_tx: ctl_tx.clone(),
        max_inflight: cfg.max_inflight_sessions.max(1),
        retry_after: cfg.retry_after,
    };
    let (sniff_tx, sniff_rx) = channel::<TcpStream>();
    let sniffer = spawn_sniffer(sniff_rx, dispatcher.clone());
    listener.set_nonblocking(true)?;
    println!(
        "serving on {} (max {} concurrent sessions, queue depth {}, inflight cap {})",
        listener.local_addr()?,
        cfg.max_sessions,
        cfg.queue_depth,
        cfg.max_inflight_sessions
    );
    loop {
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                // accepted sockets do NOT inherit the listener's
                // non-blocking mode on all platforms: set it explicitly so
                // the sniff peek can never block the accept loop — and if
                // that fails, skip the sniff rather than risk a blocking
                // peek hanging every future accept
                match stream.set_nonblocking(true) {
                    Ok(()) => match sniff_once(&stream) {
                        Sniff::Control(path) => dispatcher.dispatch(stream, Some(path)),
                        Sniff::Ordinary => dispatcher.dispatch(stream, None),
                        // first bytes not here yet: park with the
                        // sniffer, never sleep in the accept loop
                        Sniff::Undecided => {
                            let _ = sniff_tx.send(stream);
                        }
                    },
                    Err(_) => dispatcher.dispatch(stream, None),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => {
                eprintln!("accept error: {e}");
                break;
            }
        }
    }
    drop(sniff_tx); // sniffer finishes its parked connections and exits
    let _ = sniffer.join();
    drop(dispatcher); // releases its pool handle and control sender
    drop(pool); // last pool ref: joins HTTP workers, no more pushes
    queue.close(); // schedulers drain the remaining queue and exit
    for w in engine_workers {
        let _ = w.join(); // drops the completion senders
    }
    for r in responders {
        let _ = r.join(); // responders drained every completion
    }
    drop(ctl_tx); // last control sender gone; control threads exit
    for c in control_plane {
        let _ = c.join();
    }
    Ok(())
}

fn handle_conn(
    mut stream: TcpStream,
    metrics: &ServeMetrics,
    ctl_tx: &Sender<ControlConn>,
    queue: &AdmissionQueue,
    max_inflight: usize,
    retry_after: u64,
) {
    let _ = stream.set_read_timeout(Some(CLIENT_IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(CLIENT_IO_TIMEOUT));
    let req = match http::read_request(&mut stream) {
        Ok(r) => r,
        Err(_) => {
            let _ = http::write_response(&mut stream, 400, "text/plain", b"bad request");
            return;
        }
    };
    metrics.requests.fetch_add(1, Ordering::Relaxed);
    // the path may carry a query string (`/generate?stream=1&priority=batch`);
    // route on the bare path, hand the query to the generate handler
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p, q),
        None => (req.path.as_str(), ""),
    };
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => route_control(stream, ControlPath::Healthz, ctl_tx),
        ("GET", "/metrics") => route_control(stream, ControlPath::Metrics, ctl_tx),
        ("POST", "/generate") => match parse_gen_request(&req.body) {
            Ok((prompt, n, sampling)) => {
                let stream_mode = query
                    .split('&')
                    .any(|kv| matches!(kv, "stream=1" | "stream=true"));
                // query param wins over the x-priority header; absent both,
                // requests are interactive (the latency-sensitive default)
                let priority = query
                    .split('&')
                    .find_map(|kv| kv.strip_prefix("priority=").and_then(Priority::parse))
                    .or_else(|| {
                        req.headers.get("x-priority").and_then(|v| Priority::parse(v))
                    })
                    .unwrap_or_default();
                // session affinity (`?affinity=` / `x-session-affinity`):
                // same key → same engine replica while that replica lives,
                // keeping a client's follow-up turns on the replica whose
                // device cache its experts already warmed
                let affinity = query
                    .split('&')
                    .find_map(|kv| kv.strip_prefix("affinity="))
                    .map(str::to_string)
                    .or_else(|| req.headers.get("x-session-affinity").cloned())
                    .map(|v| affinity_key(&v));
                admit_generate(
                    stream, prompt, n, sampling, stream_mode, priority, affinity, metrics,
                    queue, max_inflight, retry_after,
                );
            }
            Err(msg) => {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                let body =
                    json::to_string(&Value::obj(vec![("error", Value::from(msg))]));
                let _ = http::write_response(&mut stream, 400, "application/json", body.as_bytes());
            }
        },
        _ => {
            let _ = http::write_response(&mut stream, 404, "text/plain", b"not found");
        }
    }
}

/// Hand an already-parsed control request to the dedicated control-plane
/// thread. The thread outlives the worker pool by construction; if its
/// channel is somehow gone, fail the request loudly rather than hanging
/// the client.
fn route_control(stream: TcpStream, path: ControlPath, ctl_tx: &Sender<ControlConn>) {
    if let Err(std::sync::mpsc::SendError(conn)) =
        ctl_tx.send(ControlConn { stream, path, raw: false })
    {
        let mut stream = conn.stream;
        let _ = http::write_response(&mut stream, 503, "text/plain", b"control plane down");
    }
}

/// Map a client affinity value to a routing key: all-digit values parse
/// verbatim (so `affinity=1` pins deterministically to alive replica
/// `1 mod alive_count` — tests and benches rely on this), anything else
/// is FNV-1a–hashed.
fn affinity_key(v: &str) -> u64 {
    if !v.is_empty() && v.bytes().all(|b| b.is_ascii_digit()) {
        if let Ok(k) = v.parse::<u64>() {
            return k;
        }
    }
    // FNV-1a, 64-bit
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in v.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Admission-check a parsed `/generate` and either enqueue it (handing the
/// socket to the scheduler → responder path) or answer 503 right here.
/// Either way the HTTP worker returns immediately — it never waits on a
/// decode.
#[allow(clippy::too_many_arguments)]
fn admit_generate(
    mut stream: TcpStream,
    prompt: String,
    n_tokens: usize,
    sampling: Sampling,
    stream_mode: bool,
    priority: Priority,
    affinity: Option<u64>,
    metrics: &ServeMetrics,
    queue: &AdmissionQueue,
    max_inflight: usize,
    retry_after: u64,
) {
    // reserve an in-flight slot first (released by the responder after the
    // response is written): the cap bounds queued + decoding +
    // completion-pending work, exactly
    let reserved = metrics
        .inflight_sessions
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            (v < max_inflight as u64).then_some(v + 1)
        })
        .is_ok();
    if !reserved {
        // rejection happens before any streaming starts, so streamed and
        // buffered requests get the same plain 503
        metrics.rejected_inflight.fetch_add(1, Ordering::Relaxed);
        let _ = http::write_response_with_headers(
            &mut stream,
            503,
            "text/plain",
            &retry_headers(Some(retry_after)),
            b"in-flight session cap reached; retry later",
        );
        return;
    }
    let reply = if stream_mode {
        ReplyTo::Stream(StreamConn::new(stream))
    } else {
        ReplyTo::Socket(stream)
    };
    let req = GenRequest {
        prompt,
        n_tokens,
        sampling,
        priority,
        affinity,
        reply,
        enqueued: Instant::now(),
    };
    match queue.try_push(req) {
        Ok(()) => {} // worker freed; a responder writes the reply
        Err(PushRejected::Full(req)) => {
            release_inflight(metrics);
            metrics.rejected_backpressure.fetch_add(1, Ordering::Relaxed);
            reject_reply(
                req.reply,
                503,
                Some(retry_after),
                b"queue full (backpressure); retry later",
            );
        }
        Err(PushRejected::Closed(req)) => {
            release_inflight(metrics);
            metrics.errors.fetch_add(1, Ordering::Relaxed);
            // no Retry-After: a closed queue means THIS process's engine is
            // gone for good (healthz flips red), not transient pressure
            reject_reply(req.reply, 503, None, b"engine down");
        }
    }
}

/// Build the `Retry-After` header set for an admission-control rejection —
/// always from the configured `ServeConfig.retry_after`, never a constant
/// baked at a call site, so every 503 advertises the same back-off.
fn retry_headers(retry_after: Option<u64>) -> Vec<(&'static str, String)> {
    retry_after
        .map(|s| ("Retry-After", s.to_string()))
        .into_iter()
        .collect()
}

/// Write an admission-time rejection straight to whichever reply shape the
/// request carried — the ONE exit for every refusal, so the advertised
/// `Retry-After` cannot diverge between socket, stream, and channel
/// clients. No chunked framing was started for streamed requests, so a
/// plain error response is still well-formed on their socket.
fn reject_reply(reply: ReplyTo, status: u16, retry_after: Option<u64>, body: &[u8]) {
    let extra = retry_headers(retry_after);
    match reply {
        ReplyTo::Socket(mut stream) => {
            let _ = http::write_response_with_headers(
                &mut stream, status, "text/plain", &extra, body,
            );
        }
        ReplyTo::Stream(conn) => {
            let mut stream = conn.stream.lock().unwrap();
            let _ = http::write_response_with_headers(
                &mut stream, status, "text/plain", &extra, body,
            );
            conn.state.lock().unwrap().finished = true;
        }
        ReplyTo::Channel(tx) => {
            let _ = tx.send(Err(GenError {
                status,
                message: String::from_utf8_lossy(body).into_owned(),
                retry_after,
            }));
        }
    }
}

/// `moe-offload serve` entrypoint.
///
/// `--synthetic` serves seeded synthetic weights over the native backend so
/// the whole serve stack runs from a clean checkout (no artifacts, no
/// PJRT); without it, artifacts are loaded as in production.
pub fn cmd_serve(args: &Args) -> Result<()> {
    use crate::offload::store::{HostExpertStore, HostTierConfig};
    use crate::runtime::artifacts::Artifacts;

    let port = args.usize_or("port", 7080)?;
    let dir = args.str_or("artifacts", "artifacts");
    let backend_kind = args.str_or("backend", "pjrt");
    let policy = crate::cache::PolicyKind::parse(&args.str_or("policy", "lfu"))
        .ok_or_else(|| anyhow::anyhow!("bad --policy"))?;
    let capacity = args.usize_or("capacity", 4)?;
    let quant = crate::quant::Scheme::parse(&args.str_or("quant", "int4"))
        .ok_or_else(|| anyhow::anyhow!("bad --quant"))?;
    let spec = args.bool("spec");
    let prefetch_source =
        crate::offload::prefetch::PrefetchSource::parse(&args.str_or("prefetch-source", "gate"))
            .ok_or_else(|| anyhow::anyhow!("bad --prefetch-source (gate|markov|learned)"))?;
    let predictor_weights = args.get("predictor-weights").map(|s| s.to_string());
    let transfer_workers = crate::engine::EngineConfig::transfer_workers_from(args)?;
    let synthetic = args.bool("synthetic");
    let seed = args.usize_or("seed", 0)? as u64;
    let profile = crate::sim::hardware::by_name(&args.str_or("profile", "A100"))
        .ok_or_else(|| anyhow::anyhow!("bad --profile"))?;
    let fetch_retries = args.usize_or("fetch-retries", 2)?;
    let demand_deadline_ms = args.usize_or("demand-deadline-ms", 0)? as u64;
    // tiered expert store: 0 (the default) keeps every quantized expert in
    // RAM; > 0 bounds RAM to this many MB with the remainder spilled to
    // disk and promoted on demand (DESIGN.md §10)
    let host_cache_mb = args.usize_or("host-cache-mb", 0)?;
    let disk_read_mbps = args.usize_or("disk-read-mbps", 0)?;
    let defaults = ServeConfig::default();
    let serve_cfg = ServeConfig {
        http_workers: args.usize_or("http-workers", defaults.http_workers)?,
        max_sessions: args.usize_or("max-sessions", defaults.max_sessions)?,
        queue_depth: args.usize_or("queue-depth", defaults.queue_depth)?,
        responders: args.usize_or("responders", defaults.responders)?,
        queue_timeout_ms: args.usize_or("queue-timeout-ms", defaults.queue_timeout_ms as usize)?
            as u64,
        max_inflight_sessions: args
            .usize_or("max-inflight-sessions", defaults.max_inflight_sessions)?,
        prefill_chunk: args.usize_or("prefill-chunk", defaults.prefill_chunk)?,
        round_budget_tokens: args
            .usize_or("round-budget-tokens", defaults.round_budget_tokens)?,
        // value-style flag (not a bare bool): on by default, disabled with
        // `--round-batching off` (or false/0/no) for the legacy path
        round_batching: !matches!(
            args.str_or("round-batching", "on").as_str(),
            "off" | "false" | "0" | "no"
        ),
        retry_after: args.usize_or("retry-after-s", defaults.retry_after as usize)? as u64,
        engine_workers: args.usize_or("engine-workers", defaults.engine_workers)?,
    };

    // weights and the host expert store are built ONCE, outside the
    // per-replica closure: every replica decodes the same weights and —
    // critically — shares ONE `HostExpertStore`, so the RAM budget and
    // disk tier are process-global however many replicas run (per-replica
    // device caches over a shared host tier; DESIGN.md §12). Backends are
    // still built per replica, on the replica's own thread, because the
    // PJRT backend is not `Send`.
    let (weights, artifacts) = if synthetic {
        let w = Arc::new(crate::model::weights::generate_weights(
            crate::model::ModelConfig::DEFAULT,
            seed,
        ));
        (w, None)
    } else {
        let a = Artifacts::load(std::path::Path::new(&dir))?;
        let w = Arc::new(crate::model::Weights::load(&a.weights_path)?);
        (w, Some(a))
    };
    let store = if host_cache_mb > 0 {
        let tier = HostTierConfig {
            ram_budget_bytes: host_cache_mb << 20,
            policy,
            seed,
            spill_dir: artifacts.as_ref().map(|a| a.expert_spill_dir()),
        };
        Arc::new(HostExpertStore::build_tiered(&weights, quant, &tier)?)
    } else {
        Arc::new(HostExpertStore::build(&weights, quant)?)
    };

    let listener = TcpListener::bind(("0.0.0.0", port as u16))?;
    let shutdown = Arc::new(AtomicBool::new(false));
    serve(
        listener,
        move |_replica| {
            let backend: Box<dyn crate::runtime::Backend> = match &artifacts {
                Some(a) if backend_kind != "native" => {
                    Box::new(crate::runtime::pjrt::PjrtBackend::new(a, &weights)?)
                }
                _ => Box::new(crate::runtime::native::NativeBackend::new(Arc::clone(&weights))),
            };
            let mut cfg = crate::engine::EngineConfig::serving(capacity, policy, spec);
            cfg.transfer_workers = transfer_workers;
            cfg.profile = profile;
            cfg.seed = seed;
            cfg.fetch_retries = fetch_retries;
            cfg.demand_deadline_ms = demand_deadline_ms;
            cfg.prefetch_source = prefetch_source;
            if disk_read_mbps > 0 {
                cfg.disk = crate::sim::hardware::DiskProfile::from_mbps(disk_read_mbps as f64);
            }
            let mc = *backend.config();
            let wanted = policy == crate::cache::PolicyKind::Learned
                || prefetch_source == crate::offload::prefetch::PrefetchSource::Learned;
            let predictor = crate::offload::learned::load_optional(
                predictor_weights.as_deref(),
                wanted,
                mc.n_layers,
                mc.n_experts,
            )?;
            Ok(crate::engine::InferenceEngine::with_predictor(
                backend,
                Arc::clone(&store),
                cfg,
                predictor,
            ))
        },
        serve_cfg,
        shutdown,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{
        CacheStats, HostTierStats, PipelineStats, PrecisionRecall, RoundBatchStats, SessionTally,
    };
    use super::scheduler::SessionView;

    #[test]
    fn parse_gen_request_ok() {
        let (p, n, s) =
            parse_gen_request(br#"{"prompt":"hi","n_tokens":8,"greedy":true}"#).unwrap();
        assert_eq!(p, "hi");
        assert_eq!(n, 8);
        assert_eq!(s, Sampling::Greedy);
    }

    #[test]
    fn parse_gen_request_defaults() {
        let (_, n, s) = parse_gen_request(br#"{"prompt":"x"}"#).unwrap();
        assert_eq!(n, 32);
        assert!(matches!(s, Sampling::TopP { .. }));
    }

    #[test]
    fn parse_gen_request_rejects() {
        assert!(parse_gen_request(b"{}").is_err());
        assert!(parse_gen_request(b"not json").is_err());
        assert!(parse_gen_request(br#"{"prompt":"x","n_tokens":0}"#).is_err());
    }

    #[test]
    fn response_json_shape() {
        let r = GenResponse {
            text: "abc".into(),
            n_prompt: 4,
            n_generated: 3,
            wall_s: 0.5,
            sim_tokens_per_s: 12.25,
            cache_hit_rate: 0.75,
            session_id: 9,
            session_hits: 30,
            session_misses: 10,
            spec_precision: 0.5,
            spec_recall: 0.5,
        };
        let v = json::parse(&gen_response_json(&r)).unwrap();
        assert_eq!(v.get("text").as_str(), Some("abc"));
        assert_eq!(v.get("n_generated").as_usize(), Some(3));
        assert_eq!(v.get("cache_hit_rate").as_f64(), Some(0.75));
        assert_eq!(v.get("session_id").as_usize(), Some(9));
        assert_eq!(v.get("session_hits").as_usize(), Some(30));
        assert_eq!(v.get("spec_precision").as_f64(), Some(0.5));
    }

    fn request_with_reply(n_tokens: usize) -> (GenRequest, Receiver<GenResult>) {
        let (tx, rx) = channel();
        (
            GenRequest {
                prompt: "q".into(),
                n_tokens,
                sampling: Sampling::Greedy,
                priority: Priority::Interactive,
                affinity: None,
                reply: ReplyTo::Channel(tx),
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn admission_queue_bounds_and_gauges() {
        let metrics = Arc::new(ServeMetrics::default());
        let q = AdmissionQueue::new(2, Arc::clone(&metrics));
        assert!(q.try_push(request_with_reply(1).0).is_ok());
        assert!(q.try_push(request_with_reply(2).0).is_ok());
        assert_eq!(metrics.queue_depth.load(Ordering::Relaxed), 2);
        match q.try_push(request_with_reply(3).0) {
            Err(PushRejected::Full(r)) => assert_eq!(r.n_tokens, 3),
            _ => panic!("expected Full"),
        }
        // FIFO pop, gauge tracks exactly
        match q.pop(false) {
            Popped::Req(r) => assert_eq!(r.n_tokens, 1),
            _ => panic!("expected request"),
        }
        assert_eq!(metrics.queue_depth.load(Ordering::Relaxed), 1);
        q.close();
        match q.try_push(request_with_reply(4).0) {
            Err(PushRejected::Closed(_)) => {}
            _ => panic!("expected Closed"),
        }
        // closed queues still drain
        assert!(matches!(q.pop(false), Popped::Req(_)));
        assert!(matches!(q.pop(false), Popped::Closed));
        assert!(matches!(q.pop(true), Popped::Closed));
        assert_eq!(metrics.queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn admission_queue_sheds_aged_only() {
        let metrics = Arc::new(ServeMetrics::default());
        let q = AdmissionQueue::new(8, Arc::clone(&metrics));
        let (mut old, _rx_old) = request_with_reply(7);
        if let Some(t) = Instant::now().checked_sub(Duration::from_secs(60)) {
            old.enqueued = t;
        } else {
            return; // machine uptime < backdate window; nothing to test
        }
        let (fresh, _rx_fresh) = request_with_reply(8);
        q.try_push(old).ok().unwrap();
        q.try_push(fresh).ok().unwrap();
        let aged = q.take_aged(Duration::from_secs(1));
        assert_eq!(aged.len(), 1);
        assert_eq!(aged[0].n_tokens, 7);
        assert_eq!(q.len(), 1);
        assert_eq!(metrics.queue_depth.load(Ordering::Relaxed), 1);
        assert!(q.take_aged(Duration::from_secs(1)).is_empty());
    }

    #[test]
    fn sniff_once_routes_by_first_bytes() {
        use std::io::Write as _;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();

        let sniff_until_decided = |s: &TcpStream| {
            for _ in 0..1000 {
                match sniff_once(s) {
                    Sniff::Undecided => std::thread::sleep(Duration::from_millis(1)),
                    decided => return decided,
                }
            }
            panic!("sniff never decided");
        };

        // a control probe: undecided before any bytes, then recognized
        let mut c1 = TcpStream::connect(addr).unwrap();
        let (s1, _) = listener.accept().unwrap();
        s1.set_nonblocking(true).unwrap();
        assert!(matches!(sniff_once(&s1), Sniff::Undecided), "no bytes yet");
        c1.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert!(matches!(
            sniff_until_decided(&s1),
            Sniff::Control(ControlPath::Healthz)
        ));

        // an ordinary request decides on its first bytes
        let mut c2 = TcpStream::connect(addr).unwrap();
        let (s2, _) = listener.accept().unwrap();
        s2.set_nonblocking(true).unwrap();
        c2.write_all(b"POST /generate HTTP/1.1\r\n").unwrap();
        assert!(matches!(sniff_until_decided(&s2), Sniff::Ordinary));

        // an ambiguous prefix stays undecided until enough bytes arrive
        let mut c3 = TcpStream::connect(addr).unwrap();
        let (s3, _) = listener.accept().unwrap();
        s3.set_nonblocking(true).unwrap();
        c3.write_all(b"GET /metri").unwrap();
        std::thread::sleep(Duration::from_millis(20));
        assert!(matches!(sniff_once(&s3), Sniff::Undecided));
        c3.write_all(b"cs HTTP/1.1\r\n\r\n").unwrap();
        assert!(matches!(
            sniff_until_decided(&s3),
            Sniff::Control(ControlPath::Metrics)
        ));
    }

    #[test]
    fn admission_queue_blocking_pop_wakes_on_push() {
        let metrics = Arc::new(ServeMetrics::default());
        let q = AdmissionQueue::new(2, metrics);
        let q2 = Arc::clone(&q);
        let popper = std::thread::spawn(move || match q2.pop(true) {
            Popped::Req(r) => r.n_tokens,
            _ => 0,
        });
        std::thread::sleep(Duration::from_millis(20));
        q.try_push(request_with_reply(5).0).ok().unwrap();
        assert_eq!(popper.join().unwrap(), 5);
    }

    #[test]
    fn metrics_json_reports_single_shared_cache_with_sessions() {
        let metrics = ServeMetrics::default();
        metrics.requests.store(7, Ordering::Relaxed);
        metrics.rejected_backpressure.store(2, Ordering::Relaxed);
        metrics.rejected_inflight.store(1, Ordering::Relaxed);
        metrics.shed_total.store(4, Ordering::Relaxed);
        metrics.inflight_sessions.store(3, Ordering::Relaxed);
        metrics.queue_wait.record_ns(1_000);
        metrics.tokens_prefill.store(11, Ordering::Relaxed);
        metrics.ttft.record_ns(2_000);
        let mut snap = ServeSnapshot {
            policy: "lfu".into(),
            capacity_per_layer: 4,
            n_layers: 12,
            active_sessions: 2,
            completed_sessions: 5,
            failed_sessions: 1,
            prefill_backlog: 6,
            cache: CacheStats { hits: 90, misses: 10, ..Default::default() },
            spec: PrecisionRecall { tp: 8, fp: 2, fn_: 2 },
            cross_session_prefetch_hits: 3,
            predictor_active: true,
            predictor: PrecisionRecall { tp: 6, fp: 2, fn_: 4 },
            predictor_skipped_records: 7,
            prefetch_hits_by_source: [5, 4, 3],
            pipeline: PipelineStats {
                workers: 2,
                demand_joined_prefetch: 4,
                cancelled_prefetches: 1,
                pool_allocs: 10,
                pool_reuses: 90,
                ..Default::default()
            },
            round_batching: RoundBatchStats {
                rounds: 6,
                distinct_experts: 20,
                dedup_joins: 10,
                batched_rows: 30,
            },
            degraded_tokens: 2,
            fetch_retries: 3,
            host_tier: HostTierStats {
                ram_hits: 30,
                disk_promotions: 10,
                ram_evictions: 6,
                disk_read_ns: 5_000,
                disk_read_p99_ns: 900,
                host_accesses: 40,
            },
            sessions: Vec::new(),
        };
        for id in 1..=2u64 {
            snap.sessions.push(SessionView {
                id,
                state: "active",
                n_prompt: 5,
                generated: 3,
                target: 8,
                tally: SessionTally { tokens: 8, hits: 45, misses: 5, ..Default::default() },
            });
        }
        let v = metrics_json(&metrics, &snap);
        assert_eq!(v.get("requests").as_usize(), Some(7));
        assert_eq!(v.get("failed_sessions").as_usize(), Some(1));
        // admission-control counters: rejected_total = backpressure + cap
        assert_eq!(v.get("rejected_total").as_usize(), Some(3));
        assert_eq!(v.get("rejected_backpressure").as_usize(), Some(2));
        assert_eq!(v.get("rejected_inflight").as_usize(), Some(1));
        assert_eq!(v.get("shed_total").as_usize(), Some(4));
        assert_eq!(v.get("inflight_sessions").as_usize(), Some(3));
        let qw = v.get("queue_wait_ns");
        assert_eq!(qw.get("count").as_usize(), Some(1));
        assert!(qw.get("p50").as_f64().unwrap() >= 1_000.0);
        assert!(qw.get("p99").as_f64().unwrap() >= qw.get("p50").as_f64().unwrap());
        // chunked-prefill observability: token split, backlog gauge, TTFT
        assert_eq!(v.get("tokens_prefill").as_usize(), Some(11));
        assert_eq!(v.get("prefill_backlog").as_usize(), Some(6));
        let ttft = v.get("ttft_ns");
        assert_eq!(ttft.get("count").as_usize(), Some(1));
        assert!(ttft.get("p50").as_f64().unwrap() >= 2_000.0);
        assert!(ttft.get("p99").as_f64().unwrap() >= ttft.get("p50").as_f64().unwrap());
        let cache = v.get("shared_cache");
        assert_eq!(cache.get("policy").as_str(), Some("lfu"));
        assert_eq!(cache.get("hits").as_usize(), Some(90));
        assert_eq!(cache.get("cross_session_prefetch_hits").as_usize(), Some(3));
        let pipe = v.get("transfer_pipeline");
        assert_eq!(pipe.get("workers").as_usize(), Some(2));
        assert_eq!(pipe.get("demand_joined_prefetch").as_usize(), Some(4));
        assert_eq!(pipe.get("cancelled_prefetches").as_usize(), Some(1));
        assert_eq!(pipe.get("pool_reuse_rate").as_f64(), Some(0.9));
        // round-level expert-batching counters, with the derived join rate
        let rb = v.get("round_batching");
        assert_eq!(rb.get("rounds").as_usize(), Some(6));
        assert_eq!(rb.get("distinct_experts").as_usize(), Some(20));
        assert_eq!(rb.get("dedup_joins").as_usize(), Some(10));
        assert_eq!(rb.get("batched_rows").as_usize(), Some(30));
        assert!((rb.get("join_rate").as_f64().unwrap() - 10.0 / 30.0).abs() < 1e-12);
        // predictor observability: settled guess quality + per-source hits
        let pred = v.get("predictor");
        assert_eq!(pred.get("active").as_bool(), Some(true));
        assert_eq!(pred.get("precision").as_f64(), Some(0.75));
        assert_eq!(pred.get("skipped_records").as_usize(), Some(7));
        let by = pred.get("prefetch_hits_by_source");
        assert_eq!(by.get("gate").as_usize(), Some(5));
        assert_eq!(by.get("markov").as_usize(), Some(4));
        assert_eq!(by.get("learned").as_usize(), Some(3));
        // degrade/robustness counters surface at the top level
        assert_eq!(v.get("degraded_tokens").as_usize(), Some(2));
        assert_eq!(v.get("fetch_retries").as_usize(), Some(3));
        // tiered-store counters render under one host_tier object
        let ht = v.get("host_tier");
        assert_eq!(ht.get("host_accesses").as_usize(), Some(40));
        assert_eq!(ht.get("ram_hits").as_usize(), Some(30));
        assert_eq!(ht.get("ram_hit_rate").as_f64(), Some(0.75));
        assert_eq!(ht.get("disk_promotions").as_usize(), Some(10));
        assert_eq!(ht.get("ram_evictions").as_usize(), Some(6));
        assert_eq!(ht.get("disk_read_ns").as_usize(), Some(5_000));
        assert_eq!(ht.get("disk_read_p99_ns").as_usize(), Some(900));
        assert_eq!(v.get("client_disconnects").as_usize(), Some(0));
        assert_eq!(v.get("write_errors").as_usize(), Some(0));
        assert_eq!(v.get("cancelled_sessions").as_usize(), Some(0));
        let ti = v.get("ttft_interactive_ns");
        assert_eq!(ti.get("count").as_usize(), Some(0));
        let sessions = v.get("sessions").as_arr().unwrap();
        assert_eq!(sessions.len(), 2);
        assert_eq!(sessions[0].get("hits").as_usize(), Some(45));
        // per-session traffic partitions the single shared cache's totals
        let part: usize = sessions
            .iter()
            .map(|s| s.get("hits").as_usize().unwrap() + s.get("misses").as_usize().unwrap())
            .sum();
        assert_eq!(
            part,
            cache.get("hits").as_usize().unwrap() + cache.get("misses").as_usize().unwrap()
        );
    }

    #[test]
    fn priority_parse_accepts_both_classes_case_insensitively() {
        assert_eq!(Priority::parse("interactive"), Some(Priority::Interactive));
        assert_eq!(Priority::parse(" Batch "), Some(Priority::Batch));
        assert_eq!(Priority::parse("BATCH"), Some(Priority::Batch));
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::default(), Priority::Interactive);
        assert_eq!(Priority::Batch.as_str(), "batch");
    }

    #[test]
    fn admission_queue_pops_interactive_before_older_batch() {
        let metrics = Arc::new(ServeMetrics::default());
        let q = AdmissionQueue::new(4, Arc::clone(&metrics));
        // the test only inspects pop order, never delivers, so dropping the
        // reply receivers here is fine
        let mk = |n: usize, pri: Priority| {
            let (mut r, _rx) = request_with_reply(n);
            r.priority = pri;
            r
        };
        assert!(q.try_push(mk(1, Priority::Batch)).is_ok());
        assert!(q.try_push(mk(2, Priority::Batch)).is_ok());
        assert!(q.try_push(mk(3, Priority::Interactive)).is_ok());
        assert!(q.try_push(mk(4, Priority::Interactive)).is_ok());
        // interactive requests jump the batch backlog, FIFO within class
        match q.pop(false) {
            Popped::Req(r) => assert_eq!(r.n_tokens, 3),
            _ => panic!("expected request"),
        }
        match q.pop(false) {
            Popped::Req(r) => assert_eq!(r.n_tokens, 4),
            _ => panic!("expected request"),
        }
        // batch drains FIFO once no interactive work is waiting
        match q.pop(false) {
            Popped::Req(r) => assert_eq!(r.n_tokens, 1),
            _ => panic!("expected request"),
        }
        match q.pop(false) {
            Popped::Req(r) => assert_eq!(r.n_tokens, 2),
            _ => panic!("expected request"),
        }
        q.close();
    }

    #[test]
    fn replica_router_routes_by_load_and_affinity() {
        let router = ReplicaRouter::new(2);
        assert_eq!(router.n(), 2);
        assert_eq!(router.alive_count(), 2);
        // both idle: both at minimum load, either may claim unpinned work
        assert!(router.routes_to(0, None));
        assert!(router.routes_to(1, None));
        // load imbalance: only the least-loaded replica claims
        router.set_active(0, 3);
        router.set_active(1, 1);
        assert!(!router.routes_to(0, None));
        assert!(router.routes_to(1, None));
        // affinity pins regardless of load: key k → alive slot k mod 2
        assert!(router.routes_to(0, Some(0)));
        assert!(!router.routes_to(1, Some(0)));
        assert!(router.routes_to(1, Some(1)));
        assert!(router.routes_to(0, Some(2)));
        // death quarantines the replica and remaps its keys to survivors
        assert_eq!(router.mark_dead(0), 1);
        assert!(!router.routes_to(0, None));
        assert!(!router.routes_to(0, Some(0)));
        assert!(router.routes_to(1, Some(0)));
        assert_eq!(router.affinity_target(17), Some(1));
        assert_eq!(router.mark_dead(1), 0);
        assert_eq!(router.affinity_target(0), None);
    }

    #[test]
    fn pop_routed_claims_only_eligible_and_sheds_atomically() {
        let metrics = Arc::new(ServeMetrics::default());
        let q = AdmissionQueue::new(8, Arc::clone(&metrics));
        let router = ReplicaRouter::new(2);
        let mk = |n: usize, aff: Option<u64>| {
            let (mut r, _rx) = request_with_reply(n);
            r.affinity = aff;
            r
        };
        assert!(q.try_push(mk(1, Some(0))).is_ok()); // pinned to replica 0
        assert!(q.try_push(mk(2, Some(1))).is_ok()); // pinned to replica 1
        assert!(q.try_push(mk(3, None)).is_ok());
        // replica 1 skips replica 0's pinned request and claims its own
        match q.pop_routed(1, &router, false, None) {
            (Popped::Req(r), aged) => {
                assert_eq!(r.n_tokens, 2);
                assert!(aged.is_empty());
            }
            _ => panic!("expected request"),
        }
        // replica 0 drains FIFO among its eligible requests
        match q.pop_routed(0, &router, false, None) {
            (Popped::Req(r), _) => assert_eq!(r.n_tokens, 1),
            _ => panic!("expected request"),
        }
        match q.pop_routed(0, &router, false, None) {
            (Popped::Req(r), _) => assert_eq!(r.n_tokens, 3),
            _ => panic!("expected request"),
        }
        // claim-then-shed under ONE lock acquisition: the same call sheds
        // the aged request and claims the fresh one
        let (mut old, _rx_old) = request_with_reply(7);
        if let Some(t) = Instant::now().checked_sub(Duration::from_secs(60)) {
            old.enqueued = t;
        } else {
            return; // machine uptime < backdate window; nothing to test
        }
        q.try_push(old).ok().unwrap();
        q.try_push(mk(8, None)).ok().unwrap();
        match q.pop_routed(0, &router, false, Some(Duration::from_secs(1))) {
            (Popped::Req(r), aged) => {
                assert_eq!(r.n_tokens, 8);
                assert_eq!(aged.len(), 1);
                assert_eq!(aged[0].n_tokens, 7);
            }
            _ => panic!("expected request"),
        }
        assert_eq!(metrics.queue_depth.load(Ordering::Relaxed), 0);
        // a dead replica claims nothing even with unpinned work queued
        q.try_push(mk(9, None)).ok().unwrap();
        router.mark_dead(0);
        assert!(matches!(q.pop_routed(0, &router, false, None), (Popped::Empty, _)));
        match q.pop_routed(1, &router, false, None) {
            (Popped::Req(r), _) => assert_eq!(r.n_tokens, 9),
            _ => panic!("expected request"),
        }
        q.close();
        assert!(matches!(q.pop_routed(1, &router, false, None), (Popped::Closed, _)));
    }

    #[test]
    fn affinity_key_numeric_verbatim_else_hashed() {
        assert_eq!(affinity_key("0"), 0);
        assert_eq!(affinity_key("42"), 42);
        assert_eq!(affinity_key("user-abc"), affinity_key("user-abc"));
        assert_ne!(affinity_key("user-abc"), affinity_key("user-abd"));
        assert_ne!(affinity_key(""), affinity_key("x"));
    }

    #[test]
    fn metrics_json_replicated_merges_and_reports_replicas() {
        let metrics = ServeMetrics::default();
        metrics.engine_replicas_alive.store(2, Ordering::Relaxed);
        let a = ServeSnapshot {
            completed_sessions: 3,
            cache: CacheStats { hits: 10, misses: 2, ..Default::default() },
            round_batching: RoundBatchStats {
                rounds: 2,
                distinct_experts: 5,
                dedup_joins: 3,
                batched_rows: 8,
            },
            host_tier: HostTierStats { host_accesses: 50, ram_hits: 40, ..Default::default() },
            ..Default::default()
        };
        let b = ServeSnapshot {
            completed_sessions: 4,
            cache: CacheStats { hits: 20, misses: 5, ..Default::default() },
            round_batching: RoundBatchStats {
                rounds: 3,
                distinct_experts: 7,
                dedup_joins: 4,
                batched_rows: 11,
            },
            // the SAME shared store, read later by replica b (more
            // accesses accumulated) — must be taken once, never summed
            host_tier: HostTierStats { host_accesses: 90, ram_hits: 70, ..Default::default() },
            ..Default::default()
        };
        let router = ReplicaRouter::new(2);
        router.note_admitted(0);
        router.note_admitted(1);
        router.note_admitted(1);
        let v = metrics_json_replicated(&metrics, &[a, b], &router);
        assert_eq!(v.get("engine_replicas_alive").as_usize(), Some(2));
        // per-replica counters sum across replicas
        assert_eq!(v.get("completed_sessions").as_usize(), Some(7));
        let cache = v.get("shared_cache");
        assert_eq!(cache.get("hits").as_usize(), Some(30));
        assert_eq!(cache.get("misses").as_usize(), Some(7));
        // the dedup identity batched_rows − distinct_experts == dedup_joins
        // survives the merge
        let rb = v.get("round_batching");
        assert_eq!(rb.get("batched_rows").as_usize(), Some(19));
        assert_eq!(rb.get("distinct_experts").as_usize(), Some(12));
        assert_eq!(rb.get("dedup_joins").as_usize(), Some(7));
        // shared-store stats come from the freshest reader, not a sum
        let ht = v.get("host_tier");
        assert_eq!(ht.get("host_accesses").as_usize(), Some(90));
        assert_eq!(ht.get("ram_hits").as_usize(), Some(70));
        let replicas = v.get("replicas").as_arr().unwrap();
        assert_eq!(replicas.len(), 2);
        assert_eq!(replicas[0].get("admitted").as_usize(), Some(1));
        assert_eq!(replicas[1].get("admitted").as_usize(), Some(2));
        assert_eq!(replicas[1].get("alive").as_bool(), Some(true));
        assert_eq!(replicas[1].get("completed_sessions").as_usize(), Some(4));
    }

    /// Loopback socket pair for exercising StreamConn against a real TCP
    /// stream without a full server.
    fn socket_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    fn read_all(mut s: TcpStream) -> String {
        use std::io::Read;
        let mut buf = String::new();
        let _ = s.read_to_string(&mut buf);
        buf
    }

    #[test]
    fn stream_conn_flushes_chunks_and_terminator() {
        let (client, server) = socket_pair();
        let metrics = ServeMetrics::default();
        metrics.inflight_sessions.store(1, Ordering::Relaxed);
        let conn = StreamConn::new(server);
        conn.push_text("hel");
        flush_stream(&conn, &metrics);
        conn.push_text("lo");
        flush_stream(&conn, &metrics);
        conn.finish(None);
        flush_stream(&conn, &metrics);
        // terminal flush released the in-flight slot, exactly once
        assert_eq!(metrics.inflight_sessions.load(Ordering::Relaxed), 0);
        flush_stream(&conn, &metrics); // idempotent after finish
        assert_eq!(metrics.inflight_sessions.load(Ordering::Relaxed), 0);
        drop(conn);
        let raw = read_all(client);
        assert!(raw.contains("Transfer-Encoding: chunked"), "head missing: {raw}");
        let body = raw.split("\r\n\r\n").nth(1).unwrap();
        let chunks = http::dechunk(body).expect("well-formed chunked body");
        assert_eq!(chunks, vec!["hel".to_string(), "lo".to_string()]);
        assert_eq!(metrics.write_errors.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.client_disconnects.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn stream_conn_error_before_first_chunk_is_a_buffered_error_response() {
        let (client, server) = socket_pair();
        let metrics = ServeMetrics::default();
        metrics.inflight_sessions.store(1, Ordering::Relaxed);
        let conn = StreamConn::new(server);
        conn.finish(Some(GenError {
            status: 500,
            message: "decode failed".into(),
            retry_after: None,
        }));
        flush_stream(&conn, &metrics);
        assert_eq!(metrics.errors.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.inflight_sessions.load(Ordering::Relaxed), 0);
        drop(conn);
        let raw = read_all(client);
        // no chunked framing started, so the client gets a plain error
        assert!(raw.starts_with("HTTP/1.1 500"), "raw: {raw}");
        assert!(!raw.contains("Transfer-Encoding"), "raw: {raw}");
        assert!(raw.contains("decode failed"), "raw: {raw}");
    }

    #[test]
    fn stream_conn_error_after_head_cuts_stream_without_terminator() {
        let (client, server) = socket_pair();
        let metrics = ServeMetrics::default();
        metrics.inflight_sessions.store(1, Ordering::Relaxed);
        let conn = StreamConn::new(server);
        conn.push_text("part");
        flush_stream(&conn, &metrics);
        conn.finish(Some(GenError {
            status: 500,
            message: "expert lost".into(),
            retry_after: None,
        }));
        flush_stream(&conn, &metrics);
        assert_eq!(metrics.errors.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.inflight_sessions.load(Ordering::Relaxed), 0);
        drop(conn);
        let raw = read_all(client);
        let body = raw.split("\r\n\r\n").nth(1).unwrap();
        // truncation is visible to the client: no 0-length final frame
        assert!(http::dechunk(body).is_none(), "body should be unterminated: {body}");
        assert!(body.contains("part"));
    }

    #[test]
    fn stream_conn_detects_client_eof() {
        let (client, server) = socket_pair();
        let conn = StreamConn::new(server);
        assert!(!conn.client_gone(), "connected client misread as gone");
        drop(client);
        // EOF is visible via the zero-byte peek and latches (allow a few
        // polls for the FIN to land, even on loopback)
        let mut gone = false;
        for _ in 0..200 {
            if conn.client_gone() {
                gone = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert!(gone, "EOF never observed");
        assert!(conn.disconnected.load(Ordering::Relaxed));
        assert!(conn.client_gone(), "latch must persist");
    }

    #[test]
    fn write_failure_classification_splits_disconnects_from_server_errors() {
        let metrics = ServeMetrics::default();
        let pipe: anyhow::Error =
            std::io::Error::new(std::io::ErrorKind::BrokenPipe, "gone").into();
        count_write_failure(&pipe, false, &metrics);
        let timeout: anyhow::Error =
            std::io::Error::new(std::io::ErrorKind::TimedOut, "slow").into();
        count_write_failure(&timeout, false, &metrics);
        // mid-stream failures always mean the client hung up
        count_write_failure(&timeout, true, &metrics);
        assert_eq!(metrics.client_disconnects.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.write_errors.load(Ordering::Relaxed), 1);
    }
}
