//! HTTP serving front: request queue + single engine worker.
//!
//! Architecture (vLLM-router-like, scaled to the paper's batch-size-1
//! setting): a thread pool accepts connections and parses requests; decode
//! work is funneled through an mpsc queue to ONE engine worker that owns
//! the (non-`Send`) PJRT backend and the expert cache — so the cache state
//! and its hit statistics are shared across requests, exactly like the
//! paper's persistent GPU cache across a conversation.
//!
//! API:
//!   POST /generate   {"prompt": str, "n_tokens": int, "temperature"?: f,
//!                     "top_p"?: f, "greedy"?: bool}
//!   GET  /metrics    cache + throughput counters (JSON)
//!   GET  /healthz

pub mod http;

use crate::model::sampler::{Sampler, Sampling};
use crate::model::tokenizer::Tokenizer;
use crate::util::cliargs::Args;
use crate::util::json::{self, Value};
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

pub struct GenRequest {
    pub prompt: String,
    pub n_tokens: usize,
    pub sampling: Sampling,
    pub resp: Sender<Result<GenResponse, String>>,
}

#[derive(Clone, Debug)]
pub struct GenResponse {
    pub text: String,
    pub n_prompt: usize,
    pub n_generated: usize,
    pub wall_s: f64,
    pub sim_tokens_per_s: f64,
    pub cache_hit_rate: f64,
}

/// Serve-level metrics, shared between workers and /metrics.
#[derive(Default)]
pub struct ServerMetrics {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub queue_depth: AtomicU64,
}

impl ServerMetrics {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("requests", Value::from(self.requests.load(Ordering::Relaxed) as f64)),
            ("errors", Value::from(self.errors.load(Ordering::Relaxed) as f64)),
            (
                "tokens_generated",
                Value::from(self.tokens_generated.load(Ordering::Relaxed) as f64),
            ),
            ("queue_depth", Value::from(self.queue_depth.load(Ordering::Relaxed) as f64)),
        ])
    }
}

/// Parse the /generate request body.
pub fn parse_gen_request(body: &[u8]) -> Result<(String, usize, Sampling), String> {
    let v = json::parse(std::str::from_utf8(body).map_err(|e| e.to_string())?)
        .map_err(|e| e.to_string())?;
    let prompt = v
        .get("prompt")
        .as_str()
        .ok_or("missing 'prompt'")?
        .to_string();
    let n = v.get("n_tokens").as_usize().unwrap_or(32);
    if n == 0 || n > 4096 {
        return Err(format!("n_tokens {n} out of range"));
    }
    let sampling = if v.get("greedy").as_bool() == Some(true) {
        Sampling::Greedy
    } else {
        Sampling::TopP {
            temperature: v.get("temperature").as_f64().unwrap_or(0.9) as f32,
            top_p: v.get("top_p").as_f64().unwrap_or(0.9) as f32,
        }
    };
    Ok((prompt, n, sampling))
}

pub fn gen_response_json(r: &GenResponse) -> String {
    json::to_string(&Value::obj(vec![
        ("text", Value::from(r.text.clone())),
        ("n_prompt", Value::from(r.n_prompt)),
        ("n_generated", Value::from(r.n_generated)),
        ("wall_s", Value::from(r.wall_s)),
        ("sim_tokens_per_s", Value::from(r.sim_tokens_per_s)),
        ("cache_hit_rate", Value::from(r.cache_hit_rate)),
    ]))
}

/// Run the server until `shutdown` flips (or forever). Engine construction
/// is deferred to the worker thread because the PJRT backend is not `Send`.
pub fn serve<F>(
    listener: TcpListener,
    make_engine: F,
    n_http_workers: usize,
    shutdown: Arc<AtomicBool>,
) -> Result<()>
where
    F: FnOnce() -> Result<crate::engine::InferenceEngine> + Send + 'static,
{
    let metrics = Arc::new(ServerMetrics::default());
    let (queue_tx, queue_rx) = channel::<GenRequest>();

    // engine worker: owns the engine, serializes decodes (paper batch=1)
    let worker_metrics = Arc::clone(&metrics);
    let engine_worker = std::thread::Builder::new()
        .name("engine-worker".into())
        .spawn(move || {
            let mut engine = match make_engine() {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("engine init failed: {e:#}");
                    return;
                }
            };
            let tk = Tokenizer::new(engine.config().vocab_size);
            let mut req_counter = 0u64;
            while let Ok(req) = queue_rx.recv() {
                worker_metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                req_counter += 1;
                let prompt_toks = tk.encode(&req.prompt);
                let mut sampler = Sampler::new(req.sampling, req_counter);
                let max = engine.config().max_seq;
                let result = if prompt_toks.len() + req.n_tokens > max {
                    Err(format!(
                        "prompt {} + n_tokens {} exceeds max_seq {max}",
                        prompt_toks.len(),
                        req.n_tokens
                    ))
                } else {
                    engine
                        .generate(&prompt_toks, req.n_tokens, &mut sampler)
                        .map(|out| {
                            worker_metrics
                                .tokens_generated
                                .fetch_add(out.generated.len() as u64, Ordering::Relaxed);
                            GenResponse {
                                text: tk.decode(&out.generated),
                                n_prompt: prompt_toks.len(),
                                n_generated: out.generated.len(),
                                wall_s: out.throughput.wall_s,
                                sim_tokens_per_s: out.throughput.tokens_per_s_sim(),
                                cache_hit_rate: out.cache_stats.hit_rate(),
                            }
                        })
                        .map_err(|e| format!("{e:#}"))
                };
                let _ = req.resp.send(result);
            }
        })?;

    let pool = ThreadPool::new(n_http_workers);
    let queue_tx = Arc::new(Mutex::new(queue_tx));
    listener.set_nonblocking(true)?;
    println!("serving on {}", listener.local_addr()?);
    loop {
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        match listener.accept() {
            Ok((mut stream, _)) => {
                stream.set_nonblocking(false).ok();
                let metrics = Arc::clone(&metrics);
                let queue_tx = Arc::clone(&queue_tx);
                pool.execute(move || {
                    handle_conn(&mut stream, &metrics, &queue_tx);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => {
                eprintln!("accept error: {e}");
                break;
            }
        }
    }
    drop(pool);
    drop(queue_tx);
    let _ = engine_worker.join();
    Ok(())
}

fn handle_conn(
    stream: &mut std::net::TcpStream,
    metrics: &ServerMetrics,
    queue_tx: &Mutex<Sender<GenRequest>>,
) {
    let req = match http::read_request(stream) {
        Ok(r) => r,
        Err(_) => {
            let _ = http::write_response(stream, 400, "text/plain", b"bad request");
            return;
        }
    };
    metrics.requests.fetch_add(1, Ordering::Relaxed);
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let _ = http::write_response(stream, 200, "text/plain", b"ok");
        }
        ("GET", "/metrics") => {
            let body = json::to_string(&metrics.to_json());
            let _ = http::write_response(stream, 200, "application/json", body.as_bytes());
        }
        ("POST", "/generate") => match parse_gen_request(&req.body) {
            Ok((prompt, n, sampling)) => {
                let (tx, rx) = channel();
                metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                let sent = queue_tx
                    .lock()
                    .unwrap()
                    .send(GenRequest { prompt, n_tokens: n, sampling, resp: tx })
                    .is_ok();
                if !sent {
                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = http::write_response(stream, 503, "text/plain", b"engine down");
                    return;
                }
                match rx.recv() {
                    Ok(Ok(resp)) => {
                        let body = gen_response_json(&resp);
                        let _ =
                            http::write_response(stream, 200, "application/json", body.as_bytes());
                    }
                    Ok(Err(msg)) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let body = json::to_string(&Value::obj(vec![(
                            "error",
                            Value::from(msg),
                        )]));
                        let _ =
                            http::write_response(stream, 400, "application/json", body.as_bytes());
                    }
                    Err(_) => {
                        metrics.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = http::write_response(stream, 500, "text/plain", b"worker died");
                    }
                }
            }
            Err(msg) => {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                let body =
                    json::to_string(&Value::obj(vec![("error", Value::from(msg))]));
                let _ = http::write_response(stream, 400, "application/json", body.as_bytes());
            }
        },
        _ => {
            let _ = http::write_response(stream, 404, "text/plain", b"not found");
        }
    }
}

/// `moe-offload serve` entrypoint.
pub fn cmd_serve(args: &Args) -> Result<()> {
    use crate::offload::store::HostExpertStore;
    use crate::runtime::artifacts::Artifacts;

    let port = args.usize_or("port", 7080)?;
    let dir = args.str_or("artifacts", "artifacts");
    let backend_kind = args.str_or("backend", "pjrt");
    let policy = crate::cache::PolicyKind::parse(&args.str_or("policy", "lfu"))
        .ok_or_else(|| anyhow::anyhow!("bad --policy"))?;
    let capacity = args.usize_or("capacity", 4)?;
    let quant = crate::quant::Scheme::parse(&args.str_or("quant", "int4"))
        .ok_or_else(|| anyhow::anyhow!("bad --quant"))?;
    let spec = args.bool("spec");
    let overlap = args.bool("overlap");
    let profile = crate::sim::hardware::by_name(&args.str_or("profile", "A100"))
        .ok_or_else(|| anyhow::anyhow!("bad --profile"))?;

    let listener = TcpListener::bind(("0.0.0.0", port as u16))?;
    let shutdown = Arc::new(AtomicBool::new(false));
    serve(
        listener,
        move || {
            let artifacts = Artifacts::load(std::path::Path::new(&dir))?;
            let weights = Arc::new(crate::model::Weights::load(&artifacts.weights_path)?);
            let backend: Box<dyn crate::runtime::Backend> = match backend_kind.as_str() {
                "native" => Box::new(crate::runtime::native::NativeBackend::new(Arc::clone(&weights))),
                _ => Box::new(crate::runtime::pjrt::PjrtBackend::new(&artifacts, &weights)?),
            };
            let store = Arc::new(HostExpertStore::build(&weights, quant)?);
            Ok(crate::engine::InferenceEngine::new(
                backend,
                store,
                crate::engine::EngineConfig {
                    cache_capacity: capacity,
                    policy,
                    prefetch: crate::offload::prefetch::PrefetchConfig { enabled: spec, k: 2 },
                    overlap,
                    profile,
                    seed: 0,
                    record_trace: false,
                },
            ))
        },
        args.usize_or("http-workers", 4)?,
        shutdown,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_gen_request_ok() {
        let (p, n, s) =
            parse_gen_request(br#"{"prompt":"hi","n_tokens":8,"greedy":true}"#).unwrap();
        assert_eq!(p, "hi");
        assert_eq!(n, 8);
        assert_eq!(s, Sampling::Greedy);
    }

    #[test]
    fn parse_gen_request_defaults() {
        let (_, n, s) = parse_gen_request(br#"{"prompt":"x"}"#).unwrap();
        assert_eq!(n, 32);
        assert!(matches!(s, Sampling::TopP { .. }));
    }

    #[test]
    fn parse_gen_request_rejects() {
        assert!(parse_gen_request(b"{}").is_err());
        assert!(parse_gen_request(b"not json").is_err());
        assert!(parse_gen_request(br#"{"prompt":"x","n_tokens":0}"#).is_err());
    }

    #[test]
    fn response_json_shape() {
        let r = GenResponse {
            text: "abc".into(),
            n_prompt: 4,
            n_generated: 3,
            wall_s: 0.5,
            sim_tokens_per_s: 12.25,
            cache_hit_rate: 0.75,
        };
        let v = json::parse(&gen_response_json(&r)).unwrap();
        assert_eq!(v.get("text").as_str(), Some("abc"));
        assert_eq!(v.get("n_generated").as_usize(), Some(3));
        assert_eq!(v.get("cache_hit_rate").as_f64(), Some(0.75));
    }
}
