//! Session scheduler: multiplex N concurrent decode sessions over the ONE
//! engine worker that owns the (non-`Send`) backend and the shared expert
//! cache.
//!
//! Scheduling discipline (DESIGN.md §6): round-robin token interleaving.
//! Each scheduler round steps every active session by exactly one token
//! (via [`Session::step_once`], the same feeding discipline offline
//! lockstep decoding uses), so no session can starve another,
//! time-to-first-token is bounded by one round, and consecutive tokens of
//! different sessions share the per-layer expert cache — a transfer paid
//! by one session is a hit for every other session that activates the same
//! expert while it stays resident (the paper's persistent-cache semantics,
//! now contended across sessions).
//!
//! Admission is demand-driven: new requests are drained from the bounded
//! queue between rounds, up to `max_sessions` in flight; beyond that they
//! wait in the queue (whose bound is the HTTP 503 backpressure limit).
//! Per-session accounting comes from the engine's session tallies
//! ([`crate::metrics::SessionTally`]) and is published after every round in
//! a [`ServeSnapshot`] the `/metrics` endpoint renders without touching the
//! engine thread.

use crate::engine::batch::Session;
use crate::engine::InferenceEngine;
use crate::metrics::{CacheStats, PipelineStats, PrecisionRecall, SessionTally};
use crate::model::sampler::Sampler;
use crate::model::tokenizer::Tokenizer;
use crate::serve::{GenError, GenRequest, GenResponse, ServerMetrics};
use crate::sim::costmodel::TokenEvents;
use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// How many finished sessions `/metrics` keeps visible after completion.
const RECENT_SESSIONS: usize = 32;

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Maximum sessions decoded concurrently (further requests queue).
    pub max_sessions: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_sessions: 8 }
    }
}

/// One session's row in the `/metrics` report.
#[derive(Clone, Debug)]
pub struct SessionView {
    pub id: u64,
    /// "active" while decoding, then "done" (responded) or "failed"
    /// (engine error mid-decode).
    pub state: &'static str,
    pub n_prompt: usize,
    pub generated: usize,
    pub target: usize,
    pub tally: SessionTally,
}

/// Aggregate + per-session view the scheduler publishes after every round.
/// There is exactly ONE shared expert cache behind all sessions; `cache`
/// reports its totals and `sessions[*].tally` partitions them.
#[derive(Clone, Debug, Default)]
pub struct ServeSnapshot {
    pub policy: String,
    pub capacity_per_layer: usize,
    pub n_layers: usize,
    pub active_sessions: usize,
    pub completed_sessions: u64,
    /// Sessions that died on an engine error mid-decode (not counted as
    /// completed; their clients got HTTP 500).
    pub failed_sessions: u64,
    pub cache: CacheStats,
    pub spec: PrecisionRecall,
    pub cross_session_prefetch_hits: u64,
    /// Transfer-pipeline queue + buffer-pool counters (workers == 0 when
    /// the engine runs transfers synchronously).
    pub pipeline: PipelineStats,
    pub sessions: Vec<SessionView>,
}

struct ActiveSession {
    inner: Session,
    started: Instant,
    /// Simulated clock reading at admission; the span until completion
    /// covers every interleaved token, so per-session sim tokens/s reflects
    /// contention — the serving metric, not the solo-decode one.
    sim_start: f64,
    resp: Sender<Result<GenResponse, GenError>>,
}

/// Run the scheduler until the request channel closes and no sessions
/// remain. Owns the engine for its entire lifetime.
pub fn run_scheduler(
    mut engine: InferenceEngine,
    rx: Receiver<GenRequest>,
    cfg: SchedulerConfig,
    metrics: Arc<ServerMetrics>,
    snapshot: Arc<Mutex<ServeSnapshot>>,
) {
    let tk = Tokenizer::new(engine.config().vocab_size);
    let max_sessions = cfg.max_sessions.max(1);
    let mut active: Vec<ActiveSession> = Vec::new();
    let mut recent: VecDeque<SessionView> = VecDeque::new();
    let mut completed: u64 = 0;
    let mut failed_sessions: u64 = 0;
    let mut next_id: u64 = 1;

    {
        let mut snap = snapshot.lock().unwrap();
        snap.policy = engine.cfg.policy.name().to_string();
        snap.capacity_per_layer = engine.cfg.cache_capacity;
        snap.n_layers = engine.config().n_layers;
    }

    'outer: loop {
        // --- admission: block when idle, drain opportunistically when busy
        while active.len() < max_sessions {
            let req = if active.is_empty() {
                match rx.recv() {
                    Ok(r) => r,
                    Err(_) => break 'outer, // all senders gone, nothing active
                }
            } else {
                match rx.try_recv() {
                    Ok(r) => r,
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            };
            // saturating decrement: the gauge must never wrap if a producer
            // raced its increment
            let _ = metrics
                .queue_depth
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
            // admission failures answer on the response channel; the HTTP
            // layer counts them in metrics.errors when it relays the Err
            if let Some(sess) = admit(&engine, &tk, next_id, req) {
                active.push(sess);
                next_id += 1;
            }
        }

        // --- one round-robin pass: every active session advances one token
        let mut finished: Vec<ActiveSession> = Vec::new();
        let mut i = 0;
        while i < active.len() {
            let s = &mut active[i];
            let was_generated = s.inner.next_token_is_generated();
            let mut ev = TokenEvents::default();
            let failed = match s.inner.step_once(&mut engine, &mut ev) {
                Ok(_done) => {
                    if was_generated {
                        metrics.tokens_generated.fetch_add(1, Ordering::Relaxed);
                    }
                    false
                }
                Err(e) => {
                    // engine-side failure: 500, counted by the HTTP layer
                    let _ = s.resp.send(Err(GenError {
                        status: 500,
                        message: format!("{e:#}"),
                    }));
                    true
                }
            };
            if failed || s.inner.done {
                finished.push(active.swap_remove(i));
            } else {
                i += 1;
            }
        }

        for s in finished {
            let tally = engine.take_session_tally(s.inner.id);
            let generated = s.inner.generated().len();
            let succeeded = s.inner.done;
            if succeeded {
                let sim_span = engine.sim_now() - s.sim_start;
                let resp = GenResponse {
                    text: tk.decode(s.inner.generated()),
                    n_prompt: s.inner.n_prompt,
                    n_generated: generated,
                    wall_s: s.started.elapsed().as_secs_f64(),
                    sim_tokens_per_s: if sim_span > 0.0 {
                        (s.inner.n_prompt + generated) as f64 / sim_span
                    } else {
                        0.0
                    },
                    cache_hit_rate: tally.hit_rate(),
                    session_id: s.inner.id,
                    session_hits: tally.hits,
                    session_misses: tally.misses,
                    spec_precision: tally.spec_pr.precision(),
                    spec_recall: tally.spec_pr.recall(),
                };
                let _ = s.resp.send(Ok(resp));
                completed += 1;
            } else {
                failed_sessions += 1;
            }
            recent.push_back(SessionView {
                id: s.inner.id,
                state: if succeeded { "done" } else { "failed" },
                n_prompt: s.inner.n_prompt,
                generated,
                target: s.inner.target_new,
                tally,
            });
            while recent.len() > RECENT_SESSIONS {
                recent.pop_front();
            }
        }

        publish(&engine, &active, &recent, completed, failed_sessions, &snapshot);
    }

    publish(&engine, &active, &recent, completed, failed_sessions, &snapshot);
}

/// Validate and set up one request as an active session. On failure the
/// error is sent on the response channel and `None` returned: length
/// violations are the client's fault (400), anything else in session
/// construction is the server's (500).
fn admit(
    engine: &InferenceEngine,
    tk: &Tokenizer,
    id: u64,
    req: GenRequest,
) -> Option<ActiveSession> {
    let prompt = tk.encode(&req.prompt);
    let max = engine.config().max_seq;
    if prompt.len() + req.n_tokens > max {
        let _ = req.resp.send(Err(GenError {
            status: 400,
            message: format!(
                "prompt {} + n_tokens {} exceeds max_seq {max}",
                prompt.len(),
                req.n_tokens
            ),
        }));
        return None;
    }
    let sampler = Sampler::new(req.sampling, id);
    let inner = match Session::new(id, engine, &prompt, req.n_tokens, sampler) {
        Ok(s) => s,
        Err(e) => {
            let _ = req.resp.send(Err(GenError { status: 500, message: format!("{e:#}") }));
            return None;
        }
    };
    Some(ActiveSession {
        inner,
        started: Instant::now(),
        sim_start: engine.sim_now(),
        resp: req.resp,
    })
}

fn publish(
    engine: &InferenceEngine,
    active: &[ActiveSession],
    recent: &VecDeque<SessionView>,
    completed: u64,
    failed_sessions: u64,
    snapshot: &Arc<Mutex<ServeSnapshot>>,
) {
    let mut views: Vec<SessionView> = active
        .iter()
        .map(|s| SessionView {
            id: s.inner.id,
            state: "active",
            n_prompt: s.inner.n_prompt,
            generated: s.inner.generated().len(),
            target: s.inner.target_new,
            tally: engine.session_tally(s.inner.id),
        })
        .collect();
    views.extend(recent.iter().cloned());
    let mut snap = snapshot.lock().unwrap();
    snap.active_sessions = active.len();
    snap.completed_sessions = completed;
    snap.failed_sessions = failed_sessions;
    snap.cache = engine.cache_stats();
    snap.spec = engine.spec_precision_recall();
    snap.cross_session_prefetch_hits = engine.cross_session_prefetch_hits();
    snap.pipeline = engine.pipeline_stats();
    snap.sessions = views;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::PolicyKind;
    use crate::engine::EngineConfig;
    use crate::model::sampler::Sampling;
    use crate::model::weights::generate_weights;
    use crate::model::ModelConfig;
    use crate::offload::store::HostExpertStore;
    use crate::quant::Scheme;
    use crate::runtime::native::NativeBackend;
    use std::sync::mpsc::{channel, sync_channel};

    /// Byte-tokenizer-compatible tiny config (vocab must hold 256 bytes +
    /// specials; TINY's vocab of 64 is for raw-token tests only).
    pub(crate) fn serve_test_config() -> ModelConfig {
        ModelConfig {
            vocab_size: 320,
            max_seq: 96,
            ..ModelConfig::TINY
        }
    }

    pub(crate) fn test_engine(spec: bool) -> InferenceEngine {
        test_engine_workers(spec, 0)
    }

    pub(crate) fn test_engine_workers(spec: bool, transfer_workers: usize) -> InferenceEngine {
        let weights = Arc::new(generate_weights(serve_test_config(), 42));
        let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32).unwrap());
        let mut cfg = EngineConfig::serving(4, PolicyKind::Lfu, spec);
        cfg.transfer_workers = transfer_workers;
        InferenceEngine::new(Box::new(NativeBackend::new(weights)), store, cfg)
    }

    #[allow(clippy::type_complexity)]
    fn request(
        prompt: &str,
        n: usize,
    ) -> (GenRequest, std::sync::mpsc::Receiver<Result<GenResponse, GenError>>) {
        let (tx, rx) = channel();
        (
            GenRequest {
                prompt: prompt.to_string(),
                n_tokens: n,
                sampling: Sampling::Greedy,
                resp: tx,
            },
            rx,
        )
    }

    #[test]
    fn scheduler_completes_concurrent_sessions() {
        let engine = test_engine(true);
        let (tx, rx) = sync_channel::<GenRequest>(16);
        let metrics = Arc::new(ServerMetrics::default());
        let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));

        let mut resp_rxs = Vec::new();
        for i in 0..5 {
            let (req, resp_rx) = request(&format!("prompt number {i}"), 6);
            tx.send(req).unwrap();
            metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
            resp_rxs.push(resp_rx);
        }
        drop(tx);
        run_scheduler(
            engine,
            rx,
            SchedulerConfig { max_sessions: 4 },
            Arc::clone(&metrics),
            Arc::clone(&snapshot),
        );

        let mut ids = Vec::new();
        for rx in resp_rxs {
            let resp = rx.recv().unwrap().expect("generation ok");
            assert_eq!(resp.n_generated, 6);
            assert!(!ids.contains(&resp.session_id), "duplicate session id");
            ids.push(resp.session_id);
        }
        let snap = snapshot.lock().unwrap();
        assert_eq!(snap.completed_sessions, 5);
        assert_eq!(snap.failed_sessions, 0);
        assert_eq!(snap.active_sessions, 0);
        // the recent ring keeps every finished session visible
        assert_eq!(snap.sessions.len(), 5);
        assert!(snap.sessions.iter().all(|s| s.state == "done"));
        // one shared cache served them all
        let part: u64 = snap.sessions.iter().map(|s| s.tally.hits + s.tally.misses).sum();
        assert_eq!(part, snap.cache.hits + snap.cache.misses);
        assert_eq!(metrics.tokens_generated.load(Ordering::Relaxed), 5 * 6);
    }

    #[test]
    fn scheduler_with_pipeline_matches_sync_outputs() {
        // the async transfer pipeline must be invisible in the responses:
        // same requests, same texts, with the pipeline counters live
        let run = |workers: usize| {
            let engine = test_engine_workers(true, workers);
            let (tx, rx) = sync_channel::<GenRequest>(8);
            let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
            let mut resp_rxs = Vec::new();
            for i in 0..3 {
                let (req, resp_rx) = request(&format!("pipeline probe {i}"), 5);
                tx.send(req).unwrap();
                resp_rxs.push(resp_rx);
            }
            drop(tx);
            run_scheduler(
                engine,
                rx,
                SchedulerConfig { max_sessions: 3 },
                Arc::new(ServerMetrics::default()),
                Arc::clone(&snapshot),
            );
            let texts: Vec<String> = resp_rxs
                .into_iter()
                .map(|r| r.recv().unwrap().expect("generation ok").text)
                .collect();
            let snap = snapshot.lock().unwrap();
            (texts, snap.pipeline)
        };
        let (sync_texts, sync_pipe) = run(0);
        let (pipe_texts, pipe) = run(2);
        assert_eq!(sync_texts, pipe_texts, "pipeline changed outputs");
        assert_eq!(sync_pipe.workers, 0);
        assert_eq!(pipe.workers, 2);
        assert!(pipe.completed > 0, "pipeline never delivered a transfer");
    }

    #[test]
    fn scheduler_rejects_overlong_requests_and_continues() {
        let engine = test_engine(false);
        let (tx, rx) = sync_channel::<GenRequest>(8);
        let metrics = Arc::new(ServerMetrics::default());
        let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));

        let (bad, bad_rx) = request("way too long", 4096);
        let (good, good_rx) = request("ok", 3);
        tx.send(bad).unwrap();
        tx.send(good).unwrap();
        drop(tx);
        run_scheduler(engine, rx, SchedulerConfig::default(), metrics, snapshot);

        let err = bad_rx.recv().unwrap().unwrap_err();
        assert_eq!(err.status, 400, "length violations are the client's fault");
        assert!(err.message.contains("max_seq"));
        assert_eq!(good_rx.recv().unwrap().unwrap().n_generated, 3);
    }

    #[test]
    fn interleaved_outputs_match_solo_decode() {
        // scheduling must be semantically transparent: a session decoded
        // alongside three others yields the same tokens as decoding alone
        let solo_out = {
            let mut engine = test_engine(false);
            let tk = Tokenizer::new(engine.config().vocab_size);
            let prompt = tk.encode("determinism check");
            // scheduler seeds the sampler with the session id; solo run is
            // admitted first, so it gets id 1
            let mut sampler = Sampler::new(Sampling::Greedy, 1);
            let out = engine.generate(&prompt, 5, &mut sampler).unwrap();
            out.generated
        };

        let engine = test_engine(false);
        let (tx, rx) = sync_channel::<GenRequest>(8);
        let (probe, probe_rx) = request("determinism check", 5);
        tx.send(probe).unwrap();
        let mut others = Vec::new();
        for i in 0..3 {
            let (req, orx) = request(&format!("background load {i}"), 5);
            tx.send(req).unwrap();
            others.push(orx);
        }
        drop(tx);
        run_scheduler(
            engine,
            rx,
            SchedulerConfig { max_sessions: 4 },
            Arc::new(ServerMetrics::default()),
            Arc::new(Mutex::new(ServeSnapshot::default())),
        );

        let tk = Tokenizer::new(serve_test_config().vocab_size);
        let resp = probe_rx.recv().unwrap().unwrap();
        assert_eq!(resp.text, tk.decode(&solo_out), "shared cache changed outputs");
        for orx in others {
            assert!(orx.recv().unwrap().is_ok());
        }
    }
}
