//! Session scheduler: multiplex N concurrent decode sessions over the ONE
//! engine worker that owns the (non-`Send`) backend and the shared expert
//! cache.
//!
//! Scheduling discipline (DESIGN.md §6): **continuous batching with
//! chunked prefill**. Every scheduler round does bounded, heterogeneous
//! work:
//!
//! * each decode-phase session advances by **at most one token**,
//! * **at most one prefill chunk** of `prefill_chunk` prompt tokens
//!   advances one prefill-phase session (rotating across them), and
//! * a round budget (`round_budget_tokens`) caps the **total** tokens
//!   advanced per round.
//!
//! Candidates (every decode-phase session, plus one *prefill unit*
//! standing for the oldest-served prefill-phase session) are served
//! oldest-first by the round they last advanced; when the budget
//! saturates, unserved candidates keep their older stamp and therefore
//! outrank this round's served ones next round — the deficit carry-over
//! that makes starvation impossible. A session skipped for budget waits
//! at most `candidates − 1` rounds (proven by
//! `prop_chunked_prefill_fair_and_bit_identical`). With
//! `prefill_chunk == 0` the scheduler degrades to the legacy discipline:
//! prompt tokens advance one per session per round exactly like decode
//! tokens, so a long prompt pays its prefill one round at a time.
//! Chunking changes *scheduling only*: per-session outputs are
//! bit-identical to the unchunked path because each token still runs
//! through [`Session::step_once`]'s feeding discipline.
//!
//! Consecutive tokens of different sessions share the per-layer expert
//! cache — a transfer paid by one session (prefill or decode) is a hit
//! for every other session that activates the same expert while it stays
//! resident (the paper's persistent-cache semantics, contended across
//! sessions); prefill chunks run through the same `step_session`
//! attribution as decode tokens, so they hit the cache and the
//! prefetcher identically.
//!
//! Admission is demand-driven over the bounded [`AdmissionQueue`]: new
//! requests are drained between rounds, up to `max_sessions` in flight —
//! sessions join and leave mid-flight, no barrier rounds. Before every
//! admission pass the scheduler runs a *shed sweep*: queued requests
//! older than `queue_timeout` answer 503 + `Retry-After` without ever
//! becoming a session — a shed request consumes zero engine steps.
//! Finished generations are posted to the completion channel (the client
//! socket rides along) so the scheduler never writes to a socket and can
//! never be blocked by a slow client.
//!
//! Per-session accounting comes from the engine's session tallies
//! ([`crate::metrics::SessionTally`]) and is published after every round in
//! a [`ServeSnapshot`] the `/metrics` endpoint renders without touching the
//! engine thread. Time-to-first-token is recorded the moment a session's
//! prompt is fully fed (its first output token is sampled right then).
//!
//! Robustness extensions (DESIGN.md §9):
//!
//! * **Streaming**: a session with a [`ReplyTo::Stream`] reply pushes each
//!   newly stable span of decoded text onto its connection buffer as the
//!   token lands and posts a flush event; the responder set writes the
//!   chunked frames. Only the longest prefix whose UTF-8 decoding can no
//!   longer change is streamed per token, so the concatenated chunks are
//!   byte-identical to the buffered `text` field.
//! * **Cancellation**: a disconnect sweep before every round flags
//!   streamed sessions whose client is gone (`request → active →
//!   retiring → released`); a flagged session does no further engine work
//!   and is retired at that round boundary — its queued prefetches are
//!   cancelled, its tally and speculative state dropped, and its
//!   in-flight slot released, with no reply delivered.
//! * **Priority**: `interactive` candidates outrank `batch` inside the
//!   round budget. A batch candidate that has waited more than
//!   `max_sessions + 1` rounds is promoted to interactive rank with an
//!   older deficit stamp, so batch TTFT is bounded by roughly
//!   `2·max_sessions + 2` rounds even under saturating interactive load.

use crate::engine::batch::Session;
use crate::engine::{EngineReplica, InferenceEngine, RoundWork};
use crate::metrics::{
    CacheStats, HostTierStats, PipelineStats, PrecisionRecall, RoundBatchStats, ServeMetrics,
    SessionTally,
};
use crate::model::sampler::Sampler;
use crate::model::tokenizer::Tokenizer;
use crate::serve::{
    release_inflight, AdmissionQueue, Completion, GenError, GenRequest, GenResponse, Popped,
    Priority, ReplicaRouter, ReplyTo, RETRY_AFTER_S,
};
use crate::sim::costmodel::TokenEvents;
use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How many finished sessions `/metrics` keeps visible after completion.
const RECENT_SESSIONS: usize = 32;

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Maximum sessions decoded concurrently (further requests queue).
    pub max_sessions: usize,
    /// Shed queued requests older than this before admitting them
    /// (`None` = requests wait indefinitely).
    pub queue_timeout: Option<Duration>,
    /// Prefill chunk size in prompt tokens. `0` = legacy rounds (prompt
    /// tokens advance one per session per round, like decode tokens);
    /// `k > 0` = at most ONE chunk of ≤ `k` prompt tokens per round,
    /// rotated across prefill-phase sessions.
    pub prefill_chunk: usize,
    /// Cap on total tokens advanced per round, decode + prefill
    /// (`0` = unbounded). When the budget saturates, unserved candidates
    /// carry their entitlement to later rounds (deficit carry-over,
    /// oldest first) — long-prompt sessions cannot starve decoders and
    /// vice versa.
    pub round_budget_tokens: usize,
    /// Round-level expert batching (DESIGN.md §8): dispatch the whole
    /// round's tokens through ONE [`InferenceEngine::step_round`] so
    /// sessions routing to the same `(layer, expert)` share a single
    /// resident-ensure + dequant + batched FFN pass. `false` falls back
    /// to the legacy per-session `step_once` loop (`--round-batching
    /// off`); both paths produce bit-identical outputs
    /// (`prop_round_batching_bit_identical`).
    pub round_batching: bool,
    /// Seconds advertised in the `Retry-After` header of every 503 this
    /// scheduler sheds (`--retry-after-s`); the serve layer's admission
    /// rejects advertise the same value, so clients see ONE consistent
    /// back-off policy however their request was refused.
    pub retry_after: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_sessions: 8,
            queue_timeout: None,
            prefill_chunk: 0,
            round_budget_tokens: 0,
            round_batching: true,
            retry_after: RETRY_AFTER_S,
        }
    }
}

/// One session's advancement within a round (see [`RoundReport`]).
#[derive(Clone, Copy, Debug)]
pub struct Advance {
    pub session: u64,
    /// Tokens this session advanced this round (1 for a decode step,
    /// up to the chunk size for a prefill chunk).
    pub tokens: usize,
    /// The advanced tokens were prompt (prefill) tokens.
    pub prefill: bool,
}

/// What one scheduler round did — the observable the fairness and budget
/// invariants are proven against (`proptest_invariants.rs`). Produced by
/// [`Scheduler::turn`].
#[derive(Clone, Debug, Default)]
pub struct RoundReport {
    /// 1-based round index.
    pub round: u64,
    /// Sessions active when the round ran.
    pub active: usize,
    /// Decode tokens advanced this round (≤ 1 per session).
    pub decode_tokens: usize,
    /// Prompt tokens advanced this round (≤ 1 chunk when chunking).
    pub prefill_tokens: usize,
    /// Per-session advancement; `decode_tokens + prefill_tokens` equals
    /// the sum of `tokens` and never exceeds the round budget.
    pub advanced: Vec<Advance>,
    /// Candidates that were eligible but skipped because the budget was
    /// exhausted; they outrank this round's served candidates next round.
    pub skipped: Vec<u64>,
}

/// One session's row in the `/metrics` report.
#[derive(Clone, Debug)]
pub struct SessionView {
    pub id: u64,
    /// "active" while decoding, then "done" (responded) or "failed"
    /// (engine error mid-decode).
    pub state: &'static str,
    pub n_prompt: usize,
    pub generated: usize,
    pub target: usize,
    pub tally: SessionTally,
}

/// Aggregate + per-session view the scheduler publishes after every round.
/// There is exactly ONE shared expert cache behind all sessions; `cache`
/// reports its totals and `sessions[*].tally` partitions them.
#[derive(Clone, Debug, Default)]
pub struct ServeSnapshot {
    pub policy: String,
    pub capacity_per_layer: usize,
    pub n_layers: usize,
    pub active_sessions: usize,
    pub completed_sessions: u64,
    /// Sessions that died on an engine error mid-decode (not counted as
    /// completed; their clients got HTTP 500).
    pub failed_sessions: u64,
    /// Prompt tokens admitted but not yet fed through the engine, summed
    /// over active sessions — the chunked-prefill work backlog.
    pub prefill_backlog: usize,
    pub cache: CacheStats,
    pub spec: PrecisionRecall,
    pub cross_session_prefetch_hits: u64,
    /// Whether the engine holds loaded cross-layer predictor weights.
    pub predictor_active: bool,
    /// Predictor-driven guess quality (markov/learned prefetch sources):
    /// guesses settled against the layer visits they targeted. All-zero
    /// under the default gate source.
    pub predictor: PrecisionRecall,
    /// Trace records the online Markov predictor skipped for out-of-range
    /// expert ids (0 unless `--prefetch-source markov`).
    pub predictor_skipped_records: u64,
    /// Prefetch hits attributed to each source, indexed like
    /// [`crate::offload::prefetch::PrefetchSource::ALL`]:
    /// `[gate, markov, learned]`.
    pub prefetch_hits_by_source: [u64; 3],
    /// Transfer-pipeline queue + buffer-pool counters (workers == 0 when
    /// the engine runs transfers synchronously).
    pub pipeline: PipelineStats,
    /// Round-level expert-batching counters (all zero when the scheduler
    /// runs with `round_batching` off): distinct `(layer, expert)` groups
    /// executed, dedup joins (rows that piggybacked on a group's first
    /// arrival), and total batched rows.
    pub round_batching: RoundBatchStats,
    /// Tokens that completed by renormalizing around a stalled expert
    /// under the demand-miss deadline (interactive degrade path, `0`
    /// unless `--demand-deadline-ms` is set).
    pub degraded_tokens: u64,
    /// Demand fetches re-attempted after a transient failure (each retry
    /// pays an exponential virtual backoff first).
    pub fetch_retries: u64,
    /// Host-tier (RAM-over-disk) counters of the expert store — all zeros
    /// when serving from an all-RAM store (no `--host-cache-mb`).
    pub host_tier: HostTierStats,
    pub sessions: Vec<SessionView>,
}

impl ServeSnapshot {
    /// Merge per-replica snapshots into the process-wide `/metrics` view.
    ///
    /// Sources split into two classes (the multi-replica aggregation
    /// fix): **per-replica** stats — each replica's own scheduler
    /// counters, device `ExpertCache`, transfer pipeline + buffer pool,
    /// speculation, predictor, and round batching — are summed/merged
    /// across replicas. **Shared-store** stats (`host_tier`: ONE
    /// `HostExpertStore` behind every replica) are taken ONCE, from the
    /// replica that has observed the most store accesses — the counters
    /// are process-global and monotonic, so `max(host_accesses)` picks
    /// the freshest read; summing them would count the same accesses once
    /// per replica.
    pub fn merged(snaps: &[ServeSnapshot]) -> ServeSnapshot {
        let Some(first) = snaps.first() else {
            return ServeSnapshot::default();
        };
        let mut out = ServeSnapshot {
            policy: first.policy.clone(),
            capacity_per_layer: first.capacity_per_layer,
            n_layers: first.n_layers,
            ..ServeSnapshot::default()
        };
        for s in snaps {
            out.active_sessions += s.active_sessions;
            out.completed_sessions += s.completed_sessions;
            out.failed_sessions += s.failed_sessions;
            out.prefill_backlog += s.prefill_backlog;
            out.cache.merge(&s.cache);
            out.spec.merge(&s.spec);
            out.cross_session_prefetch_hits += s.cross_session_prefetch_hits;
            out.predictor_active |= s.predictor_active;
            out.predictor.merge(&s.predictor);
            out.predictor_skipped_records += s.predictor_skipped_records;
            for (o, v) in out.prefetch_hits_by_source.iter_mut().zip(s.prefetch_hits_by_source) {
                *o += v;
            }
            out.pipeline.merge(&s.pipeline);
            out.round_batching.merge(&s.round_batching);
            out.degraded_tokens += s.degraded_tokens;
            out.fetch_retries += s.fetch_retries;
            out.sessions.extend(s.sessions.iter().cloned());
        }
        out.host_tier = snaps
            .iter()
            .max_by_key(|s| s.host_tier.host_accesses)
            .map(|s| s.host_tier)
            .unwrap_or_default();
        // the dedup accounting identity holds per replica and is
        // preserved by summation — check it on the merged view
        debug_assert_eq!(
            out.round_batching.batched_rows,
            out.round_batching.distinct_experts + out.round_batching.dedup_joins,
            "dedup identity must survive the merge"
        );
        out
    }
}

struct ActiveSession {
    inner: Session,
    started: Instant,
    /// When the request entered the admission queue — TTFT measures from
    /// here, so it includes queue wait.
    enqueued: Instant,
    /// Simulated clock reading at admission; the span until completion
    /// covers every interleaved token, so per-session sim tokens/s reflects
    /// contention — the serving metric, not the solo-decode one.
    sim_start: f64,
    /// Last round this session advanced ≥ 1 token (admission round for
    /// fresh sessions). The scheduler serves candidates oldest-first by
    /// this stamp — the deficit carry-over under a round budget.
    last_round: u64,
    /// SLO class: interactive candidates outrank batch within the round
    /// budget, and only interactive rows may degrade under the
    /// demand-miss deadline.
    priority: Priority,
    reply: ReplyTo,
    /// Engine failure recorded mid-round; delivered when the session is
    /// retired (the reply path needs the session by value).
    error: Option<GenError>,
    /// Flagged by the disconnect sweep (or the [`Scheduler::cancel`] test
    /// hook): the session does no further engine work and is retired at
    /// this round boundary without delivering a reply.
    cancelled: bool,
    /// Bytes of `decode_bytes(generated())` already streamed to the
    /// client (streamed replies only) — the held-back tail is at most one
    /// incomplete UTF-8 sequence.
    emitted_bytes: usize,
}

/// The active-session set, with a panic-safe reply guarantee: if the
/// scheduler unwinds mid-decode, every still-active session's client gets
/// a 500 through the completion channel (releasing its in-flight slot)
/// instead of a silent EOF. On a normal exit the set is empty and the
/// drop is a no-op.
struct ActiveSet {
    sessions: Vec<ActiveSession>,
    completions: Sender<Completion>,
}

impl Drop for ActiveSet {
    fn drop(&mut self) {
        for s in self.sessions.drain(..) {
            s.reply.deliver(
                Err(GenError {
                    status: 500,
                    message: "engine worker died mid-decode".into(),
                    retry_after: None,
                }),
                &self.completions,
            );
        }
    }
}

/// A round candidate: one decode-phase session, or the single prefill
/// unit (the rotating "one chunk per round" slot).
enum Cand {
    Step(usize),
    PrefillUnit(usize),
}

/// Priority rank for the candidate sort: interactive first. A batch
/// candidate that has waited more than `max_sessions + 1` rounds is
/// promoted to interactive rank — with its older deficit stamp it then
/// wins the tie, bounding batch starvation at roughly `2·max_sessions +
/// 2` rounds (`batch_starvation_is_bounded`).
fn rank(priority: Priority, round: u64, last_round: u64, max_sessions: usize) -> u8 {
    match priority {
        Priority::Interactive => 0,
        Priority::Batch if round.saturating_sub(last_round) > max_sessions as u64 + 1 => 0,
        Priority::Batch => 1,
    }
}

/// Record a session's time-to-first-token, in aggregate and per priority
/// class (the SLO split `/metrics` reports).
fn record_ttft(metrics: &ServeMetrics, s: &ActiveSession) {
    let ns = s.enqueued.elapsed().as_nanos() as u64;
    metrics.ttft.record_ns(ns);
    match s.priority {
        Priority::Interactive => metrics.ttft_interactive.record_ns(ns),
        Priority::Batch => metrics.ttft_batch.record_ns(ns),
    }
}

/// Length of the longest prefix of `bytes` whose lossy UTF-8 decoding is
/// final. A trailing *incomplete* sequence is excluded (later bytes may
/// complete it, changing its decoding); an *invalid* sequence is included
/// (lossy decoding already settled it to U+FFFD).
fn utf8_stable_prefix(bytes: &[u8]) -> usize {
    let mut i = 0;
    loop {
        match std::str::from_utf8(&bytes[i..]) {
            Ok(_) => return bytes.len(),
            Err(e) => match e.error_len() {
                Some(n) => i += e.valid_up_to() + n,
                None => return i + e.valid_up_to(),
            },
        }
    }
}

/// Push session `s`'s newly stable decoded text to its stream connection
/// (no-op for buffered replies) and post a flush event. `final_flush`
/// forces out a held-back incomplete UTF-8 tail as U+FFFD — exactly what
/// `Tokenizer::decode` of the full sequence produces — so the
/// concatenated chunks always equal the buffered `text` byte for byte.
fn stream_progress(
    tk: &Tokenizer,
    s: &mut ActiveSession,
    completions: &Sender<Completion>,
    final_flush: bool,
) {
    let ReplyTo::Stream(conn) = &s.reply else { return };
    let bytes = tk.decode_bytes(s.inner.generated());
    let upto = if final_flush { bytes.len() } else { utf8_stable_prefix(&bytes) };
    if upto > s.emitted_bytes {
        let delta = String::from_utf8_lossy(&bytes[s.emitted_bytes..upto]);
        conn.push_text(&delta);
        s.emitted_bytes = upto;
        let _ = completions.send(Completion::Chunk { conn: Arc::clone(conn) });
    }
}

/// The serve scheduler as a drivable state machine: [`Scheduler::turn`]
/// runs one shed-sweep + admission + round + retirement cycle and reports
/// what the round did, so tests can prove round-level invariants (budget,
/// fairness, TTFT ordering) deterministically — no sleeps, no wall clock.
/// [`run_scheduler`] is the production loop over it.
pub struct Scheduler {
    engine: InferenceEngine,
    /// Which engine replica this scheduler drives (0 of 1 in
    /// single-replica runs) — its slot in the [`ReplicaRouter`] and the
    /// offset of its session-id stride.
    replica_id: usize,
    router: Arc<ReplicaRouter>,
    /// Session ids advance by this much per admission (the router's
    /// replica count): replica r issues r+1, r+1+N, r+1+2N, … so ids are
    /// process-unique without cross-replica coordination. Degenerates to
    /// the historical 1, 2, 3, … at N=1.
    id_stride: u64,
    tk: Tokenizer,
    queue: Arc<AdmissionQueue>,
    cfg: SchedulerConfig,
    max_sessions: usize,
    metrics: Arc<ServeMetrics>,
    snapshot: Arc<Mutex<ServeSnapshot>>,
    // panic-safe: if a turn unwinds, still-active sessions answer 500
    // through the completion channel (see ActiveSet::drop)
    active: ActiveSet,
    recent: VecDeque<SessionView>,
    completed: u64,
    failed_sessions: u64,
    next_id: u64,
    round: u64,
    /// Last round the prefill unit advanced — its deficit stamp against
    /// the decode candidates.
    prefill_last_round: u64,
}

impl Scheduler {
    pub fn new(
        engine: InferenceEngine,
        queue: Arc<AdmissionQueue>,
        completions: Sender<Completion>,
        cfg: SchedulerConfig,
        metrics: Arc<ServeMetrics>,
        snapshot: Arc<Mutex<ServeSnapshot>>,
    ) -> Scheduler {
        Scheduler::for_replica(
            EngineReplica::solo(engine),
            queue,
            completions,
            cfg,
            metrics,
            snapshot,
            ReplicaRouter::new(1),
        )
    }

    /// Build the scheduler for one replica of a multi-replica server: it
    /// claims work through `router` (affinity + least-loaded eligibility,
    /// atomically with the shed sweep) and issues session ids on its own
    /// stride.
    pub fn for_replica(
        replica: EngineReplica,
        queue: Arc<AdmissionQueue>,
        completions: Sender<Completion>,
        cfg: SchedulerConfig,
        metrics: Arc<ServeMetrics>,
        snapshot: Arc<Mutex<ServeSnapshot>>,
        router: Arc<ReplicaRouter>,
    ) -> Scheduler {
        let EngineReplica { id: replica_id, engine } = replica;
        let tk = Tokenizer::new(engine.config().vocab_size);
        {
            let mut snap = snapshot.lock().unwrap();
            snap.policy = engine.cfg.policy.name().to_string();
            snap.capacity_per_layer = engine.cfg.cache_capacity;
            snap.n_layers = engine.config().n_layers;
        }
        Scheduler {
            tk,
            queue,
            max_sessions: cfg.max_sessions.max(1),
            cfg,
            metrics,
            snapshot,
            active: ActiveSet { sessions: Vec::new(), completions },
            recent: VecDeque::new(),
            completed: 0,
            failed_sessions: 0,
            next_id: replica_id as u64 + 1,
            id_stride: router.n() as u64,
            round: 0,
            prefill_last_round: 0,
            replica_id,
            router,
            engine,
        }
    }

    /// Recover the engine after the run (e.g. for
    /// [`InferenceEngine::total_steps`] — the shed-consumes-nothing proof).
    pub fn into_engine(self) -> InferenceEngine {
        let Scheduler { engine, .. } = self;
        engine
    }

    /// One scheduler cycle: shed sweep, admission drain, one budgeted
    /// round, retirement, snapshot publish. Blocks for work when idle.
    /// Returns `None` exactly once — when the queue is closed and drained
    /// and no session remains (the run is over).
    pub fn turn(&mut self) -> Option<RoundReport> {
        // --- shed sweep for turns with no admission capacity: requests
        // past their queue deadline answer 503 + Retry-After without ever
        // becoming sessions. When there IS capacity, shedding happens
        // inside `pop_routed` below, atomically with each claim.
        if self.active.sessions.len() >= self.max_sessions {
            if let Some(t) = self.cfg.queue_timeout {
                for req in self.queue.take_aged(t) {
                    shed(req, &self.active.completions, &self.metrics, self.cfg.retry_after);
                }
            }
        }

        // --- admission: block when idle, drain opportunistically when
        // busy — sessions join mid-flight, between rounds, never barriers.
        // Claim-or-shed is decided under ONE queue-lock acquisition
        // (`pop_routed`), so with N replica schedulers popping
        // concurrently a request is claimed XOR shed, never both — and a
        // claimed request was within its deadline at the claim itself.
        while self.active.sessions.len() < self.max_sessions {
            let block = self.active.sessions.is_empty();
            let (popped, aged) =
                self.queue
                    .pop_routed(self.replica_id, &self.router, block, self.cfg.queue_timeout);
            let had_aged = !aged.is_empty();
            for req in aged {
                shed(req, &self.active.completions, &self.metrics, self.cfg.retry_after);
            }
            let req = match popped {
                Popped::Req(r) => r,
                Popped::Empty => {
                    if block && had_aged {
                        // got control back to shed before re-blocking;
                        // still idle, so wait for work again
                        continue;
                    }
                    break;
                }
                Popped::Closed => {
                    if self.active.sessions.is_empty() {
                        self.router.set_active(self.replica_id, 0);
                        self.publish(); // final state for /metrics
                        return None; // closed, drained, nothing active
                    }
                    break;
                }
            };
            self.metrics
                .queue_wait
                .record_ns(req.enqueued.elapsed().as_nanos() as u64);
            // admission failures answer on the reply path; the responder
            // layer counts them in metrics.errors for socket replies
            if let Some(sess) = admit(
                &self.engine,
                &self.tk,
                self.next_id,
                self.round,
                req,
                &self.active.completions,
            ) {
                self.active.sessions.push(sess);
                self.router.note_admitted(self.replica_id);
                // publish load as it rises so concurrent routing spreads
                // the drain across replicas, not just after the round
                self.router.set_active(self.replica_id, self.active.sessions.len());
                self.next_id += self.id_stride;
            }
        }

        // --- disconnect sweep: a streamed client that hung up cancels its
        // session at this round boundary — it contributes no further rows
        // and `retire` releases everything it held (engine prefetches,
        // tally, in-flight slot) without delivering a reply
        for s in &mut self.active.sessions {
            if !s.cancelled {
                if let ReplyTo::Stream(conn) = &s.reply {
                    if conn.client_gone() {
                        s.cancelled = true;
                    }
                }
            }
        }

        let report = self.round_pass();
        self.retire();
        self.router.set_active(self.replica_id, self.active.sessions.len());
        self.publish();
        Some(report)
    }

    /// Test/bench hook: flag `session` for cancellation exactly as the
    /// disconnect sweep would (same retire path, same accounting).
    /// Returns whether the session was active.
    pub fn cancel(&mut self, session: u64) -> bool {
        match self.active.sessions.iter_mut().find(|s| s.inner.id == session) {
            Some(s) => {
                s.cancelled = true;
                true
            }
            None => false,
        }
    }

    /// Engine state for post-run assertions (pending prefetch tags,
    /// degrade counters) without consuming the scheduler.
    pub fn engine(&self) -> &InferenceEngine {
        &self.engine
    }

    /// One budgeted round: serve candidates oldest-first until the token
    /// budget is spent. Sessions are only retired afterwards, so indices
    /// stay stable for the whole pass.
    fn round_pass(&mut self) -> RoundReport {
        self.round += 1;
        let budget = match self.cfg.round_budget_tokens {
            0 => usize::MAX,
            b => b,
        };
        let chunk = self.cfg.prefill_chunk;
        let mut report = RoundReport {
            round: self.round,
            active: self.active.sessions.len(),
            ..RoundReport::default()
        };

        // candidate list: (priority rank, last-advanced round, tiebreak
        // id, kind) — interactive rank outranks batch (with the
        // anti-starvation promotion in `rank`), then oldest-first within
        // rank. Cancelled sessions contribute no candidates: they are
        // retired at this round boundary. With chunking, prefill-phase
        // sessions are represented by ONE prefill unit selecting the
        // oldest-served of them; its tiebreak of u64::MAX gives decode
        // steps priority on equal stamps.
        let mut cands: Vec<(u8, u64, u64, Cand)> = Vec::new();
        let mut prefill_sel: Option<usize> = None;
        for (i, s) in self.active.sessions.iter().enumerate() {
            if s.cancelled {
                continue;
            }
            if chunk == 0 || s.inner.next_token_is_generated() {
                let r = rank(s.priority, self.round, s.last_round, self.max_sessions);
                cands.push((r, s.last_round, s.inner.id, Cand::Step(i)));
            } else {
                prefill_sel = match prefill_sel {
                    Some(j) => {
                        let old = &self.active.sessions[j];
                        if (s.last_round, s.inner.id) < (old.last_round, old.inner.id) {
                            Some(i)
                        } else {
                            Some(j)
                        }
                    }
                    None => Some(i),
                };
            }
        }
        if let Some(i) = prefill_sel {
            let r = rank(
                self.active.sessions[i].priority,
                self.round,
                self.prefill_last_round,
                self.max_sessions,
            );
            cands.push((r, self.prefill_last_round, u64::MAX, Cand::PrefillUnit(i)));
        }
        cands.sort_by_key(|&(r, last, id, _)| (r, last, id));

        let mut spent = 0usize;
        if self.cfg.round_batching {
            // --- batched dispatch: settle the budget FIRST (selection is
            // identical to the legacy loop on error-free rounds), then run
            // every selected token through ONE engine round so sessions
            // routing to the same (layer, expert) share one transfer +
            // dequant + batched FFN pass (DESIGN.md §8)
            let mut batch_idx: Vec<usize> = Vec::new();
            let mut prefill_grant: Option<(usize, usize)> = None;
            for (_, _, _, cand) in cands {
                match cand {
                    Cand::Step(i) => {
                        if spent >= budget {
                            report.skipped.push(self.active.sessions[i].inner.id);
                            continue;
                        }
                        batch_idx.push(i);
                        spent += 1;
                    }
                    Cand::PrefillUnit(i) => {
                        if spent >= budget {
                            report.skipped.push(self.active.sessions[i].inner.id);
                            continue;
                        }
                        let grant = chunk.min(budget - spent);
                        batch_idx.push(i);
                        prefill_grant = Some((i, grant));
                        spent += grant.min(self.active.sessions[i].inner.prefill_remaining());
                    }
                }
            }
            self.dispatch_round(&batch_idx, prefill_grant, &mut report);
            return report;
        }
        for (_, _, _, cand) in cands {
            match cand {
                Cand::Step(i) => {
                    if spent >= budget {
                        report.skipped.push(self.active.sessions[i].inner.id);
                        continue;
                    }
                    if let Some(adv) = self.advance_one(i) {
                        spent += adv.tokens;
                        if adv.prefill {
                            report.prefill_tokens += adv.tokens;
                        } else {
                            report.decode_tokens += adv.tokens;
                        }
                        report.advanced.push(adv);
                    }
                }
                Cand::PrefillUnit(i) => {
                    if spent >= budget {
                        report.skipped.push(self.active.sessions[i].inner.id);
                        continue;
                    }
                    let grant = chunk.min(budget - spent);
                    if let Some(adv) = self.advance_prefill(i, grant) {
                        spent += adv.tokens;
                        report.prefill_tokens += adv.tokens;
                        report.advanced.push(adv);
                    }
                }
            }
        }
        report
    }

    /// Run one batched round: peek every selected session's next token,
    /// dispatch ONE [`InferenceEngine::step_round`] over all of them, then
    /// commit each outcome through [`Session::apply_step`] with the exact
    /// bookkeeping of [`Scheduler::advance_one`] (token meters, TTFT at
    /// prompt completion, engine errors as deferred 500s).
    ///
    /// `prefill_grant = (i, grant)` marks session `i` as this round's
    /// prefill-chunk unit: only its FIRST prompt token rides the batched
    /// round (token `t+1`'s attention needs token `t`'s KV write, so one
    /// session contributes at most one row per round); the remaining
    /// `grant − 1` tokens run as singleton `step_round` calls right after,
    /// preserving `advance_prefill`'s chunk semantics and its single
    /// aggregated [`Advance`] entry.
    fn dispatch_round(
        &mut self,
        batch_idx: &[usize],
        prefill_grant: Option<(usize, usize)>,
        report: &mut RoundReport,
    ) {
        if batch_idx.is_empty() {
            return;
        }
        let round = self.round;
        let prefill_idx = prefill_grant.map(|(i, _)| i);
        let feeds: Vec<(u32, bool)> = batch_idx
            .iter()
            .map(|&i| self.active.sessions[i].inner.peek_next())
            .collect();
        // disjoint &mut borrows of the chosen sessions (candidate indices
        // are distinct by construction): take each out of a slot vector so
        // every RoundWork can hold `&mut kv` simultaneously
        let mut slots: Vec<Option<&mut ActiveSession>> =
            self.active.sessions.iter_mut().map(Some).collect();
        let mut chosen: Vec<&mut ActiveSession> = batch_idx
            .iter()
            .map(|&i| slots[i].take().expect("distinct candidate indices"))
            .collect();
        let mut work: Vec<RoundWork> = chosen
            .iter_mut()
            .zip(&feeds)
            .map(|(s, &(tok, gen))| RoundWork {
                session: s.inner.id,
                tok,
                pos: s.inner.pos,
                prefill: !gen,
                // only interactive rows may degrade under the demand-miss
                // deadline; a batch row in an expert group pins the fetch
                degradable: s.priority == Priority::Interactive,
                kv: &mut s.inner.kv,
            })
            .collect();
        let results = self.engine.step_round(&mut work);
        drop(work);
        // the prefill unit's first-token advance is reported together with
        // its continuation tokens as one aggregated chunk entry below
        let mut chunk_fed = 0usize;
        for (((&i, s), &(tok, was_generated)), outcome) in batch_idx
            .iter()
            .zip(chosen.iter_mut())
            .zip(&feeds)
            .zip(results.outcomes)
        {
            s.last_round = round;
            match outcome {
                Ok(logits) => {
                    s.inner.apply_step(tok, was_generated, &logits);
                    if was_generated {
                        self.metrics.tokens_generated.fetch_add(1, Ordering::Relaxed);
                        stream_progress(&self.tk, s, &self.active.completions, false);
                        report.decode_tokens += 1;
                        report.advanced.push(Advance {
                            session: s.inner.id,
                            tokens: 1,
                            prefill: false,
                        });
                    } else {
                        self.metrics.tokens_prefill.fetch_add(1, Ordering::Relaxed);
                        if s.inner.next_token_is_generated() {
                            record_ttft(&self.metrics, s);
                        }
                        if Some(i) == prefill_idx {
                            chunk_fed = 1;
                        } else {
                            report.prefill_tokens += 1;
                            report.advanced.push(Advance {
                                session: s.inner.id,
                                tokens: 1,
                                prefill: true,
                            });
                        }
                    }
                }
                Err(e) => {
                    s.error = Some(GenError {
                        status: 500,
                        message: format!("{e:#}"),
                        retry_after: None,
                    });
                }
            }
        }
        drop(chosen);
        drop(slots);
        if let Some((i, grant)) = prefill_grant {
            let sid = self.active.sessions[i].inner.id;
            while chunk_fed > 0 && chunk_fed < grant {
                let s = &mut self.active.sessions[i];
                if s.error.is_some() || s.inner.done || !s.inner.in_prefill() {
                    break;
                }
                let (tok, _gen) = s.inner.peek_next();
                let degradable = s.priority == Priority::Interactive;
                let mut work = [RoundWork {
                    session: sid,
                    tok,
                    pos: s.inner.pos,
                    prefill: true,
                    degradable,
                    kv: &mut s.inner.kv,
                }];
                let mut results = self.engine.step_round(&mut work);
                drop(work);
                match results.outcomes.pop().expect("one outcome per work item") {
                    Ok(logits) => {
                        let s = &mut self.active.sessions[i];
                        s.inner.apply_step(tok, false, &logits);
                        chunk_fed += 1;
                        self.metrics.tokens_prefill.fetch_add(1, Ordering::Relaxed);
                        if s.inner.next_token_is_generated() {
                            record_ttft(&self.metrics, s);
                        }
                    }
                    Err(e) => {
                        self.active.sessions[i].error = Some(GenError {
                            status: 500,
                            message: format!("{e:#}"),
                            retry_after: None,
                        });
                        break;
                    }
                }
            }
            self.prefill_last_round = round;
            if chunk_fed > 0 {
                report.prefill_tokens += chunk_fed;
                report.advanced.push(Advance { session: sid, tokens: chunk_fed, prefill: true });
            }
        }
    }

    /// Advance session `i` by one token (prompt or generated). Returns
    /// what happened for the round report; `None` tokens advanced on an
    /// engine error (the session is retired with a 500 afterwards).
    fn advance_one(&mut self, i: usize) -> Option<Advance> {
        let round = self.round;
        let s = &mut self.active.sessions[i];
        let was_generated = s.inner.next_token_is_generated();
        let mut ev = TokenEvents::default();
        match s.inner.step_once(&mut self.engine, &mut ev) {
            Ok(_done) => {
                s.last_round = round;
                if was_generated {
                    self.metrics.tokens_generated.fetch_add(1, Ordering::Relaxed);
                    stream_progress(&self.tk, s, &self.active.completions, false);
                } else {
                    self.metrics.tokens_prefill.fetch_add(1, Ordering::Relaxed);
                    if s.inner.next_token_is_generated() {
                        // prompt fully fed: the first output token was
                        // sampled by this very step — that's TTFT
                        record_ttft(&self.metrics, s);
                    }
                }
                Some(Advance { session: s.inner.id, tokens: 1, prefill: !was_generated })
            }
            Err(e) => {
                // engine-side failure: 500, delivered at retirement
                s.last_round = round;
                s.error = Some(GenError {
                    status: 500,
                    message: format!("{e:#}"),
                    retry_after: None,
                });
                None
            }
        }
    }

    /// Advance session `i` by one prefill chunk of up to `grant` prompt
    /// tokens (a budget-truncated grant leaves the session's cursor in
    /// place — the shortfall carries over to its next rotation slot).
    fn advance_prefill(&mut self, i: usize, grant: usize) -> Option<Advance> {
        let round = self.round;
        let s = &mut self.active.sessions[i];
        let before = s.inner.pos;
        let mut ev = TokenEvents::default();
        let err = s.inner.prefill_chunk(&mut self.engine, grant, &mut ev).err();
        let advanced = s.inner.pos - before;
        s.last_round = round;
        self.prefill_last_round = round;
        if advanced > 0 {
            self.metrics
                .tokens_prefill
                .fetch_add(advanced as u64, Ordering::Relaxed);
        }
        if err.is_none() && s.inner.next_token_is_generated() {
            record_ttft(&self.metrics, s);
        }
        if let Some(e) = err {
            s.error = Some(GenError {
                status: 500,
                message: format!("{e:#}"),
                retry_after: None,
            });
        }
        if advanced > 0 {
            Some(Advance { session: s.inner.id, tokens: advanced, prefill: true })
        } else {
            None
        }
    }

    /// Retire finished, failed, and cancelled sessions: deliver replies
    /// (cancelled sessions get none — their client is gone), release
    /// engine-side and admission-side state, fold tallies into the recent
    /// ring.
    fn retire(&mut self) {
        let mut finished: Vec<ActiveSession> = Vec::new();
        let mut i = 0;
        while i < self.active.sessions.len() {
            let s = &self.active.sessions[i];
            if s.error.is_some() || s.inner.done || s.cancelled {
                finished.push(self.active.sessions.swap_remove(i));
            } else {
                i += 1;
            }
        }
        for mut s in finished {
            if !s.cancelled && s.error.is_none() {
                // flush a held-back incomplete UTF-8 tail so the streamed
                // bytes match the buffered decode exactly
                stream_progress(&self.tk, &mut s, &self.active.completions, true);
            }
            let ActiveSession { inner, started, sim_start, reply, error, cancelled, .. } = s;
            // tally first: cancel_session drops the engine's records
            let tally = self.engine.take_session_tally(inner.id);
            let generated = inner.generated().len();
            if cancelled {
                // released: queued prefetches cancelled, speculative state
                // dropped, in-flight slot freed. No reply — for a streamed
                // session the finish transition below is exactly-once
                // against any still-queued responder flush.
                self.engine.cancel_session(inner.id);
                self.metrics.cancelled_sessions.fetch_add(1, Ordering::Relaxed);
                match reply {
                    ReplyTo::Stream(conn) => {
                        crate::serve::finish_stream(&conn, &self.metrics);
                    }
                    _ => release_inflight(&self.metrics),
                }
                self.recent.push_back(SessionView {
                    id: inner.id,
                    state: "cancelled",
                    n_prompt: inner.n_prompt,
                    generated,
                    target: inner.target_new,
                    tally,
                });
                while self.recent.len() > RECENT_SESSIONS {
                    self.recent.pop_front();
                }
                continue;
            }
            let succeeded = error.is_none() && inner.done;
            let result = if succeeded {
                let sim_span = self.engine.sim_now() - sim_start;
                self.completed += 1;
                Ok(GenResponse {
                    text: self.tk.decode(inner.generated()),
                    n_prompt: inner.n_prompt,
                    n_generated: generated,
                    wall_s: started.elapsed().as_secs_f64(),
                    sim_tokens_per_s: if sim_span > 0.0 {
                        (inner.n_prompt + generated) as f64 / sim_span
                    } else {
                        0.0
                    },
                    cache_hit_rate: tally.hit_rate(),
                    session_id: inner.id,
                    session_hits: tally.hits,
                    session_misses: tally.misses,
                    spec_precision: tally.spec_pr.precision(),
                    spec_recall: tally.spec_pr.recall(),
                })
            } else {
                self.failed_sessions += 1;
                Err(error.unwrap_or_else(|| GenError {
                    status: 500,
                    message: "session aborted".into(),
                    retry_after: None,
                }))
            };
            reply.deliver(result, &self.active.completions);
            self.recent.push_back(SessionView {
                id: inner.id,
                state: if succeeded { "done" } else { "failed" },
                n_prompt: inner.n_prompt,
                generated,
                target: inner.target_new,
                tally,
            });
            while self.recent.len() > RECENT_SESSIONS {
                self.recent.pop_front();
            }
        }
    }

    fn publish(&self) {
        let mut views: Vec<SessionView> = self
            .active
            .sessions
            .iter()
            .map(|s| SessionView {
                id: s.inner.id,
                state: "active",
                n_prompt: s.inner.n_prompt,
                generated: s.inner.generated().len(),
                target: s.inner.target_new,
                tally: self.engine.session_tally(s.inner.id),
            })
            .collect();
        views.extend(self.recent.iter().cloned());
        let backlog: usize = self
            .active
            .sessions
            .iter()
            .map(|s| s.inner.n_prompt.saturating_sub(s.inner.pos))
            .sum();
        let mut snap = self.snapshot.lock().unwrap();
        snap.active_sessions = self.active.sessions.len();
        snap.completed_sessions = self.completed;
        snap.failed_sessions = self.failed_sessions;
        snap.prefill_backlog = backlog;
        snap.cache = self.engine.cache_stats();
        snap.spec = self.engine.spec_precision_recall();
        snap.cross_session_prefetch_hits = self.engine.cross_session_prefetch_hits();
        snap.predictor_active = self.engine.predictor_active();
        snap.predictor = self.engine.predictor_precision_recall();
        snap.predictor_skipped_records = self.engine.predictor_skipped_records();
        let mut by_source = [0u64; 3];
        for (i, (_, hits)) in self.engine.prefetch_hits_by_source().iter().enumerate() {
            by_source[i] = *hits;
        }
        snap.prefetch_hits_by_source = by_source;
        snap.pipeline = self.engine.pipeline_stats();
        snap.round_batching = self.engine.round_batch_stats();
        snap.degraded_tokens = self.engine.degraded_tokens();
        snap.fetch_retries = self.engine.fetch_retries_performed();
        snap.host_tier = self.engine.host_tier_stats();
        snap.sessions = views;
    }
}

/// Run the scheduler until the admission queue closes and drains and no
/// sessions remain. Owns the engine for its entire lifetime and returns it
/// so callers can inspect post-run engine state (e.g.
/// [`InferenceEngine::total_steps`] — the shed-consumes-nothing proof).
/// Single-replica wrapper over [`run_replica`].
pub fn run_scheduler(
    engine: InferenceEngine,
    queue: Arc<AdmissionQueue>,
    completions: Sender<Completion>,
    cfg: SchedulerConfig,
    metrics: Arc<ServeMetrics>,
    snapshot: Arc<Mutex<ServeSnapshot>>,
) -> InferenceEngine {
    run_replica(
        EngineReplica::solo(engine),
        queue,
        completions,
        cfg,
        metrics,
        snapshot,
        ReplicaRouter::new(1),
    )
}

/// Run one replica's scheduler loop of a multi-replica server: claims
/// work from the shared admission queue through `router` until the queue
/// closes and drains and no session remains. Returns the replica's engine
/// for post-run inspection (its `total_steps` sum across replicas is the
/// exactly-once proof at N > 1).
pub fn run_replica(
    replica: EngineReplica,
    queue: Arc<AdmissionQueue>,
    completions: Sender<Completion>,
    cfg: SchedulerConfig,
    metrics: Arc<ServeMetrics>,
    snapshot: Arc<Mutex<ServeSnapshot>>,
    router: Arc<ReplicaRouter>,
) -> InferenceEngine {
    let mut sched =
        Scheduler::for_replica(replica, queue, completions, cfg, metrics, snapshot, router);
    while sched.turn().is_some() {}
    sched.into_engine()
}

/// Refuse one aged request: 503 + `Retry-After` (the configured
/// `retry_after` seconds — the same value every other 503 path advertises),
/// `shed_total` incremented, queue wait recorded — and, by construction,
/// zero engine steps consumed.
fn shed(req: GenRequest, completions: &Sender<Completion>, metrics: &ServeMetrics, retry_after: u64) {
    metrics
        .queue_wait
        .record_ns(req.enqueued.elapsed().as_nanos() as u64);
    metrics.shed_total.fetch_add(1, Ordering::Relaxed);
    req.reply.deliver(
        Err(GenError {
            status: 503,
            message: "shed: queued past --queue-timeout-ms; retry later".into(),
            retry_after: Some(retry_after),
        }),
        completions,
    );
}

/// Validate and set up one request as an active session. On failure the
/// error is delivered on the reply path and `None` returned: length
/// violations are the client's fault (400), anything else in session
/// construction is the server's (500).
fn admit(
    engine: &InferenceEngine,
    tk: &Tokenizer,
    id: u64,
    round: u64,
    req: GenRequest,
    completions: &Sender<Completion>,
) -> Option<ActiveSession> {
    let prompt = tk.encode(&req.prompt);
    let max = engine.config().max_seq;
    if prompt.len() + req.n_tokens > max {
        req.reply.deliver(
            Err(GenError {
                status: 400,
                message: format!(
                    "prompt {} + n_tokens {} exceeds max_seq {max}",
                    prompt.len(),
                    req.n_tokens
                ),
                retry_after: None,
            }),
            completions,
        );
        return None;
    }
    let sampler = Sampler::new(req.sampling, id);
    let inner = match Session::new(id, engine, &prompt, req.n_tokens, sampler) {
        Ok(s) => s,
        Err(e) => {
            req.reply.deliver(
                Err(GenError { status: 500, message: format!("{e:#}"), retry_after: None }),
                completions,
            );
            return None;
        }
    };
    Some(ActiveSession {
        inner,
        started: Instant::now(),
        enqueued: req.enqueued,
        sim_start: engine.sim_now(),
        last_round: round,
        priority: req.priority,
        reply: req.reply,
        error: None,
        cancelled: false,
        emitted_bytes: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::PolicyKind;
    use crate::engine::EngineConfig;
    use crate::model::sampler::Sampling;
    use crate::model::weights::generate_weights;
    use crate::model::ModelConfig;
    use crate::offload::store::HostExpertStore;
    use crate::quant::Scheme;
    use crate::runtime::native::NativeBackend;
    use crate::serve::{GenResult, ReplyTo};
    use std::sync::mpsc::{channel, Receiver};

    /// Byte-tokenizer-compatible tiny config (vocab must hold 256 bytes +
    /// specials; TINY's vocab of 64 is for raw-token tests only).
    pub(crate) fn serve_test_config() -> ModelConfig {
        ModelConfig {
            vocab_size: 320,
            max_seq: 96,
            ..ModelConfig::TINY
        }
    }

    pub(crate) fn test_engine(spec: bool) -> InferenceEngine {
        test_engine_workers(spec, 0)
    }

    pub(crate) fn test_engine_workers(spec: bool, transfer_workers: usize) -> InferenceEngine {
        let weights = Arc::new(generate_weights(serve_test_config(), 42));
        let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32).unwrap());
        let mut cfg = EngineConfig::serving(4, PolicyKind::Lfu, spec);
        cfg.transfer_workers = transfer_workers;
        InferenceEngine::new(Box::new(NativeBackend::new(weights)), store, cfg)
    }

    fn request(prompt: &str, n: usize) -> (GenRequest, Receiver<GenResult>) {
        let (tx, rx) = channel();
        (
            GenRequest {
                prompt: prompt.to_string(),
                n_tokens: n,
                sampling: Sampling::Greedy,
                priority: Priority::Interactive,
                affinity: None,
                reply: ReplyTo::Channel(tx),
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    fn push(queue: &AdmissionQueue, prompt: &str, n: usize) -> Receiver<GenResult> {
        let (req, rx) = request(prompt, n);
        assert!(queue.try_push(req).is_ok(), "test queue accepts");
        rx
    }

    fn test_queue(
        depth: usize,
    ) -> (Arc<AdmissionQueue>, Arc<ServeMetrics>) {
        let metrics = Arc::new(ServeMetrics::default());
        (AdmissionQueue::new(depth, Arc::clone(&metrics)), metrics)
    }

    #[test]
    fn scheduler_completes_concurrent_sessions() {
        let engine = test_engine(true);
        let (queue, metrics) = test_queue(16);
        let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
        let (completions, _completion_rx) = channel();

        let mut resp_rxs = Vec::new();
        for i in 0..5 {
            resp_rxs.push(push(&queue, &format!("prompt number {i}"), 6));
        }
        queue.close();
        let engine = run_scheduler(
            engine,
            queue,
            completions,
            SchedulerConfig { max_sessions: 4, ..SchedulerConfig::default() },
            Arc::clone(&metrics),
            Arc::clone(&snapshot),
        );

        let mut ids = Vec::new();
        let mut stepped = 0u64;
        let mut prompt_toks = 0u64;
        for rx in resp_rxs {
            let resp = rx.recv().unwrap().expect("generation ok");
            assert_eq!(resp.n_generated, 6);
            assert!(!ids.contains(&resp.session_id), "duplicate session id");
            ids.push(resp.session_id);
            stepped += (resp.n_prompt + resp.n_generated) as u64;
            prompt_toks += resp.n_prompt as u64;
        }
        // admitted sessions account for every engine step, split exactly
        // into prefill (prompt) and decode work
        assert_eq!(engine.total_steps(), stepped);
        assert_eq!(engine.prefill_steps(), prompt_toks);
        assert_eq!(engine.decode_steps(), stepped - prompt_toks);
        let snap = snapshot.lock().unwrap();
        assert_eq!(snap.completed_sessions, 5);
        assert_eq!(snap.failed_sessions, 0);
        assert_eq!(snap.active_sessions, 0);
        assert_eq!(snap.prefill_backlog, 0, "no prompt work left behind");
        // the recent ring keeps every finished session visible
        assert_eq!(snap.sessions.len(), 5);
        assert!(snap.sessions.iter().all(|s| s.state == "done"));
        // one shared cache served them all
        let part: u64 = snap.sessions.iter().map(|s| s.tally.hits + s.tally.misses).sum();
        assert_eq!(part, snap.cache.hits + snap.cache.misses);
        assert_eq!(metrics.tokens_generated.load(Ordering::Relaxed), 5 * 6);
        assert_eq!(metrics.tokens_prefill.load(Ordering::Relaxed), prompt_toks);
        // every session's first token has a TTFT sample
        assert_eq!(metrics.ttft.count(), 5);
        // every admitted request's queue wait was recorded
        assert_eq!(metrics.queue_wait.count(), 5);
        assert_eq!(metrics.queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn scheduler_with_pipeline_matches_sync_outputs() {
        // the async transfer pipeline must be invisible in the responses:
        // same requests, same texts, with the pipeline counters live
        let run = |workers: usize| {
            let engine = test_engine_workers(true, workers);
            let (queue, metrics) = test_queue(8);
            let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
            let (completions, _completion_rx) = channel();
            let mut resp_rxs = Vec::new();
            for i in 0..3 {
                resp_rxs.push(push(&queue, &format!("pipeline probe {i}"), 5));
            }
            queue.close();
            run_scheduler(
                engine,
                queue,
                completions,
                SchedulerConfig { max_sessions: 3, ..SchedulerConfig::default() },
                metrics,
                Arc::clone(&snapshot),
            );
            let texts: Vec<String> = resp_rxs
                .into_iter()
                .map(|r| r.recv().unwrap().expect("generation ok").text)
                .collect();
            let snap = snapshot.lock().unwrap();
            (texts, snap.pipeline)
        };
        let (sync_texts, sync_pipe) = run(0);
        let (pipe_texts, pipe) = run(2);
        assert_eq!(sync_texts, pipe_texts, "pipeline changed outputs");
        assert_eq!(sync_pipe.workers, 0);
        assert_eq!(pipe.workers, 2);
        assert!(pipe.completed > 0, "pipeline never delivered a transfer");
    }

    #[test]
    fn scheduler_rejects_overlong_requests_and_continues() {
        let engine = test_engine(false);
        let (queue, metrics) = test_queue(8);
        let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
        let (completions, _completion_rx) = channel();

        let bad_rx = push(&queue, "way too long", 4096);
        let good_rx = push(&queue, "ok", 3);
        queue.close();
        run_scheduler(
            engine,
            queue,
            completions,
            SchedulerConfig::default(),
            metrics,
            snapshot,
        );

        let err = bad_rx.recv().unwrap().unwrap_err();
        assert_eq!(err.status, 400, "length violations are the client's fault");
        assert!(err.message.contains("max_seq"));
        assert_eq!(good_rx.recv().unwrap().unwrap().n_generated, 3);
    }

    #[test]
    fn scheduler_sheds_aged_requests_before_decode() {
        // a request that outwaited the queue timeout gets 503 +
        // Retry-After and consumes ZERO engine steps; fresh requests are
        // served normally
        let backdated = Instant::now().checked_sub(Duration::from_secs(120));
        let Some(backdated) = backdated else {
            return; // machine uptime too short to backdate; skip
        };
        let engine = test_engine(false);
        let (queue, metrics) = test_queue(8);
        let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
        let (completions, _completion_rx) = channel();

        let (mut aged, aged_rx) = request("stale request", 4);
        aged.enqueued = backdated;
        assert!(queue.try_push(aged).is_ok());
        let fresh_rx = push(&queue, "fresh request", 4);
        queue.close();
        let engine = run_scheduler(
            engine,
            queue,
            completions,
            SchedulerConfig {
                max_sessions: 2,
                queue_timeout: Some(Duration::from_secs(60)),
                ..SchedulerConfig::default()
            },
            Arc::clone(&metrics),
            snapshot,
        );

        let err = aged_rx.recv().unwrap().unwrap_err();
        assert_eq!(err.status, 503);
        assert_eq!(err.retry_after, Some(RETRY_AFTER_S), "sheds advertise Retry-After");
        assert!(err.message.contains("shed"), "{}", err.message);
        let ok = fresh_rx.recv().unwrap().expect("fresh request served");
        assert_eq!(ok.n_generated, 4);
        // the shed request consumed nothing on the engine
        assert_eq!(engine.total_steps(), (ok.n_prompt + ok.n_generated) as u64);
        assert_eq!(metrics.shed_total.load(Ordering::Relaxed), 1);
        // both dequeues recorded a queue wait
        assert_eq!(metrics.queue_wait.count(), 2);
    }

    #[test]
    fn sheds_advertise_the_configured_retry_after() {
        // a non-default --retry-after-s must flow through to the shed 503
        let backdated = Instant::now().checked_sub(Duration::from_secs(120));
        let Some(backdated) = backdated else {
            return; // machine uptime too short to backdate; skip
        };
        let engine = test_engine(false);
        let (queue, metrics) = test_queue(8);
        let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
        let (completions, _completion_rx) = channel();

        let (mut aged, aged_rx) = request("stale request", 4);
        aged.enqueued = backdated;
        assert!(queue.try_push(aged).is_ok());
        queue.close();
        run_scheduler(
            engine,
            queue,
            completions,
            SchedulerConfig {
                queue_timeout: Some(Duration::from_secs(60)),
                retry_after: 7,
                ..SchedulerConfig::default()
            },
            metrics,
            snapshot,
        );
        let err = aged_rx.recv().unwrap().unwrap_err();
        assert_eq!(err.status, 503);
        assert_eq!(err.retry_after, Some(7), "configured Retry-After ignored by shed");
    }

    #[test]
    fn interleaved_outputs_match_solo_decode() {
        // scheduling must be semantically transparent: a session decoded
        // alongside three others yields the same tokens as decoding alone
        let solo_out = {
            let mut engine = test_engine(false);
            let tk = Tokenizer::new(engine.config().vocab_size);
            let prompt = tk.encode("determinism check");
            // scheduler seeds the sampler with the session id; solo run is
            // admitted first, so it gets id 1
            let mut sampler = Sampler::new(Sampling::Greedy, 1);
            let out = engine.generate(&prompt, 5, &mut sampler).unwrap();
            out.generated
        };

        let engine = test_engine(false);
        let (queue, metrics) = test_queue(8);
        let (completions, _completion_rx) = channel();
        let probe_rx = push(&queue, "determinism check", 5);
        let mut others = Vec::new();
        for i in 0..3 {
            others.push(push(&queue, &format!("background load {i}"), 5));
        }
        queue.close();
        run_scheduler(
            engine,
            queue,
            completions,
            SchedulerConfig { max_sessions: 4, ..SchedulerConfig::default() },
            metrics,
            Arc::new(Mutex::new(ServeSnapshot::default())),
        );

        let tk = Tokenizer::new(serve_test_config().vocab_size);
        let resp = probe_rx.recv().unwrap().unwrap();
        assert_eq!(resp.text, tk.decode(&solo_out), "shared cache changed outputs");
        for orx in others {
            assert!(orx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn chunked_prefill_outputs_bit_identical_to_unchunked() {
        // chunking changes scheduling only: same requests, same texts,
        // same engine step totals as the legacy one-token-per-round path
        let run = |chunk: usize, budget: usize| {
            let engine = test_engine(true);
            let (queue, metrics) = test_queue(8);
            let (completions, _completion_rx) = channel();
            let mut rxs = Vec::new();
            rxs.push(push(&queue, &"L".repeat(40), 4)); // long prompt
            for i in 0..3 {
                rxs.push(push(&queue, &format!("short {i}"), 4));
            }
            queue.close();
            let engine = run_scheduler(
                engine,
                queue,
                completions,
                SchedulerConfig {
                    max_sessions: 4,
                    prefill_chunk: chunk,
                    round_budget_tokens: budget,
                    ..SchedulerConfig::default()
                },
                metrics,
                Arc::new(Mutex::new(ServeSnapshot::default())),
            );
            let texts: Vec<String> = rxs
                .into_iter()
                .map(|r| r.recv().unwrap().expect("generation ok").text)
                .collect();
            (texts, engine.total_steps(), engine.prefill_steps())
        };
        let (legacy, legacy_steps, legacy_prefill) = run(0, 0);
        for (chunk, budget) in [(3usize, 0usize), (8, 6), (1, 2)] {
            let (texts, steps, prefill) = run(chunk, budget);
            assert_eq!(texts, legacy, "chunk {chunk}/budget {budget} changed outputs");
            assert_eq!(steps, legacy_steps, "chunk {chunk}/budget {budget} changed step count");
            assert_eq!(prefill, legacy_prefill, "prefill step split drifted");
        }
    }

    #[test]
    fn round_batching_outputs_bit_identical_to_per_session() {
        // the tentpole invariant at the scheduler level: batched rounds
        // are a dispatch optimization only — same requests, same texts,
        // same engine step totals as the per-session step_once loop,
        // across chunking and budget configurations
        let run = |on: bool, chunk: usize, budget: usize| {
            let engine = test_engine(true);
            let (queue, metrics) = test_queue(8);
            let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
            let (completions, _completion_rx) = channel();
            let mut rxs = Vec::new();
            rxs.push(push(&queue, &"L".repeat(40), 4)); // long prompt
            for i in 0..3 {
                rxs.push(push(&queue, &format!("short {i}"), 4));
            }
            queue.close();
            let engine = run_scheduler(
                engine,
                queue,
                completions,
                SchedulerConfig {
                    max_sessions: 4,
                    prefill_chunk: chunk,
                    round_budget_tokens: budget,
                    round_batching: on,
                    ..SchedulerConfig::default()
                },
                metrics,
                Arc::clone(&snapshot),
            );
            let texts: Vec<String> = rxs
                .into_iter()
                .map(|r| r.recv().unwrap().expect("generation ok").text)
                .collect();
            let stats = snapshot.lock().unwrap().round_batching;
            (texts, engine.total_steps(), engine.prefill_steps(), stats)
        };
        for (chunk, budget) in [(0usize, 0usize), (3, 0), (8, 6)] {
            let (legacy, legacy_steps, legacy_prefill, off_stats) = run(false, chunk, budget);
            let (batched, steps, prefill, on_stats) = run(true, chunk, budget);
            assert_eq!(batched, legacy, "chunk {chunk}/budget {budget}: outputs diverged");
            assert_eq!(steps, legacy_steps, "chunk {chunk}/budget {budget}: step count diverged");
            assert_eq!(prefill, legacy_prefill, "prefill step split drifted");
            // the off path never touches the round engine...
            assert_eq!(off_stats.rounds, 0);
            assert_eq!(off_stats.batched_rows, 0);
            // ...the on path runs everything through it, preserving the
            // dedup identity
            assert!(on_stats.rounds > 0, "round path never dispatched");
            assert_eq!(
                on_stats.batched_rows - on_stats.distinct_experts,
                on_stats.dedup_joins
            );
        }
    }

    #[test]
    fn round_batching_dedups_identical_sessions() {
        // three sessions with the SAME prompt under greedy sampling decode
        // identical token streams in lockstep, so every round routes all
        // three onto the same experts — dedup joins are guaranteed
        let engine = test_engine(false);
        let (queue, metrics) = test_queue(8);
        let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
        let (completions, _completion_rx) = channel();
        let rxs: Vec<_> = (0..3).map(|_| push(&queue, "same text", 5)).collect();
        queue.close();
        run_scheduler(
            engine,
            queue,
            completions,
            SchedulerConfig { max_sessions: 3, ..SchedulerConfig::default() },
            metrics,
            Arc::clone(&snapshot),
        );
        let texts: Vec<String> = rxs
            .into_iter()
            .map(|r| r.recv().unwrap().expect("generation ok").text)
            .collect();
        assert!(texts.windows(2).all(|w| w[0] == w[1]), "greedy twins diverged");
        let snap = snapshot.lock().unwrap();
        let stats = snap.round_batching;
        assert!(stats.dedup_joins > 0, "identical lockstep sessions never deduped");
        assert_eq!(stats.batched_rows - stats.distinct_experts, stats.dedup_joins);
        // first-arrival-pays attribution keeps the per-session tallies an
        // exact partition of the shared cache totals
        let part: u64 = snap.sessions.iter().map(|s| s.tally.hits + s.tally.misses).sum();
        assert_eq!(part, snap.cache.hits + snap.cache.misses);
    }

    /// Drive `Scheduler::turn` directly — the deterministic harness: no
    /// sleeps, no wall clock, round-level assertions.
    fn driven_scheduler(
        cfg: SchedulerConfig,
        requests: &[(&str, usize)],
    ) -> (Scheduler, Vec<Receiver<GenResult>>) {
        let engine = test_engine(false);
        let (queue, metrics) = test_queue(requests.len().max(1));
        // channel replies deliver inline; the completion channel is only
        // exercised by socket replies, so the receiver can drop here
        let (completions, _completion_rx) = channel();
        let rxs: Vec<_> = requests.iter().map(|(p, n)| push(&queue, p, *n)).collect();
        queue.close();
        let sched = Scheduler::new(
            engine,
            queue,
            completions,
            cfg,
            metrics,
            Arc::new(Mutex::new(ServeSnapshot::default())),
        );
        (sched, rxs)
    }

    #[test]
    fn round_budget_caps_round_work_with_deficit_carryover() {
        let (mut sched, rxs) = driven_scheduler(
            SchedulerConfig {
                max_sessions: 4,
                prefill_chunk: 4,
                round_budget_tokens: 3,
                ..SchedulerConfig::default()
            },
            &[("aaaaaaaaaaaaaaaaaaaa", 3), ("bb", 3), ("cc", 3), ("dd", 3)],
        );
        let mut reports = Vec::new();
        while let Some(r) = sched.turn() {
            assert!(
                r.decode_tokens + r.prefill_tokens <= 3,
                "round {} advanced {} tokens past the budget",
                r.round,
                r.decode_tokens + r.prefill_tokens
            );
            // at most one prefill chunk per round, never above chunk size
            let prefill_entries: Vec<_> =
                r.advanced.iter().filter(|a| a.prefill).collect();
            assert!(prefill_entries.len() <= 1, "more than one prefill chunk in a round");
            for a in &prefill_entries {
                assert!(a.tokens <= 4, "chunk of {} exceeds prefill_chunk", a.tokens);
            }
            // decode steps are one token each
            assert!(r.advanced.iter().filter(|a| !a.prefill).all(|a| a.tokens == 1));
            reports.push(r);
        }
        // budget 3 < the work of a full round: some round must have skipped
        // a candidate, and every skipped candidate advanced soon after
        assert!(reports.iter().any(|r| !r.skipped.is_empty()), "budget never saturated");
        for (k, r) in reports.iter().enumerate() {
            for &id in &r.skipped {
                let within = reports[k + 1..]
                    .iter()
                    .take(5) // candidates ≤ 5 (4 sessions + prefill unit)
                    .any(|later| later.advanced.iter().any(|a| a.session == id));
                assert!(within, "session {id} skipped in round {} starved", r.round);
            }
        }
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().expect("served").n_generated, 3);
        }
    }

    /// The discriminating TTFT test: the same mixed workload runs
    /// unchunked and chunked, counting rounds until the LONG session's
    /// first token. Unchunked, a prompt advances one token per round, so
    /// a p-token prompt costs p rounds of TTFT; chunked, it costs about
    /// ⌈p/k⌉ rotation slots. The comparison fails if chunking is ever
    /// silently disabled (no multi-token chunk, no round-count win) —
    /// unlike the "shorts don't wait" property, which BOTH disciplines
    /// satisfy (one-token-per-session rounds never head-of-line blocked
    /// short sessions; that invariant is asserted for both here).
    #[test]
    fn chunked_prefill_cuts_long_prompt_ttft_rounds() {
        let run = |chunk: usize| {
            let long_prompt = "L".repeat(60);
            let (mut sched, rxs) = driven_scheduler(
                SchedulerConfig {
                    max_sessions: 4,
                    prefill_chunk: chunk,
                    ..SchedulerConfig::default()
                },
                &[(long_prompt.as_str(), 2), ("s0", 2), ("s1", 2), ("s2", 2)],
            );
            let metrics = Arc::clone(&sched.metrics);
            let mut long_ttft_round = None;
            let mut multi_token_chunk = false;
            let mut shorts_before_long = false;
            while let Some(r) = sched.turn() {
                multi_token_chunk |= r.advanced.iter().any(|a| a.prefill && a.tokens > 1);
                let long_in_prefill = sched
                    .active
                    .sessions
                    .iter()
                    .any(|s| s.inner.n_prompt > 50 && s.inner.in_prefill());
                // ttft counts sessions whose prompt is fully fed (first
                // output token sampled)
                if long_in_prefill && metrics.ttft.count() >= 3 {
                    shorts_before_long = true;
                }
                if long_ttft_round.is_none() && metrics.ttft.count() == 4 {
                    long_ttft_round = Some(r.round); // the long one crossed
                }
            }
            for rx in rxs {
                assert_eq!(rx.recv().unwrap().expect("served").n_generated, 2);
            }
            (
                long_ttft_round.expect("long session never reached its first token"),
                multi_token_chunk,
                shorts_before_long,
            )
        };
        let (unchunked_rounds, unchunked_multi, unchunked_shorts_first) = run(0);
        let (chunked_rounds, chunked_multi, chunked_shorts_first) = run(4);
        // short sessions' first tokens precede the long prefill under
        // BOTH disciplines — chunking must preserve that
        assert!(unchunked_shorts_first, "legacy rounds starved short sessions");
        assert!(chunked_shorts_first, "chunking made short sessions wait on the long prefill");
        // the chunked run must really chunk...
        assert!(!unchunked_multi, "unchunked run advanced a multi-token chunk");
        assert!(chunked_multi, "prefill_chunk=4 never advanced a multi-token chunk");
        // ...and that is what cuts the long prompt's TTFT: ~p/k rotation
        // slots instead of p one-token rounds
        assert!(
            chunked_rounds < unchunked_rounds,
            "chunking did not reduce long-prompt TTFT rounds \
             ({chunked_rounds} vs {unchunked_rounds})"
        );
    }

    fn push_pri(
        queue: &AdmissionQueue,
        prompt: &str,
        n: usize,
        pri: Priority,
    ) -> Receiver<GenResult> {
        let (mut req, rx) = request(prompt, n);
        req.priority = pri;
        assert!(queue.try_push(req).is_ok(), "test queue accepts");
        rx
    }

    /// Mixed-priority harness: two interactive and two batch sessions
    /// under a 1-token round budget, driven to completion. Returns the
    /// round each session FIRST advanced and every (round, session)
    /// advancement, with interactive sessions admitted as ids 1–2 and
    /// batch as ids 3–4 (the admission pop itself serves interactive
    /// first).
    fn mixed_priority_run() -> (Vec<(u64, u64)>, Vec<Receiver<GenResult>>) {
        let engine = test_engine(false);
        let (queue, metrics) = test_queue(8);
        let (completions, _completion_rx) = channel();
        let mut rxs = Vec::new();
        rxs.push(push_pri(&queue, "batch 0", 3, Priority::Batch));
        rxs.push(push_pri(&queue, "batch 1", 3, Priority::Batch));
        rxs.push(push_pri(&queue, "inter 0", 3, Priority::Interactive));
        rxs.push(push_pri(&queue, "inter 1", 3, Priority::Interactive));
        queue.close();
        let mut sched = Scheduler::new(
            engine,
            queue,
            completions,
            SchedulerConfig {
                max_sessions: 4,
                round_budget_tokens: 1,
                ..SchedulerConfig::default()
            },
            metrics,
            Arc::new(Mutex::new(ServeSnapshot::default())),
        );
        let mut advances = Vec::new();
        while let Some(r) = sched.turn() {
            for a in &r.advanced {
                advances.push((r.round, a.session));
            }
        }
        (advances, rxs)
    }

    #[test]
    fn interactive_outranks_batch_within_the_round_budget() {
        let (advances, rxs) = mixed_priority_run();
        // interactive requests were popped first at admission → ids 1, 2
        let first = |id: u64| {
            advances
                .iter()
                .find(|&&(_, s)| s == id)
                .map(|&(r, _)| r)
                .expect("session advanced")
        };
        let interactive_first = first(1).max(first(2));
        let batch_first = first(3).min(first(4));
        assert!(
            interactive_first < batch_first,
            "batch (round {batch_first}) advanced before both interactive \
             sessions (last at round {interactive_first})"
        );
        // the tier is a priority, not a denial of service
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().expect("served").n_generated, 3);
        }
    }

    #[test]
    fn batch_starvation_is_bounded() {
        let (advances, rxs) = mixed_priority_run();
        // anti-starvation promotion: a batch session never waits more
        // than ~2·max_sessions + 2 rounds between advances
        let bound = 2 * 4 + 2;
        for id in [3u64, 4] {
            let rounds: Vec<u64> = advances
                .iter()
                .filter(|&&(_, s)| s == id)
                .map(|&(r, _)| r)
                .collect();
            assert!(!rounds.is_empty(), "batch session {id} never ran");
            let mut prev = 0u64; // admitted before round 1
            for &r in &rounds {
                assert!(
                    r - prev <= bound,
                    "batch session {id} waited {} rounds (bound {bound})",
                    r - prev
                );
                prev = r;
            }
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn cancel_releases_everything_and_survivors_match() {
        // reference: the surviving prompts decoded with no one else around
        // (after the cancelled sessions' retire round, the engine must
        // behave as if they never existed — cache contents may differ, but
        // outputs are cache-transparent)
        let reference: Vec<String> = {
            let (mut sched, rxs) = driven_scheduler(
                SchedulerConfig { max_sessions: 4, ..SchedulerConfig::default() },
                &[("keeper zero", 6), ("keeper one", 6)],
            );
            let mut reference_turns = 0u64;
            while sched.turn().is_some() {
                reference_turns += 1;
            }
            assert!(reference_turns > 0);
            rxs.into_iter().map(|rx| rx.recv().unwrap().expect("served").text).collect()
        };

        // spec prefetch on: cancellation must also drop the engine's
        // queued prefetch records tagged to the dead sessions
        let engine = test_engine(true);
        let (queue, metrics) = test_queue(8);
        let (completions, _completion_rx) = channel();
        let keep_rx: Vec<_> = [("keeper zero", 6), ("keeper one", 6)]
            .iter()
            .map(|&(p, n)| push(&queue, p, n))
            .collect();
        let doomed_rx: Vec<_> = [("doomed two", 40), ("doomed three", 40)]
            .iter()
            .map(|&(p, n)| push(&queue, p, n))
            .collect();
        queue.close();
        let mut sched = Scheduler::new(
            engine,
            queue,
            completions,
            SchedulerConfig { max_sessions: 4, ..SchedulerConfig::default() },
            Arc::clone(&metrics),
            Arc::new(Mutex::new(ServeSnapshot::default())),
        );
        // run until both doomed sessions are mid-decode (≥ 1 generated)
        for _ in 0..10_000 {
            sched.turn().expect("work remains");
            let mid_decode = sched
                .active
                .sessions
                .iter()
                .filter(|s| s.inner.id >= 3)
                .filter(|s| !s.inner.generated().is_empty())
                .count();
            if mid_decode == 2 {
                break;
            }
        }
        assert!(sched.cancel(3), "session 3 active");
        assert!(sched.cancel(4), "session 4 active");
        assert!(!sched.cancel(99), "unknown session");
        // ONE round boundary releases them: no engine work, retired out
        sched.turn().expect("survivors still active");
        assert_eq!(metrics.cancelled_sessions.load(Ordering::Relaxed), 2);
        assert!(sched.active.sessions.iter().all(|s| s.inner.id < 3));
        let pending = sched.engine().pending_prefetch_sessions();
        assert!(
            !pending.contains(&3) && !pending.contains(&4),
            "queued prefetches still tagged to cancelled sessions: {pending:?}"
        );
        let mut turns_after = 0u64;
        while sched.turn().is_some() {
            turns_after += 1;
            assert!(turns_after < 10_000, "survivors failed to finish");
        }
        let texts: Vec<String> = keep_rx
            .into_iter()
            .map(|rx| rx.recv().unwrap().expect("survivor served").text)
            .collect();
        assert_eq!(texts, reference, "cancellation perturbed survivor outputs");
        // cancelled clients get silence (their channel drops undelivered),
        // and the sessions count as cancelled, not completed or failed
        for rx in doomed_rx {
            assert!(rx.recv().is_err(), "cancelled session delivered a reply");
        }
        assert_eq!(sched.completed, 2);
        assert_eq!(sched.failed_sessions, 0);
        let cancelled_views = sched
            .recent
            .iter()
            .filter(|v| v.state == "cancelled")
            .count();
        assert_eq!(cancelled_views, 2);
    }
}
