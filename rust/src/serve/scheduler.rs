//! Session scheduler: multiplex N concurrent decode sessions over the ONE
//! engine worker that owns the (non-`Send`) backend and the shared expert
//! cache.
//!
//! Scheduling discipline (DESIGN.md §6): round-robin token interleaving.
//! Each scheduler round steps every active session by exactly one token
//! (via [`Session::step_once`], the same feeding discipline offline
//! lockstep decoding uses), so no session can starve another,
//! time-to-first-token is bounded by one round, and consecutive tokens of
//! different sessions share the per-layer expert cache — a transfer paid
//! by one session is a hit for every other session that activates the same
//! expert while it stays resident (the paper's persistent-cache semantics,
//! now contended across sessions).
//!
//! Admission is demand-driven over the bounded [`AdmissionQueue`]: new
//! requests are drained between rounds, up to `max_sessions` in flight.
//! Before every admission pass the scheduler runs a *shed sweep*: queued
//! requests older than `queue_timeout` answer 503 + `Retry-After` without
//! ever becoming a session — a shed request consumes zero engine steps.
//! Finished generations are posted to the completion channel (the client
//! socket rides along) so the scheduler never writes to a socket and can
//! never be blocked by a slow client.
//!
//! Per-session accounting comes from the engine's session tallies
//! ([`crate::metrics::SessionTally`]) and is published after every round in
//! a [`ServeSnapshot`] the `/metrics` endpoint renders without touching the
//! engine thread.

use crate::engine::batch::Session;
use crate::engine::InferenceEngine;
use crate::metrics::{CacheStats, PipelineStats, PrecisionRecall, ServeMetrics, SessionTally};
use crate::model::sampler::Sampler;
use crate::model::tokenizer::Tokenizer;
use crate::serve::{
    AdmissionQueue, Completion, GenError, GenRequest, GenResponse, Popped, RETRY_AFTER_S,
};
use crate::sim::costmodel::TokenEvents;
use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How many finished sessions `/metrics` keeps visible after completion.
const RECENT_SESSIONS: usize = 32;

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Maximum sessions decoded concurrently (further requests queue).
    pub max_sessions: usize,
    /// Shed queued requests older than this before admitting them
    /// (`None` = requests wait indefinitely).
    pub queue_timeout: Option<Duration>,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_sessions: 8, queue_timeout: None }
    }
}

/// One session's row in the `/metrics` report.
#[derive(Clone, Debug)]
pub struct SessionView {
    pub id: u64,
    /// "active" while decoding, then "done" (responded) or "failed"
    /// (engine error mid-decode).
    pub state: &'static str,
    pub n_prompt: usize,
    pub generated: usize,
    pub target: usize,
    pub tally: SessionTally,
}

/// Aggregate + per-session view the scheduler publishes after every round.
/// There is exactly ONE shared expert cache behind all sessions; `cache`
/// reports its totals and `sessions[*].tally` partitions them.
#[derive(Clone, Debug, Default)]
pub struct ServeSnapshot {
    pub policy: String,
    pub capacity_per_layer: usize,
    pub n_layers: usize,
    pub active_sessions: usize,
    pub completed_sessions: u64,
    /// Sessions that died on an engine error mid-decode (not counted as
    /// completed; their clients got HTTP 500).
    pub failed_sessions: u64,
    pub cache: CacheStats,
    pub spec: PrecisionRecall,
    pub cross_session_prefetch_hits: u64,
    /// Transfer-pipeline queue + buffer-pool counters (workers == 0 when
    /// the engine runs transfers synchronously).
    pub pipeline: PipelineStats,
    pub sessions: Vec<SessionView>,
}

struct ActiveSession {
    inner: Session,
    started: Instant,
    /// Simulated clock reading at admission; the span until completion
    /// covers every interleaved token, so per-session sim tokens/s reflects
    /// contention — the serving metric, not the solo-decode one.
    sim_start: f64,
    reply: crate::serve::ReplyTo,
    /// Engine failure recorded mid-round; delivered when the session is
    /// retired (the reply path needs the session by value).
    error: Option<GenError>,
}

/// The active-session set, with a panic-safe reply guarantee: if the
/// scheduler unwinds mid-decode, every still-active session's client gets
/// a 500 through the completion channel (releasing its in-flight slot)
/// instead of a silent EOF. On a normal exit the set is empty and the
/// drop is a no-op.
struct ActiveSet {
    sessions: Vec<ActiveSession>,
    completions: Sender<Completion>,
}

impl Drop for ActiveSet {
    fn drop(&mut self) {
        for s in self.sessions.drain(..) {
            s.reply.deliver(
                Err(GenError {
                    status: 500,
                    message: "engine worker died mid-decode".into(),
                    retry_after: None,
                }),
                &self.completions,
            );
        }
    }
}

/// Run the scheduler until the admission queue closes and drains and no
/// sessions remain. Owns the engine for its entire lifetime and returns it
/// so callers can inspect post-run engine state (e.g.
/// [`InferenceEngine::total_steps`] — the shed-consumes-nothing proof).
pub fn run_scheduler(
    mut engine: InferenceEngine,
    queue: Arc<AdmissionQueue>,
    completions: Sender<Completion>,
    cfg: SchedulerConfig,
    metrics: Arc<ServeMetrics>,
    snapshot: Arc<Mutex<ServeSnapshot>>,
) -> InferenceEngine {
    let tk = Tokenizer::new(engine.config().vocab_size);
    let max_sessions = cfg.max_sessions.max(1);
    // panic-safe: if anything below unwinds, still-active sessions answer
    // 500 through the completion channel (see ActiveSet::drop)
    let mut active = ActiveSet { sessions: Vec::new(), completions: completions.clone() };
    let mut recent: VecDeque<SessionView> = VecDeque::new();
    let mut completed: u64 = 0;
    let mut failed_sessions: u64 = 0;
    let mut next_id: u64 = 1;

    {
        let mut snap = snapshot.lock().unwrap();
        snap.policy = engine.cfg.policy.name().to_string();
        snap.capacity_per_layer = engine.cfg.cache_capacity;
        snap.n_layers = engine.config().n_layers;
    }

    'outer: loop {
        // --- shed sweep: requests past their queue deadline answer 503 +
        // Retry-After *before* admission — they never become sessions and
        // never consume an engine step
        if let Some(t) = cfg.queue_timeout {
            for req in queue.take_aged(t) {
                shed(req, &completions, &metrics);
            }
        }

        // --- admission: block when idle, drain opportunistically when busy
        while active.sessions.len() < max_sessions {
            let req = match queue.pop(active.sessions.is_empty()) {
                Popped::Req(r) => r,
                Popped::Empty => break,
                Popped::Closed => {
                    if active.sessions.is_empty() {
                        break 'outer; // closed, drained, nothing active
                    }
                    break;
                }
            };
            // a request can age past its deadline between the sweep and
            // this pop (e.g. while the scheduler blocked idle): re-check,
            // so "admitted" always implies "within deadline at admission"
            if cfg.queue_timeout.is_some_and(|t| req.enqueued.elapsed() > t) {
                shed(req, &completions, &metrics);
                continue;
            }
            metrics
                .queue_wait
                .record_ns(req.enqueued.elapsed().as_nanos() as u64);
            // admission failures answer on the reply path; the responder
            // layer counts them in metrics.errors for socket replies
            if let Some(sess) = admit(&engine, &tk, next_id, req, &completions) {
                active.sessions.push(sess);
                next_id += 1;
            }
        }

        // --- one round-robin pass: every active session advances one token
        let mut finished: Vec<ActiveSession> = Vec::new();
        let mut i = 0;
        while i < active.sessions.len() {
            let s = &mut active.sessions[i];
            let was_generated = s.inner.next_token_is_generated();
            let mut ev = TokenEvents::default();
            match s.inner.step_once(&mut engine, &mut ev) {
                Ok(_done) => {
                    if was_generated {
                        metrics.tokens_generated.fetch_add(1, Ordering::Relaxed);
                    }
                }
                Err(e) => {
                    // engine-side failure: 500, delivered at retirement
                    s.error = Some(GenError {
                        status: 500,
                        message: format!("{e:#}"),
                        retry_after: None,
                    });
                }
            }
            if s.error.is_some() || s.inner.done {
                finished.push(active.sessions.swap_remove(i));
            } else {
                i += 1;
            }
        }

        for s in finished {
            let ActiveSession { inner, started, sim_start, reply, error } = s;
            let tally = engine.take_session_tally(inner.id);
            let generated = inner.generated().len();
            let succeeded = error.is_none() && inner.done;
            let result = if succeeded {
                let sim_span = engine.sim_now() - sim_start;
                completed += 1;
                Ok(GenResponse {
                    text: tk.decode(inner.generated()),
                    n_prompt: inner.n_prompt,
                    n_generated: generated,
                    wall_s: started.elapsed().as_secs_f64(),
                    sim_tokens_per_s: if sim_span > 0.0 {
                        (inner.n_prompt + generated) as f64 / sim_span
                    } else {
                        0.0
                    },
                    cache_hit_rate: tally.hit_rate(),
                    session_id: inner.id,
                    session_hits: tally.hits,
                    session_misses: tally.misses,
                    spec_precision: tally.spec_pr.precision(),
                    spec_recall: tally.spec_pr.recall(),
                })
            } else {
                failed_sessions += 1;
                Err(error.unwrap_or_else(|| GenError {
                    status: 500,
                    message: "session aborted".into(),
                    retry_after: None,
                }))
            };
            reply.deliver(result, &completions);
            recent.push_back(SessionView {
                id: inner.id,
                state: if succeeded { "done" } else { "failed" },
                n_prompt: inner.n_prompt,
                generated,
                target: inner.target_new,
                tally,
            });
            while recent.len() > RECENT_SESSIONS {
                recent.pop_front();
            }
        }

        publish(&engine, &active.sessions, &recent, completed, failed_sessions, &snapshot);
    }

    publish(&engine, &active.sessions, &recent, completed, failed_sessions, &snapshot);
    engine
}

/// Refuse one aged request: 503 + `Retry-After`, `shed_total` incremented,
/// queue wait recorded — and, by construction, zero engine steps consumed.
fn shed(req: GenRequest, completions: &Sender<Completion>, metrics: &ServeMetrics) {
    metrics
        .queue_wait
        .record_ns(req.enqueued.elapsed().as_nanos() as u64);
    metrics.shed_total.fetch_add(1, Ordering::Relaxed);
    req.reply.deliver(
        Err(GenError {
            status: 503,
            message: "shed: queued past --queue-timeout-ms; retry later".into(),
            retry_after: Some(RETRY_AFTER_S),
        }),
        completions,
    );
}

/// Validate and set up one request as an active session. On failure the
/// error is delivered on the reply path and `None` returned: length
/// violations are the client's fault (400), anything else in session
/// construction is the server's (500).
fn admit(
    engine: &InferenceEngine,
    tk: &Tokenizer,
    id: u64,
    req: GenRequest,
    completions: &Sender<Completion>,
) -> Option<ActiveSession> {
    let prompt = tk.encode(&req.prompt);
    let max = engine.config().max_seq;
    if prompt.len() + req.n_tokens > max {
        req.reply.deliver(
            Err(GenError {
                status: 400,
                message: format!(
                    "prompt {} + n_tokens {} exceeds max_seq {max}",
                    prompt.len(),
                    req.n_tokens
                ),
                retry_after: None,
            }),
            completions,
        );
        return None;
    }
    let sampler = Sampler::new(req.sampling, id);
    let inner = match Session::new(id, engine, &prompt, req.n_tokens, sampler) {
        Ok(s) => s,
        Err(e) => {
            req.reply.deliver(
                Err(GenError { status: 500, message: format!("{e:#}"), retry_after: None }),
                completions,
            );
            return None;
        }
    };
    Some(ActiveSession {
        inner,
        started: Instant::now(),
        sim_start: engine.sim_now(),
        reply: req.reply,
        error: None,
    })
}

fn publish(
    engine: &InferenceEngine,
    active: &[ActiveSession],
    recent: &VecDeque<SessionView>,
    completed: u64,
    failed_sessions: u64,
    snapshot: &Arc<Mutex<ServeSnapshot>>,
) {
    let mut views: Vec<SessionView> = active
        .iter()
        .map(|s| SessionView {
            id: s.inner.id,
            state: "active",
            n_prompt: s.inner.n_prompt,
            generated: s.inner.generated().len(),
            target: s.inner.target_new,
            tally: engine.session_tally(s.inner.id),
        })
        .collect();
    views.extend(recent.iter().cloned());
    let mut snap = snapshot.lock().unwrap();
    snap.active_sessions = active.len();
    snap.completed_sessions = completed;
    snap.failed_sessions = failed_sessions;
    snap.cache = engine.cache_stats();
    snap.spec = engine.spec_precision_recall();
    snap.cross_session_prefetch_hits = engine.cross_session_prefetch_hits();
    snap.pipeline = engine.pipeline_stats();
    snap.sessions = views;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::PolicyKind;
    use crate::engine::EngineConfig;
    use crate::model::sampler::Sampling;
    use crate::model::weights::generate_weights;
    use crate::model::ModelConfig;
    use crate::offload::store::HostExpertStore;
    use crate::quant::Scheme;
    use crate::runtime::native::NativeBackend;
    use crate::serve::{GenResult, ReplyTo};
    use std::sync::mpsc::{channel, Receiver};

    /// Byte-tokenizer-compatible tiny config (vocab must hold 256 bytes +
    /// specials; TINY's vocab of 64 is for raw-token tests only).
    pub(crate) fn serve_test_config() -> ModelConfig {
        ModelConfig {
            vocab_size: 320,
            max_seq: 96,
            ..ModelConfig::TINY
        }
    }

    pub(crate) fn test_engine(spec: bool) -> InferenceEngine {
        test_engine_workers(spec, 0)
    }

    pub(crate) fn test_engine_workers(spec: bool, transfer_workers: usize) -> InferenceEngine {
        let weights = Arc::new(generate_weights(serve_test_config(), 42));
        let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32).unwrap());
        let mut cfg = EngineConfig::serving(4, PolicyKind::Lfu, spec);
        cfg.transfer_workers = transfer_workers;
        InferenceEngine::new(Box::new(NativeBackend::new(weights)), store, cfg)
    }

    fn request(prompt: &str, n: usize) -> (GenRequest, Receiver<GenResult>) {
        let (tx, rx) = channel();
        (
            GenRequest {
                prompt: prompt.to_string(),
                n_tokens: n,
                sampling: Sampling::Greedy,
                reply: ReplyTo::Channel(tx),
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    fn push(queue: &AdmissionQueue, prompt: &str, n: usize) -> Receiver<GenResult> {
        let (req, rx) = request(prompt, n);
        assert!(queue.try_push(req).is_ok(), "test queue accepts");
        rx
    }

    fn test_queue(
        depth: usize,
    ) -> (Arc<AdmissionQueue>, Arc<ServeMetrics>) {
        let metrics = Arc::new(ServeMetrics::default());
        (AdmissionQueue::new(depth, Arc::clone(&metrics)), metrics)
    }

    #[test]
    fn scheduler_completes_concurrent_sessions() {
        let engine = test_engine(true);
        let (queue, metrics) = test_queue(16);
        let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
        let (completions, _completion_rx) = channel();

        let mut resp_rxs = Vec::new();
        for i in 0..5 {
            resp_rxs.push(push(&queue, &format!("prompt number {i}"), 6));
        }
        queue.close();
        let engine = run_scheduler(
            engine,
            queue,
            completions,
            SchedulerConfig { max_sessions: 4, queue_timeout: None },
            Arc::clone(&metrics),
            Arc::clone(&snapshot),
        );

        let mut ids = Vec::new();
        let mut stepped = 0u64;
        for rx in resp_rxs {
            let resp = rx.recv().unwrap().expect("generation ok");
            assert_eq!(resp.n_generated, 6);
            assert!(!ids.contains(&resp.session_id), "duplicate session id");
            ids.push(resp.session_id);
            stepped += (resp.n_prompt + resp.n_generated) as u64;
        }
        // admitted sessions account for every engine step
        assert_eq!(engine.total_steps(), stepped);
        let snap = snapshot.lock().unwrap();
        assert_eq!(snap.completed_sessions, 5);
        assert_eq!(snap.failed_sessions, 0);
        assert_eq!(snap.active_sessions, 0);
        // the recent ring keeps every finished session visible
        assert_eq!(snap.sessions.len(), 5);
        assert!(snap.sessions.iter().all(|s| s.state == "done"));
        // one shared cache served them all
        let part: u64 = snap.sessions.iter().map(|s| s.tally.hits + s.tally.misses).sum();
        assert_eq!(part, snap.cache.hits + snap.cache.misses);
        assert_eq!(metrics.tokens_generated.load(Ordering::Relaxed), 5 * 6);
        // every admitted request's queue wait was recorded
        assert_eq!(metrics.queue_wait.count(), 5);
        assert_eq!(metrics.queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn scheduler_with_pipeline_matches_sync_outputs() {
        // the async transfer pipeline must be invisible in the responses:
        // same requests, same texts, with the pipeline counters live
        let run = |workers: usize| {
            let engine = test_engine_workers(true, workers);
            let (queue, metrics) = test_queue(8);
            let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
            let (completions, _completion_rx) = channel();
            let mut resp_rxs = Vec::new();
            for i in 0..3 {
                resp_rxs.push(push(&queue, &format!("pipeline probe {i}"), 5));
            }
            queue.close();
            run_scheduler(
                engine,
                queue,
                completions,
                SchedulerConfig { max_sessions: 3, queue_timeout: None },
                metrics,
                Arc::clone(&snapshot),
            );
            let texts: Vec<String> = resp_rxs
                .into_iter()
                .map(|r| r.recv().unwrap().expect("generation ok").text)
                .collect();
            let snap = snapshot.lock().unwrap();
            (texts, snap.pipeline)
        };
        let (sync_texts, sync_pipe) = run(0);
        let (pipe_texts, pipe) = run(2);
        assert_eq!(sync_texts, pipe_texts, "pipeline changed outputs");
        assert_eq!(sync_pipe.workers, 0);
        assert_eq!(pipe.workers, 2);
        assert!(pipe.completed > 0, "pipeline never delivered a transfer");
    }

    #[test]
    fn scheduler_rejects_overlong_requests_and_continues() {
        let engine = test_engine(false);
        let (queue, metrics) = test_queue(8);
        let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
        let (completions, _completion_rx) = channel();

        let bad_rx = push(&queue, "way too long", 4096);
        let good_rx = push(&queue, "ok", 3);
        queue.close();
        run_scheduler(
            engine,
            queue,
            completions,
            SchedulerConfig::default(),
            metrics,
            snapshot,
        );

        let err = bad_rx.recv().unwrap().unwrap_err();
        assert_eq!(err.status, 400, "length violations are the client's fault");
        assert!(err.message.contains("max_seq"));
        assert_eq!(good_rx.recv().unwrap().unwrap().n_generated, 3);
    }

    #[test]
    fn scheduler_sheds_aged_requests_before_decode() {
        // a request that outwaited the queue timeout gets 503 +
        // Retry-After and consumes ZERO engine steps; fresh requests are
        // served normally
        let backdated = Instant::now().checked_sub(Duration::from_secs(120));
        let Some(backdated) = backdated else {
            return; // machine uptime too short to backdate; skip
        };
        let engine = test_engine(false);
        let (queue, metrics) = test_queue(8);
        let snapshot = Arc::new(Mutex::new(ServeSnapshot::default()));
        let (completions, _completion_rx) = channel();

        let (mut aged, aged_rx) = request("stale request", 4);
        aged.enqueued = backdated;
        assert!(queue.try_push(aged).is_ok());
        let fresh_rx = push(&queue, "fresh request", 4);
        queue.close();
        let engine = run_scheduler(
            engine,
            queue,
            completions,
            SchedulerConfig { max_sessions: 2, queue_timeout: Some(Duration::from_secs(60)) },
            Arc::clone(&metrics),
            snapshot,
        );

        let err = aged_rx.recv().unwrap().unwrap_err();
        assert_eq!(err.status, 503);
        assert_eq!(err.retry_after, Some(RETRY_AFTER_S), "sheds advertise Retry-After");
        assert!(err.message.contains("shed"), "{}", err.message);
        let ok = fresh_rx.recv().unwrap().expect("fresh request served");
        assert_eq!(ok.n_generated, 4);
        // the shed request consumed nothing on the engine
        assert_eq!(engine.total_steps(), (ok.n_prompt + ok.n_generated) as u64);
        assert_eq!(metrics.shed_total.load(Ordering::Relaxed), 1);
        // both dequeues recorded a queue wait
        assert_eq!(metrics.queue_wait.count(), 2);
    }

    #[test]
    fn interleaved_outputs_match_solo_decode() {
        // scheduling must be semantically transparent: a session decoded
        // alongside three others yields the same tokens as decoding alone
        let solo_out = {
            let mut engine = test_engine(false);
            let tk = Tokenizer::new(engine.config().vocab_size);
            let prompt = tk.encode("determinism check");
            // scheduler seeds the sampler with the session id; solo run is
            // admitted first, so it gets id 1
            let mut sampler = Sampler::new(Sampling::Greedy, 1);
            let out = engine.generate(&prompt, 5, &mut sampler).unwrap();
            out.generated
        };

        let engine = test_engine(false);
        let (queue, metrics) = test_queue(8);
        let (completions, _completion_rx) = channel();
        let probe_rx = push(&queue, "determinism check", 5);
        let mut others = Vec::new();
        for i in 0..3 {
            others.push(push(&queue, &format!("background load {i}"), 5));
        }
        queue.close();
        run_scheduler(
            engine,
            queue,
            completions,
            SchedulerConfig { max_sessions: 4, queue_timeout: None },
            metrics,
            Arc::new(Mutex::new(ServeSnapshot::default())),
        );

        let tk = Tokenizer::new(serve_test_config().vocab_size);
        let resp = probe_rx.recv().unwrap().unwrap();
        assert_eq!(resp.text, tk.decode(&solo_out), "shared cache changed outputs");
        for orx in others {
            assert!(orx.recv().unwrap().is_ok());
        }
    }
}
