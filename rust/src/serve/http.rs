//! Minimal HTTP/1.1 on `std::net` — enough for a JSON inference API:
//! request-line + headers + Content-Length bodies, keep-alive off.

use anyhow::{bail, Result};
use std::collections::HashMap;
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Hard cap on reading ONE request (request line + headers + body),
/// checked between reads. Combined with a per-socket read timeout (set by
/// the serve accept path) this bounds how long a slow or stalled client
/// can hold the reading thread — without either, a drip-feeding client
/// could pin an HTTP worker indefinitely.
const READ_DEADLINE: Duration = Duration::from_secs(30);

/// More headers than any sane client sends; a slowloris favourite.
const MAX_HEADERS: usize = 100;

/// Per-line byte cap (request line / header line).
const MAX_LINE_BYTES: usize = 8 << 10;

/// `read_line` with the deadline enforced *inside* the line: a drip-fed
/// line with no terminator must not pin the reading thread (std's
/// `read_line` loops until newline or EOF, unbounded in both time and
/// memory). Byte-at-a-time off the `BufReader` — the buffer makes that one
/// memcpy per byte, one syscall per buffer fill.
fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    start: Instant,
    deadline: Duration,
) -> Result<String> {
    let mut buf = Vec::new();
    loop {
        if start.elapsed() > deadline {
            bail!("request read deadline exceeded");
        }
        if buf.len() >= MAX_LINE_BYTES {
            bail!("header line too long");
        }
        let mut byte = [0u8; 1];
        if reader.read(&mut byte)? == 0 {
            break; // EOF
        }
        buf.push(byte[0]);
        if byte[0] == b'\n' {
            break;
        }
    }
    Ok(String::from_utf8_lossy(&buf).into_owned())
}

#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    read_request_bounded(stream, READ_DEADLINE)
}

/// [`read_request`] with a caller-chosen absolute deadline — the
/// control-plane thread parses its (tiny) requests under a much tighter
/// bound so one drip-feeding client cannot monopolize it for long.
pub fn read_request_bounded(stream: &mut TcpStream, deadline: Duration) -> Result<Request> {
    let start = Instant::now();
    let mut reader = BufReader::new(stream.try_clone()?);
    let line = read_line_bounded(&mut reader, start, deadline)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line {line:?}");
    }
    let mut headers = HashMap::new();
    // count LINES, not parsed headers: colon-less garbage lines must not
    // bypass the cap
    let mut terminated = false;
    for _ in 0..MAX_HEADERS {
        if start.elapsed() > deadline {
            bail!("request read deadline exceeded");
        }
        let h = read_line_bounded(&mut reader, start, deadline)?;
        let h = h.trim_end();
        if h.is_empty() {
            terminated = true;
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    if !terminated {
        bail!("too many header lines");
    }
    let len: usize = headers
        .get("content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    if len > 16 << 20 {
        bail!("body too large: {len}");
    }
    let mut body = vec![0u8; len];
    let mut filled = 0;
    while filled < len {
        if start.elapsed() > deadline {
            bail!("request read deadline exceeded");
        }
        let n = reader.read(&mut body[filled..])?;
        if n == 0 {
            bail!("connection closed mid-body ({filled}/{len} bytes)");
        }
        filled += n;
    }
    Ok(Request { method, path, headers, body })
}

pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> Result<()> {
    write_response_with_headers(stream, status, content_type, &[], body)
}

/// Like [`write_response`] with extra response headers (e.g. `Retry-After`
/// on admission-control 503s).
pub fn write_response_with_headers(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    };
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// chunked transfer encoding (streamed /generate responses)
// ---------------------------------------------------------------------------

/// Response head for a streamed body: `Transfer-Encoding: chunked`, no
/// `Content-Length` (the length is unknown while tokens are still
/// decoding), `Connection: close` like every other response.
pub fn write_chunked_head(stream: &mut TcpStream, status: u16, content_type: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        _ => "Unknown",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    );
    stream.write_all(head.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// One chunk frame: `{len:x}\r\n{data}\r\n`. Empty data is silently
/// skipped — a zero-length frame IS the terminator, so writing one here
/// would truncate the stream.
pub fn write_chunk(stream: &mut TcpStream, data: &[u8]) -> Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    stream.write_all(format!("{:x}\r\n", data.len()).as_bytes())?;
    stream.write_all(data)?;
    stream.write_all(b"\r\n")?;
    stream.flush()?;
    Ok(())
}

/// The terminating zero-length chunk (`0\r\n\r\n`, no trailers).
pub fn write_chunked_end(stream: &mut TcpStream) -> Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()?;
    Ok(())
}

/// Decode a chunked body into its chunks. Returns `None` on framing errors
/// (bad length line, missing terminator) so tests can assert on the exact
/// wire format, not just the concatenation.
pub fn dechunk(body: &str) -> Option<Vec<String>> {
    let mut chunks = Vec::new();
    let mut rest = body;
    loop {
        let (len_line, after) = rest.split_once("\r\n")?;
        let len = usize::from_str_radix(len_line.trim(), 16).ok()?;
        if len == 0 {
            // terminator: `0\r\n` then a final empty line
            return after.starts_with("\r\n").then_some(chunks);
        }
        if after.len() < len {
            return None;
        }
        let (data, tail) = after.split_at(len);
        chunks.push(data.to_string());
        rest = tail.strip_prefix("\r\n")?;
    }
}

// ---------------------------------------------------------------------------
// minimal blocking client (Connection: close framing), shared by the load
// example and the serve integration tests so the two cannot drift apart
// ---------------------------------------------------------------------------

/// Send a raw HTTP/1.1 request and return the entire response text (status
/// line + headers + body) — for tests that assert on headers like
/// `Retry-After`.
pub fn client_request_text(addr: std::net::SocketAddr, raw: &str) -> std::io::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(raw.as_bytes())?;
    let mut resp = String::new();
    s.read_to_string(&mut resp)?;
    Ok(resp)
}

/// Send a raw HTTP/1.1 request and read the full response; returns
/// `(status, body)`. Status 0 when the status line is unparseable.
pub fn client_request(addr: std::net::SocketAddr, raw: &str) -> std::io::Result<(u16, String)> {
    let resp = client_request_text(addr, raw)?;
    let status: u16 = resp
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or(0);
    let body = resp.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    Ok((status, body))
}

pub fn client_get(addr: std::net::SocketAddr, path: &str) -> std::io::Result<(u16, String)> {
    client_request(addr, &format!("GET {path} HTTP/1.1\r\nHost: localhost\r\n\r\n"))
}

pub fn client_post(
    addr: std::net::SocketAddr,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    client_request(addr, &post_raw(path, body))
}

/// `client_post` variant returning the raw response text (headers
/// included).
pub fn client_post_text(
    addr: std::net::SocketAddr,
    path: &str,
    body: &str,
) -> std::io::Result<String> {
    client_request_text(addr, &post_raw(path, body))
}

fn post_raw(path: &str, body: &str) -> String {
    format!(
        "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
}

/// POST and decode a chunked response: returns `(status, chunks)`, where
/// each element is one chunk's payload in arrival order. Errors with
/// `InvalidData` when the response is not chunked or mis-framed.
pub fn client_post_stream(
    addr: std::net::SocketAddr,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, Vec<String>)> {
    let resp = client_request_text(addr, &post_raw(path, body))?;
    let status: u16 = resp
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or(0);
    let (head, raw_body) = resp
        .split_once("\r\n\r\n")
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "no header break"))?;
    if !head.to_ascii_lowercase().contains("transfer-encoding: chunked") {
        // error responses (4xx/5xx) come back buffered with Content-Length
        return Ok((status, vec![raw_body.to_string()]));
    }
    let chunks = dechunk(raw_body).ok_or_else(|| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, "mis-framed chunked body")
    })?;
    Ok((status, chunks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn roundtrip_request_response() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let req = read_request(&mut s).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/generate");
            assert_eq!(req.body, b"{\"n\":1}");
            write_response(&mut s, 200, "application/json", b"{\"ok\":true}").unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n{\"n\":1}")
            .unwrap();
        let mut resp = String::new();
        c.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"));
        assert!(resp.ends_with("{\"ok\":true}"));
        server.join().unwrap();
    }

    #[test]
    fn client_helpers_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let req = read_request(&mut s).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.body, b"{\"n\":2}");
            write_response(&mut s, 503, "text/plain", b"busy").unwrap();
        });
        let (status, body) = client_post(addr, "/generate", "{\"n\":2}").unwrap();
        assert_eq!(status, 503);
        assert_eq!(body, "busy");
        server.join().unwrap();
    }

    #[test]
    fn extra_headers_are_written() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let _ = read_request(&mut s).unwrap();
            write_response_with_headers(
                &mut s,
                503,
                "text/plain",
                &[("Retry-After", "1".to_string())],
                b"busy",
            )
            .unwrap();
        });
        let raw = client_post_text(addr, "/generate", "{}").unwrap();
        assert!(raw.starts_with("HTTP/1.1 503"), "{raw}");
        assert!(raw.contains("\r\nRetry-After: 1\r\n"), "{raw}");
        assert!(raw.ends_with("busy"), "{raw}");
        server.join().unwrap();
    }

    #[test]
    fn chunked_roundtrip_and_dechunk() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let _ = read_request(&mut s).unwrap();
            write_chunked_head(&mut s, 200, "text/plain").unwrap();
            write_chunk(&mut s, b"hello ").unwrap();
            write_chunk(&mut s, b"").unwrap(); // skipped, NOT a terminator
            write_chunk(&mut s, b"world").unwrap();
            write_chunked_end(&mut s).unwrap();
        });
        let (status, chunks) = client_post_stream(addr, "/generate?stream=1", "{}").unwrap();
        assert_eq!(status, 200);
        assert_eq!(chunks, vec!["hello ".to_string(), "world".to_string()]);
        server.join().unwrap();
    }

    #[test]
    fn dechunk_rejects_bad_framing() {
        assert_eq!(dechunk("5\r\nhello\r\n0\r\n\r\n").unwrap(), vec!["hello"]);
        assert!(dechunk("5\r\nhel").is_none(), "truncated data");
        assert!(dechunk("zz\r\nhello\r\n").is_none(), "bad length line");
        assert!(dechunk("5\r\nhello\r\n").is_none(), "missing terminator");
    }

    #[test]
    fn malformed_request_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            assert!(read_request(&mut s).is_err());
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"\r\n\r\n").unwrap();
        drop(c);
        server.join().unwrap();
    }
}
