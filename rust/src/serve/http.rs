//! Minimal HTTP/1.1 on `std::net` — enough for a JSON inference API:
//! request-line + headers + Content-Length bodies, keep-alive off.

use anyhow::{bail, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line {line:?}");
    }
    let mut headers = HashMap::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = headers
        .get("content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    if len > 16 << 20 {
        bail!("body too large: {len}");
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, headers, body })
}

pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// minimal blocking client (Connection: close framing), shared by the load
// example and the serve integration tests so the two cannot drift apart
// ---------------------------------------------------------------------------

/// Send a raw HTTP/1.1 request and read the full response; returns
/// `(status, body)`. Status 0 when the status line is unparseable.
pub fn client_request(addr: std::net::SocketAddr, raw: &str) -> std::io::Result<(u16, String)> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(raw.as_bytes())?;
    let mut resp = String::new();
    s.read_to_string(&mut resp)?;
    let status: u16 = resp
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or(0);
    let body = resp.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    Ok((status, body))
}

pub fn client_get(addr: std::net::SocketAddr, path: &str) -> std::io::Result<(u16, String)> {
    client_request(addr, &format!("GET {path} HTTP/1.1\r\nHost: localhost\r\n\r\n"))
}

pub fn client_post(
    addr: std::net::SocketAddr,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    client_request(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn roundtrip_request_response() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let req = read_request(&mut s).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/generate");
            assert_eq!(req.body, b"{\"n\":1}");
            write_response(&mut s, 200, "application/json", b"{\"ok\":true}").unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n{\"n\":1}")
            .unwrap();
        let mut resp = String::new();
        c.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"));
        assert!(resp.ends_with("{\"ok\":true}"));
        server.join().unwrap();
    }

    #[test]
    fn client_helpers_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let req = read_request(&mut s).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.body, b"{\"n\":2}");
            write_response(&mut s, 503, "text/plain", b"busy").unwrap();
        });
        let (status, body) = client_post(addr, "/generate", "{\"n\":2}").unwrap();
        assert_eq!(status, 503);
        assert_eq!(body, "busy");
        server.join().unwrap();
    }

    #[test]
    fn malformed_request_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            assert!(read_request(&mut s).is_err());
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"\r\n\r\n").unwrap();
        drop(c);
        server.join().unwrap();
    }
}
