//! Metrics: cache hit accounting, the paper's precision/recall definitions,
//! transfer-volume accounting and throughput meters.
//!
//! Precision/recall follow paper §4.2/§5.3 exactly: per (token, layer),
//! compare the set of experts **cached at activation time** against the set
//! of **activated** experts. TP = activated ∧ cached, FP = cached ∧ ¬activated,
//! FN = activated ∧ ¬cached. For speculation (§5.4): guessed vs activated —
//! with |guessed| = |activated| = k this forces FP == FN and therefore
//! precision == recall (asserted by a property test).

/// Confusion-matrix accumulator over (token, layer) events.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PrecisionRecall {
    pub tp: u64,
    pub fp: u64,
    pub fn_: u64,
}

impl PrecisionRecall {
    /// Record one event: which experts were predicted (cached/guessed) and
    /// which were actually activated.
    pub fn record(&mut self, predicted: &[usize], activated: &[usize]) {
        for &p in predicted {
            if activated.contains(&p) {
                self.tp += 1;
            } else {
                self.fp += 1;
            }
        }
        for &a in activated {
            if !predicted.contains(&a) {
                self.fn_ += 1;
            }
        }
    }
    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fp) as f64
    }
    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fn_) as f64
    }
    pub fn merge(&mut self, other: &PrecisionRecall) {
        self.tp += other.tp;
        self.fp += other.fp;
        self.fn_ += other.fn_;
    }
}

/// Expert-cache hit/miss/eviction counters (optionally per layer).
#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub prefetch_hits: u64,
    pub prefetch_wasted: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
    pub fn merge(&mut self, o: &CacheStats) {
        self.hits += o.hits;
        self.misses += o.misses;
        self.evictions += o.evictions;
        self.prefetch_hits += o.prefetch_hits;
        self.prefetch_wasted += o.prefetch_wasted;
    }
}

/// Per-decode-session accounting under concurrent serving: each session's
/// share of the *shared* expert cache's traffic, plus its own speculative
/// precision/recall. Maintained by the engine per tagged session id.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionTally {
    /// Tokens this session has stepped through the engine.
    pub tokens: u64,
    /// Cache hits/misses attributed to this session's lookups.
    pub hits: u64,
    pub misses: u64,
    /// Speculative-prefetch guesses issued by this session, scored against
    /// its own activations (paper §5.4 semantics, per session).
    pub spec_pr: PrecisionRecall,
    /// Speculative transfers this session issued that were never used.
    pub wasted_prefetches: u64,
}

impl SessionTally {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

/// Transfer-pipeline counters (`offload::pipeline`): queue behaviour of the
/// multi-worker dequant pipeline plus the shared buffer pool's allocation
/// accounting. `workers == 0` means the engine ran the synchronous path
/// (the pool counters still apply — the sync path draws from the same pool).
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineStats {
    pub workers: u64,
    /// Jobs enqueued at demand priority (misses with nothing to join).
    pub submitted_demand: u64,
    /// Jobs enqueued at prefetch priority.
    pub submitted_prefetch: u64,
    /// Results delivered back to the engine.
    pub completed: u64,
    /// Demand misses that joined an in-flight prefetch of the same expert
    /// instead of double-fetching.
    pub demand_joined_prefetch: u64,
    /// Queued prefetches cancelled before a worker started them (guess
    /// superseded or target evicted).
    pub cancelled_prefetches: u64,
    /// High-water mark of jobs submitted-but-uncollected.
    pub peak_in_flight: u64,
    /// Buffer-pool acquires served by a fresh allocation.
    pub pool_allocs: u64,
    /// Buffer-pool acquires served by recycling.
    pub pool_reuses: u64,
}

impl PipelineStats {
    /// Fraction of buffer acquires served without allocating (the
    /// steady-state zero-allocation criterion; 0.0 if the pool was unused).
    pub fn pool_reuse_rate(&self) -> f64 {
        let total = self.pool_allocs + self.pool_reuses;
        if total == 0 {
            return 0.0;
        }
        self.pool_reuses as f64 / total as f64
    }
}

/// Host->device transfer accounting (bytes that crossed the simulated PCIe).
#[derive(Clone, Debug, Default)]
pub struct TransferStats {
    pub transfers: u64,
    pub bytes: u64,
    pub dequant_ns: u64,
    pub upload_ns: u64,
}

impl TransferStats {
    pub fn record(&mut self, bytes: usize) {
        self.transfers += 1;
        self.bytes += bytes as u64;
    }
}

/// Tokens/s meter over both wallclock and the simulated clock.
#[derive(Clone, Debug, Default)]
pub struct Throughput {
    pub tokens: u64,
    pub wall_s: f64,
    pub sim_s: f64,
}

impl Throughput {
    pub fn tokens_per_s_wall(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.tokens as f64 / self.wall_s
    }
    pub fn tokens_per_s_sim(&self) -> f64 {
        if self.sim_s <= 0.0 {
            return 0.0;
        }
        self.tokens as f64 / self.sim_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pr_basic() {
        let mut pr = PrecisionRecall::default();
        // cache {0,1,2,3}, activated {1,4}
        pr.record(&[0, 1, 2, 3], &[1, 4]);
        assert_eq!(pr.tp, 1);
        assert_eq!(pr.fp, 3);
        assert_eq!(pr.fn_, 1);
        assert_eq!(pr.precision(), 0.25);
        assert_eq!(pr.recall(), 0.5);
    }

    #[test]
    fn pr_equal_cardinality_forces_p_eq_r() {
        // paper §5.4: |guessed| == |activated| => FP == FN => P == R
        let mut pr = PrecisionRecall::default();
        pr.record(&[0, 1], &[1, 5]);
        pr.record(&[2, 3], &[2, 3]);
        pr.record(&[4, 6], &[0, 7]);
        assert_eq!(pr.fp, pr.fn_);
        assert_eq!(pr.precision(), pr.recall());
    }

    #[test]
    fn pr_empty_is_zero() {
        let pr = PrecisionRecall::default();
        assert_eq!(pr.precision(), 0.0);
        assert_eq!(pr.recall(), 0.0);
    }

    #[test]
    fn pr_merge() {
        let mut a = PrecisionRecall::default();
        a.record(&[0], &[0]);
        let mut b = PrecisionRecall::default();
        b.record(&[1], &[2]);
        a.merge(&b);
        assert_eq!(a.tp, 1);
        assert_eq!(a.fp, 1);
        assert_eq!(a.fn_, 1);
    }

    #[test]
    fn cache_hit_rate() {
        let mut s = CacheStats::default();
        s.hits = 3;
        s.misses = 1;
        assert_eq!(s.hit_rate(), 0.75);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn throughput() {
        let t = Throughput { tokens: 10, wall_s: 2.0, sim_s: 4.0 };
        assert_eq!(t.tokens_per_s_wall(), 5.0);
        assert_eq!(t.tokens_per_s_sim(), 2.5);
    }
}
