//! Metrics: cache hit accounting, the paper's precision/recall definitions,
//! transfer-volume accounting and throughput meters.
//!
//! Precision/recall follow paper §4.2/§5.3 exactly: per (token, layer),
//! compare the set of experts **cached at activation time** against the set
//! of **activated** experts. TP = activated ∧ cached, FP = cached ∧ ¬activated,
//! FN = activated ∧ ¬cached. For speculation (§5.4): guessed vs activated —
//! with |guessed| = |activated| = k this forces FP == FN and therefore
//! precision == recall (asserted by a property test).

use std::sync::atomic::{AtomicU64, Ordering};

/// Confusion-matrix accumulator over (token, layer) events.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PrecisionRecall {
    pub tp: u64,
    pub fp: u64,
    pub fn_: u64,
}

impl PrecisionRecall {
    /// Record one event: which experts were predicted (cached/guessed) and
    /// which were actually activated.
    pub fn record(&mut self, predicted: &[usize], activated: &[usize]) {
        for &p in predicted {
            if activated.contains(&p) {
                self.tp += 1;
            } else {
                self.fp += 1;
            }
        }
        for &a in activated {
            if !predicted.contains(&a) {
                self.fn_ += 1;
            }
        }
    }
    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fp) as f64
    }
    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fn_) as f64
    }
    pub fn merge(&mut self, other: &PrecisionRecall) {
        self.tp += other.tp;
        self.fp += other.fp;
        self.fn_ += other.fn_;
    }
}

/// Expert-cache hit/miss/eviction counters (optionally per layer).
#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub prefetch_hits: u64,
    pub prefetch_wasted: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
    pub fn merge(&mut self, o: &CacheStats) {
        self.hits += o.hits;
        self.misses += o.misses;
        self.evictions += o.evictions;
        self.prefetch_hits += o.prefetch_hits;
        self.prefetch_wasted += o.prefetch_wasted;
    }
}

/// Per-decode-session accounting under concurrent serving: each session's
/// share of the *shared* expert cache's traffic, plus its own speculative
/// precision/recall. Maintained by the engine per tagged session id.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionTally {
    /// Tokens this session has stepped through the engine.
    pub tokens: u64,
    /// Cache hits/misses attributed to this session's lookups.
    pub hits: u64,
    pub misses: u64,
    /// Speculative-prefetch guesses issued by this session, scored against
    /// its own activations (paper §5.4 semantics, per session).
    pub spec_pr: PrecisionRecall,
    /// Speculative transfers this session issued that were never used.
    pub wasted_prefetches: u64,
}

impl SessionTally {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

/// Round-level expert-batching counters (DESIGN.md §8): one `step_round`
/// groups every routed token in the round by `(layer, expert)` and runs ONE
/// resident-ensure + multi-row FFN per distinct expert. The first arriving
/// session pays the fetch; each co-routed session is a dedup join (a plain
/// cache hit in its tally). `batched_rows - distinct_experts == dedup_joins`
/// by construction.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundBatchStats {
    /// `step_round` calls executed.
    pub rounds: u64,
    /// Distinct `(layer, expert)` groups executed (one ensure + one
    /// multi-row FFN each).
    pub distinct_experts: u64,
    /// Rows that joined a group another session had already opened this
    /// round — each one is a fetch + dequant that per-session stepping
    /// would have had to consider separately.
    pub dedup_joins: u64,
    /// Total rows pushed through batched expert FFNs.
    pub batched_rows: u64,
}

impl RoundBatchStats {
    /// Fraction of batched rows that were dedup joins (0.0 when idle).
    pub fn join_rate(&self) -> f64 {
        if self.batched_rows == 0 {
            return 0.0;
        }
        self.dedup_joins as f64 / self.batched_rows as f64
    }
    pub fn merge(&mut self, o: &RoundBatchStats) {
        self.rounds += o.rounds;
        self.distinct_experts += o.distinct_experts;
        self.dedup_joins += o.dedup_joins;
        self.batched_rows += o.batched_rows;
    }
}

/// Transfer-pipeline counters (`offload::pipeline`): queue behaviour of the
/// multi-worker dequant pipeline plus the shared buffer pool's allocation
/// accounting. `workers == 0` means the engine ran the synchronous path
/// (the pool counters still apply — the sync path draws from the same pool).
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineStats {
    pub workers: u64,
    /// Jobs enqueued at demand priority (misses with nothing to join).
    pub submitted_demand: u64,
    /// Jobs enqueued at prefetch priority.
    pub submitted_prefetch: u64,
    /// Results delivered back to the engine.
    pub completed: u64,
    /// Demand misses that joined an in-flight prefetch of the same expert
    /// instead of double-fetching.
    pub demand_joined_prefetch: u64,
    /// Queued prefetches cancelled before a worker started them (guess
    /// superseded or target evicted).
    pub cancelled_prefetches: u64,
    /// High-water mark of jobs submitted-but-uncollected.
    pub peak_in_flight: u64,
    /// Buffer-pool acquires served by a fresh allocation.
    pub pool_allocs: u64,
    /// Buffer-pool acquires served by recycling.
    pub pool_reuses: u64,
}

impl PipelineStats {
    /// Fraction of buffer acquires served without allocating (the
    /// steady-state zero-allocation criterion; 0.0 if the pool was unused).
    pub fn pool_reuse_rate(&self) -> f64 {
        let total = self.pool_allocs + self.pool_reuses;
        if total == 0 {
            return 0.0;
        }
        self.pool_reuses as f64 / total as f64
    }

    /// Fold another replica's pipeline counters in (multi-replica serving:
    /// each engine replica spawns its own worker set and buffer pool over
    /// the shared host store, so counters sum). `peak_in_flight` takes the
    /// max — summing per-replica high-water marks would report a peak no
    /// moment in time ever had.
    pub fn merge(&mut self, o: &PipelineStats) {
        self.workers += o.workers;
        self.submitted_demand += o.submitted_demand;
        self.submitted_prefetch += o.submitted_prefetch;
        self.completed += o.completed;
        self.demand_joined_prefetch += o.demand_joined_prefetch;
        self.cancelled_prefetches += o.cancelled_prefetches;
        self.peak_in_flight = self.peak_in_flight.max(o.peak_in_flight);
        self.pool_allocs += o.pool_allocs;
        self.pool_reuses += o.pool_reuses;
    }
}

/// Lock-free log₂-bucketed latency histogram over nanosecond samples.
///
/// 64 power-of-two buckets cover the full `u64` range; `percentile_ns`
/// returns the inclusive upper bound of the bucket the target rank lands
/// in, so the reported quantile is within 2× of the true value — plenty
/// for the serve layer's queue-wait p50/p99 gauges, with `record_ns` a
/// single relaxed fetch_add on the hot admission path.
pub struct LatencyHisto {
    buckets: [AtomicU64; 64],
}

impl Default for LatencyHisto {
    fn default() -> Self {
        LatencyHisto { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl LatencyHisto {
    pub fn record_ns(&self, ns: u64) {
        let idx = 63 - (ns | 1).leading_zeros() as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Approximate `q`-quantile in ns (upper bound of the rank's bucket);
    /// 0 when no samples were recorded. The top bucket `[2^63, u64::MAX]`
    /// has no finite power-of-two upper bound, so ranks landing there
    /// saturate to its lower bound `2^63` — a guaranteed floor — instead
    /// of serializing a nonsense 1.8e19 sentinel into `/metrics`.
    pub fn percentile_ns(&self, q: f64) -> u64 {
        const TOP_BUCKET_NS: u64 = 1u64 << 63;
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut acc = 0u64;
        for (i, c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i >= 63 { TOP_BUCKET_NS } else { (1u64 << (i + 1)) - 1 };
            }
        }
        TOP_BUCKET_NS
    }
}

/// Serve-layer counters and gauges, shared between the HTTP workers, the
/// admission queue, the scheduler, and the responder set (see DESIGN.md
/// §6). Counters are monotone; `queue_depth` and `inflight_sessions` are
/// gauges:
///
/// * `queue_depth` — requests waiting in the bounded admission queue.
///   Maintained under the queue's own lock, so it is exact and can never
///   exceed the configured `--queue-depth`.
/// * `inflight_sessions` — accepted-but-unfinished requests (queued +
///   decoding + waiting on a responder write). Bounded by
///   `--max-inflight-sessions` via a reserve-slot CAS at admission.
#[derive(Default)]
pub struct ServeMetrics {
    pub requests: AtomicU64,
    /// Client/server failures relayed to clients (4xx/5xx), excluding the
    /// admission-control 503s counted by the reject/shed counters below.
    pub errors: AtomicU64,
    /// `/generate` 503s: bounded admission queue full.
    pub rejected_backpressure: AtomicU64,
    /// `/generate` 503s: in-flight session cap reached.
    pub rejected_inflight: AtomicU64,
    /// Queued requests shed at dequeue because they waited longer than
    /// `--queue-timeout-ms` (503 + Retry-After, no engine steps consumed).
    pub shed_total: AtomicU64,
    pub tokens_generated: AtomicU64,
    /// Prompt tokens fed through the engine (the prefill share of serve
    /// work; `tokens_generated` is the decode share).
    pub tokens_prefill: AtomicU64,
    pub queue_depth: AtomicU64,
    pub inflight_sessions: AtomicU64,
    /// Responder writes that failed because the CLIENT went away
    /// (connection reset / broken pipe, or any failure after the client
    /// already received streamed body bytes). Not a server error.
    pub client_disconnects: AtomicU64,
    /// Responder writes that failed for any other (server-side) reason —
    /// e.g. a local socket error before the first byte reached the peer.
    pub write_errors: AtomicU64,
    /// Sessions cancelled mid-decode because their streamed client
    /// disconnected (or an operator cancel): retired at the next round
    /// boundary, resources reclaimed, no response delivered.
    pub cancelled_sessions: AtomicU64,
    /// Admission-queue wait, recorded at dequeue (admitted or shed).
    pub queue_wait: LatencyHisto,
    /// Time-to-first-token: enqueue → the session's prompt fully fed
    /// (its first output token is sampled by that very step). Includes
    /// queue wait, so it is the client-observable TTFT.
    pub ttft: LatencyHisto,
    /// TTFT split by priority class — the SLO-tier observable: an
    /// `interactive` request's sample lands in both `ttft` and here.
    pub ttft_interactive: LatencyHisto,
    /// TTFT of `batch`-priority requests (see `ttft_interactive`).
    pub ttft_batch: LatencyHisto,
    /// Engine replicas still serving (gauge; starts at `--engine-workers`).
    /// A replica that exits or panics quarantines itself and decrements
    /// this; the admission queue only closes when it reaches zero.
    pub engine_replicas_alive: AtomicU64,
}

impl ServeMetrics {
    /// All admission rejections (queue full + in-flight cap); sheds are
    /// tracked separately because those requests were accepted first.
    pub fn rejected_total(&self) -> u64 {
        self.rejected_backpressure.load(Ordering::Relaxed)
            + self.rejected_inflight.load(Ordering::Relaxed)
    }
}

/// Host-tier (RAM→disk) counters for the tiered expert store (DESIGN.md
/// §10): every host access lands in exactly one of `ram_hits` (entry was
/// resident in the budgeted RAM cache) or `disk_promotions` (entry was
/// read from the spill file and promoted), so
/// `ram_hits + disk_promotions == host_accesses` always holds. All zeros
/// when the store runs unbounded (all-RAM backing).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HostTierStats {
    /// Host accesses served from the RAM cache.
    pub ram_hits: u64,
    /// Host accesses that missed RAM and promoted the entry from disk.
    pub disk_promotions: u64,
    /// RAM-cache entries evicted to make room for a promotion.
    pub ram_evictions: u64,
    /// Total wallclock nanoseconds spent in disk reads.
    pub disk_read_ns: u64,
    /// p99 of individual disk-read latencies (bucketed, see
    /// [`LatencyHisto::percentile_ns`]).
    pub disk_read_p99_ns: u64,
    /// Total host-store accesses (`ram_hits + disk_promotions`).
    pub host_accesses: u64,
}

impl HostTierStats {
    /// Fraction of host accesses served without touching disk (0.0 idle).
    pub fn ram_hit_rate(&self) -> f64 {
        if self.host_accesses == 0 {
            return 0.0;
        }
        self.ram_hits as f64 / self.host_accesses as f64
    }
}

/// Host->device transfer accounting (bytes that crossed the simulated PCIe).
#[derive(Clone, Debug, Default)]
pub struct TransferStats {
    pub transfers: u64,
    pub bytes: u64,
    pub dequant_ns: u64,
    pub upload_ns: u64,
    /// Demand fetches re-attempted after an injected (or real) transient
    /// failure; each retry pays an exponential virtual backoff first.
    pub retries: u64,
}

impl TransferStats {
    pub fn record(&mut self, bytes: usize) {
        self.transfers += 1;
        self.bytes += bytes as u64;
    }
}

/// Tokens/s meter over both wallclock and the simulated clock.
#[derive(Clone, Debug, Default)]
pub struct Throughput {
    pub tokens: u64,
    pub wall_s: f64,
    pub sim_s: f64,
}

impl Throughput {
    pub fn tokens_per_s_wall(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.tokens as f64 / self.wall_s
    }
    pub fn tokens_per_s_sim(&self) -> f64 {
        if self.sim_s <= 0.0 {
            return 0.0;
        }
        self.tokens as f64 / self.sim_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pr_basic() {
        let mut pr = PrecisionRecall::default();
        // cache {0,1,2,3}, activated {1,4}
        pr.record(&[0, 1, 2, 3], &[1, 4]);
        assert_eq!(pr.tp, 1);
        assert_eq!(pr.fp, 3);
        assert_eq!(pr.fn_, 1);
        assert_eq!(pr.precision(), 0.25);
        assert_eq!(pr.recall(), 0.5);
    }

    #[test]
    fn pr_equal_cardinality_forces_p_eq_r() {
        // paper §5.4: |guessed| == |activated| => FP == FN => P == R
        let mut pr = PrecisionRecall::default();
        pr.record(&[0, 1], &[1, 5]);
        pr.record(&[2, 3], &[2, 3]);
        pr.record(&[4, 6], &[0, 7]);
        assert_eq!(pr.fp, pr.fn_);
        assert_eq!(pr.precision(), pr.recall());
    }

    #[test]
    fn pr_empty_is_zero() {
        let pr = PrecisionRecall::default();
        assert_eq!(pr.precision(), 0.0);
        assert_eq!(pr.recall(), 0.0);
    }

    #[test]
    fn pr_merge() {
        let mut a = PrecisionRecall::default();
        a.record(&[0], &[0]);
        let mut b = PrecisionRecall::default();
        b.record(&[1], &[2]);
        a.merge(&b);
        assert_eq!(a.tp, 1);
        assert_eq!(a.fp, 1);
        assert_eq!(a.fn_, 1);
    }

    #[test]
    fn cache_hit_rate() {
        let mut s = CacheStats::default();
        s.hits = 3;
        s.misses = 1;
        assert_eq!(s.hit_rate(), 0.75);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn throughput() {
        let t = Throughput { tokens: 10, wall_s: 2.0, sim_s: 4.0 };
        assert_eq!(t.tokens_per_s_wall(), 5.0);
        assert_eq!(t.tokens_per_s_sim(), 2.5);
    }

    #[test]
    fn histo_empty_is_zero() {
        let h = LatencyHisto::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_ns(0.5), 0);
        assert_eq!(h.percentile_ns(0.99), 0);
    }

    #[test]
    fn histo_percentiles_bound_samples() {
        let h = LatencyHisto::default();
        // 90 fast samples (~1µs), 10 slow (~1ms)
        for _ in 0..90 {
            h.record_ns(1_000);
        }
        for _ in 0..10 {
            h.record_ns(1_000_000);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile_ns(0.5);
        let p99 = h.percentile_ns(0.99);
        // upper-bucket-bound semantics: within 2x above the true value,
        // never below it
        assert!((1_000..=2_048).contains(&p50), "p50 {p50}");
        assert!((1_000_000..=2_097_152).contains(&p99), "p99 {p99}");
        assert!(p50 <= p99);
    }

    #[test]
    fn histo_extremes() {
        let h = LatencyHisto::default();
        h.record_ns(0);
        h.record_ns(u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile_ns(0.25), 1); // bucket 0 upper bound
        // top bucket saturates to its lower bound 2^63, not u64::MAX
        assert_eq!(h.percentile_ns(1.0), 1u64 << 63);
    }

    #[test]
    fn histo_top_bucket_saturates_not_sentinel() {
        // every sample in the top bucket [2^63, u64::MAX]: all quantiles
        // must report the bucket's finite floor, never the old u64::MAX
        // sentinel that serialized as a nonsense 1.8e19 ns gauge
        let h = LatencyHisto::default();
        for _ in 0..5 {
            h.record_ns(1u64 << 63);
        }
        h.record_ns(u64::MAX);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.percentile_ns(q), 1u64 << 63, "q={q}");
        }
        // one bucket down still reports its exact finite upper bound
        let h2 = LatencyHisto::default();
        h2.record_ns((1u64 << 62) + 17);
        assert_eq!(h2.percentile_ns(1.0), (1u64 << 63) - 1);
    }

    #[test]
    fn host_tier_stats_hit_rate_and_invariant() {
        let s = HostTierStats {
            ram_hits: 30,
            disk_promotions: 10,
            ram_evictions: 4,
            disk_read_ns: 1_000,
            disk_read_p99_ns: 200,
            host_accesses: 40,
        };
        assert_eq!(s.ram_hits + s.disk_promotions, s.host_accesses);
        assert_eq!(s.ram_hit_rate(), 0.75);
        assert_eq!(HostTierStats::default().ram_hit_rate(), 0.0);
    }

    #[test]
    fn round_batch_stats_join_rate_and_merge() {
        let mut a = RoundBatchStats { rounds: 1, distinct_experts: 2, dedup_joins: 1, batched_rows: 3 };
        let b = RoundBatchStats { rounds: 1, distinct_experts: 2, dedup_joins: 3, batched_rows: 5 };
        a.merge(&b);
        assert_eq!(a.rounds, 2);
        assert_eq!(a.distinct_experts, 4);
        assert_eq!(a.dedup_joins, 4);
        assert_eq!(a.batched_rows, 8);
        // the structural identity every round preserves
        assert_eq!(a.batched_rows - a.distinct_experts, a.dedup_joins);
        assert_eq!(a.join_rate(), 0.5);
        assert_eq!(RoundBatchStats::default().join_rate(), 0.0);
    }

    #[test]
    fn pipeline_stats_merge_sums_counters_maxes_peak() {
        let mut a = PipelineStats {
            workers: 2,
            submitted_demand: 10,
            submitted_prefetch: 4,
            completed: 14,
            demand_joined_prefetch: 1,
            cancelled_prefetches: 2,
            peak_in_flight: 5,
            pool_allocs: 3,
            pool_reuses: 7,
        };
        let b = PipelineStats {
            workers: 2,
            submitted_demand: 6,
            submitted_prefetch: 2,
            completed: 8,
            demand_joined_prefetch: 3,
            cancelled_prefetches: 0,
            peak_in_flight: 9,
            pool_allocs: 1,
            pool_reuses: 9,
        };
        a.merge(&b);
        assert_eq!(a.workers, 4);
        assert_eq!(a.submitted_demand, 16);
        assert_eq!(a.completed, 22);
        assert_eq!(a.demand_joined_prefetch, 4);
        assert_eq!(a.peak_in_flight, 9, "peaks max, not sum");
        assert_eq!(a.pool_allocs, 4);
        assert_eq!(a.pool_reuses, 16);
        assert_eq!(a.pool_reuse_rate(), 0.8);
    }

    #[test]
    fn serve_metrics_rejected_total_sums() {
        let m = ServeMetrics::default();
        m.rejected_backpressure.store(3, Ordering::Relaxed);
        m.rejected_inflight.store(2, Ordering::Relaxed);
        m.shed_total.store(9, Ordering::Relaxed);
        assert_eq!(m.rejected_total(), 5, "sheds are not rejections");
    }
}
