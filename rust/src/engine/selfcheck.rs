//! Selfcheck: validate the rust runtimes against the JAX golden vectors.
//!
//! `python/compile/aot.py` records (a) per-stage outputs on fixed inputs
//! and (b) an 8-token greedy decode with per-layer expert selections and
//! logit digests. This module replays both through a [`Backend`] (PJRT or
//! native) and reports per-check absolute errors — the cross-language,
//! cross-runtime correctness anchor of the whole stack.

use crate::cache::PolicyKind;
use crate::engine::{EngineConfig, InferenceEngine};
use crate::model::sampler::{Sampler, Sampling};
use crate::offload::prefetch::PrefetchConfig;
use crate::offload::store::HostExpertStore;
use crate::quant::Scheme;
use crate::runtime::{artifacts::Artifacts, Backend};
use crate::sim::costmodel::TokenEvents;
use crate::util::json::Value;
use anyhow::{bail, Result};
use std::sync::Arc;

pub struct CheckReport {
    pub checks: Vec<(String, f64, f64)>, // (name, max_abs_err, tolerance)
    pub passed: bool,
}

impl CheckReport {
    fn add(&mut self, name: &str, err: f64, tol: f64) {
        if err > tol {
            self.passed = false;
        }
        self.checks.push((name.to_string(), err, tol));
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, err, tol) in &self.checks {
            out.push_str(&format!(
                "  {} {name}: max_abs_err {err:.3e} (tol {tol:.1e})\n",
                if err <= tol { "PASS" } else { "FAIL" }
            ));
        }
        out.push_str(if self.passed { "selfcheck: ALL PASS\n" } else { "selfcheck: FAILURES\n" });
        out
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() as f64)
        .fold(0.0, f64::max)
}

/// Stage-level checks against `testvec.json` `stages`.
pub fn check_stages(backend: &dyn Backend, tv: &Value) -> Result<CheckReport> {
    let sv = tv.get("stages");
    let mut rep = CheckReport { checks: Vec::new(), passed: true };
    let x: Vec<f32> = sv.get("x").as_f32_vec().unwrap_or_default();
    if x.is_empty() {
        bail!("testvec has no stage vectors");
    }

    // embed
    let got = backend.embed(3)?;
    let want = sv.get("embed_tok3").as_f32_vec().unwrap();
    rep.add("embed", max_abs_diff(&got, &want), 1e-5);

    // attn at pos 0 with fresh caches
    let mut kv = backend.new_kv()?;
    let got = backend.attn(0, &x, &mut kv, 0)?;
    let want = sv.get("attn_x_res").as_f32_vec().unwrap();
    rep.add("attn.x_res", max_abs_diff(&got, &want), 5e-4);

    // router
    let (h, probs) = backend.router(0, &x)?;
    let want_h = sv.get("router_h").as_f32_vec().unwrap();
    let want_p = sv.get("router_probs").as_f32_vec().unwrap();
    rep.add("router.h", max_abs_diff(&h, &want_h), 5e-4);
    rep.add("router.probs", max_abs_diff(&probs, &want_p), 1e-4);

    // expert 0 of layer 0 — via an f32 store (no quantization error)
    let want_y = sv.get("expert0_y").as_f32_vec().unwrap();
    let got = {
        // the caller passes a backend built over the same weights; fetch
        // the raw f32 weights through an ExpertHandle upload
        let handle = upload_f32_expert(backend, 0, 0)?;
        backend.expert(&h, &handle)?
    };
    rep.add("expert0.y", max_abs_diff(&got, &want_y), 2e-3);

    // final logits
    let got = backend.final_logits(&x)?;
    let first8 = &got[..8.min(got.len())];
    let want8 = sv.get("final_logits_first8").as_f32_vec().unwrap();
    rep.add("final.first8", max_abs_diff(first8, &want8), 5e-4);
    let sum: f64 = got.iter().map(|&v| v as f64).sum();
    let want_sum = sv.get("final_logits_sum").as_f64().unwrap_or(f64::NAN);
    rep.add("final.sum", (sum - want_sum).abs() / want_sum.abs().max(1.0), 1e-3);
    Ok(rep)
}

/// The selfcheck needs raw f32 expert weights; they travel via the same
/// `upload_expert` path the transfer engine uses.
fn upload_f32_expert(
    backend: &dyn Backend,
    layer: usize,
    expert: usize,
) -> Result<crate::runtime::ExpertHandle> {
    // Weights live inside the backend for native; for pjrt we need the
    // original weights. The engine-level check below covers pjrt; here we
    // reconstruct from the artifacts weights file through a thread-local.
    WEIGHTS.with(|w| {
        let wref = w.borrow();
        let weights = wref.as_ref().expect("selfcheck weights not set");
        Ok(backend.upload_expert(
            weights.expert(layer, expert, "w1")?.to_vec(),
            weights.expert(layer, expert, "w3")?.to_vec(),
            weights.expert(layer, expert, "w2")?.to_vec(),
        )?)
    })
}

thread_local! {
    static WEIGHTS: std::cell::RefCell<Option<Arc<crate::model::Weights>>> =
        const { std::cell::RefCell::new(None) };
}

pub fn set_selfcheck_weights(w: Arc<crate::model::Weights>) {
    WEIGHTS.with(|cell| *cell.borrow_mut() = Some(w));
}

/// Golden-decode check: replay the recorded greedy decode through the full
/// engine (f32 store so quantization cannot perturb selections) and compare
/// expert selections, argmax tokens and logit digests.
pub fn check_decode(
    backend: Box<dyn Backend>,
    weights: Arc<crate::model::Weights>,
    tv: &Value,
) -> Result<CheckReport> {
    let dec = tv.get("decode");
    let steps = dec.get("steps").as_arr().unwrap_or(&[]);
    if steps.is_empty() {
        bail!("testvec has no decode steps");
    }
    let prompt: Vec<u32> = dec
        .get("prompt")
        .as_usize_vec()
        .unwrap_or_default()
        .iter()
        .map(|&t| t as u32)
        .collect();
    let n_gen = dec.get("n_gen").as_usize().unwrap_or(0);

    let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32)?);
    let mc = *backend.config();
    let mut engine = InferenceEngine::new(
        backend,
        store,
        EngineConfig {
            cache_capacity: mc.n_experts, // full cache: no eviction noise
            policy: PolicyKind::Lru,
            prefetch: PrefetchConfig::default(),
            transfer_workers: 0,
            profile: crate::sim::hardware::physical()[0],
            disk: crate::sim::hardware::DiskProfile::default(),
            seed: 0,
            record_trace: true,
            fetch_retries: 2,
            demand_deadline_ms: 0,
            ..EngineConfig::default()
        },
    );
    let mut sampler = Sampler::new(Sampling::Greedy, 0);
    let out = engine.generate(&prompt, n_gen, &mut sampler)?;
    let trace = out.trace.as_ref().expect("trace recorded");

    let mut rep = CheckReport { checks: Vec::new(), passed: true };
    let mut sel_mismatches = 0usize;
    let mut argmax_mismatches = 0usize;
    for (i, step) in steps.iter().enumerate() {
        let want_experts = step.get("experts").as_arr().unwrap();
        for (l, want) in want_experts.iter().enumerate() {
            let mut want: Vec<usize> = want.as_usize_vec().unwrap();
            let mut got = trace.at(i, l).activated.clone();
            want.sort_unstable();
            got.sort_unstable();
            if want != got {
                sel_mismatches += 1;
            }
        }
        // generated-token agreement
        if i + 1 > prompt.len() && i < out.tokens.len() {
            let want_tok = step.get("token").as_usize().unwrap_or(0) as u32;
            if out.tokens[i] != want_tok {
                argmax_mismatches += 1;
            }
        }
    }
    let n_events = steps.len() * mc.n_layers;
    rep.add(
        "decode.expert_selections",
        sel_mismatches as f64 / n_events as f64,
        0.02, // ≤2% of (token,layer) events may flip on fp disagreement
    );
    rep.add(
        "decode.generated_tokens",
        argmax_mismatches as f64 / n_gen.max(1) as f64,
        0.25, // argmax over 1024 logits is fp-sensitive; selections matter more
    );
    Ok(rep)
}

/// Convenience: run both checks for a backend over shipped artifacts.
pub fn run_all(
    make_backend: impl Fn() -> Result<Box<dyn Backend>>,
    artifacts: &Artifacts,
    weights: Arc<crate::model::Weights>,
) -> Result<CheckReport> {
    let tv = artifacts.load_testvec()?;
    set_selfcheck_weights(Arc::clone(&weights));
    let be = make_backend()?;
    let mut rep = check_stages(be.as_ref(), &tv)?;
    drop(be);
    let rep2 = check_decode(make_backend()?, weights, &tv)?;
    for c in rep2.checks {
        if c.1 > c.2 {
            rep.passed = false;
        }
        rep.checks.push(c);
    }
    Ok(rep)
}

/// Used by tests: make sure a step through the engine with TokenEvents
/// default-initialized works for arbitrary backends.
pub fn smoke_step(backend: Box<dyn Backend>, weights: Arc<crate::model::Weights>) -> Result<Vec<f32>> {
    let store = Arc::new(HostExpertStore::build(&weights, Scheme::F32)?);
    let mut engine = InferenceEngine::new(backend, store, EngineConfig::baseline_lru(2));
    let mut kv = engine.backend.new_kv()?;
    let mut ev = TokenEvents::default();
    engine.step(1, &mut kv, 0, &mut ev)
}
