//! Inference engine — the L3 per-token decode loop where every offloading
//! decision is made.
//!
//! For each token, for each layer:
//!   1. run the attention stage (AOT artifact via PJRT, or native oracle),
//!   2. run the router stage, take top-k experts in rust,
//!   3. snapshot the expert cache (the paper's trace "gray squares"),
//!   4. for each activated expert: cache hit -> use the resident device
//!      buffers; miss -> transfer (dequantize + upload) and insert,
//!      evicting per the configured policy (LRU/LFU/…),
//!   5. optionally guess layer l+1's experts by applying its gate to this
//!      layer's hidden states (speculative prefetch, §3.2) and transfer
//!      them early — synchronously or via the overlap worker (§6.1),
//!   6. combine expert outputs with renormalized gate weights + residual.
//!
//! Wallclock is measured; simulated device time is charged to a [`SimClock`]
//! per the hardware profile (DESIGN.md §3): compute per stage, transfer per
//! miss, with prefetched transfers hidden behind compute up to bus
//! serialization.

pub mod batch;
pub mod selfcheck;

use crate::cache::{ExpertCache, PolicyKind};
use crate::metrics::{PrecisionRecall, SessionTally, Throughput};
use crate::model::sampler::{top_k, Sampler};
use crate::offload::overlap::OverlapWorker;
use crate::offload::prefetch::{PendingPrefetch, PrefetchConfig, TaggedGuess};
use crate::offload::store::HostExpertStore;
use crate::offload::transfer::TransferEngine;
use crate::runtime::{Backend, ExpertHandle, KvState};
use crate::sim::costmodel::TokenEvents;
use crate::sim::hardware::{HwProfile, ModelScale};
use crate::trace::Trace;
use crate::util::simclock::SimClock;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Session id used by the single-sequence [`InferenceEngine::generate`] /
/// [`InferenceEngine::step`] paths; the concurrent serve scheduler assigns
/// its own ids starting from 1.
pub const SOLO_SESSION: u64 = 0;

#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Experts kept per layer ("# offloads" = n_experts − capacity).
    pub cache_capacity: usize,
    pub policy: PolicyKind,
    pub prefetch: PrefetchConfig,
    /// Run prefetch dequantization on the overlap worker thread.
    pub overlap: bool,
    /// Hardware profile for the simulated clock.
    pub profile: HwProfile,
    pub seed: u64,
    /// Record the full activation/cache trace.
    pub record_trace: bool,
}

impl EngineConfig {
    pub fn baseline_lru(capacity: usize) -> Self {
        EngineConfig {
            cache_capacity: capacity,
            policy: PolicyKind::Lru,
            prefetch: PrefetchConfig::default(),
            overlap: false,
            profile: crate::sim::hardware::physical()[0],
            seed: 0,
            record_trace: true,
        }
    }

    /// Preset for the concurrent serve path: requested policy + capacity,
    /// optional speculation, no trace recording (traces grow with every
    /// token ever decoded, which a long-lived server must not do).
    pub fn serving(capacity: usize, policy: PolicyKind, prefetch: bool) -> Self {
        EngineConfig {
            cache_capacity: capacity,
            policy,
            prefetch: PrefetchConfig { enabled: prefetch, k: 2 },
            record_trace: false,
            ..EngineConfig::baseline_lru(capacity)
        }
    }
}

impl Default for EngineConfig {
    /// The paper's baseline operating point (LRU, 4-of-8 experts cached).
    fn default() -> Self {
        EngineConfig::baseline_lru(4)
    }
}

/// Outcome of one `generate` call.
pub struct GenerationOutput {
    pub tokens: Vec<u32>,
    pub generated: Vec<u32>,
    pub trace: Option<Trace>,
    pub events: Vec<TokenEvents>,
    pub throughput: Throughput,
    pub cache_stats: crate::metrics::CacheStats,
    pub spec_pr: PrecisionRecall,
    /// Peak simulated device bytes (static + resident experts + KV).
    pub peak_resident_bytes: usize,
    pub transfer_bytes: u64,
}

pub struct InferenceEngine {
    pub backend: Box<dyn Backend>,
    pub cfg: EngineConfig,
    cache: ExpertCache<ExpertHandle>,
    transfer: TransferEngine,
    overlap: Option<OverlapWorker>,
    clock: SimClock,
    /// In-flight prefetch transfers on the simulated bus, tagged with the
    /// issuing session so cross-session hits are attributable.
    pending_prefetch: Vec<PendingPrefetch>,
    spec_pr: PrecisionRecall,
    /// Per-session accounting (cache traffic + speculation quality); keyed
    /// by the session id passed to [`InferenceEngine::step_session`].
    session_stats: HashMap<u64, SessionTally>,
    /// Demand lookups that were satisfied by an expert a *different*
    /// session prefetched — the shared-cache amortization counter.
    cross_session_prefetch_hits: u64,
    /// Pending speculative guess for the next layer, session-tagged.
    spec_guess: Option<TaggedGuess>,
    trace: Option<Trace>,
    /// Per-layer compute seconds (dense) and per-expert seconds, derived
    /// from the profile and the artifact's true dimensions.
    dense_s_per_layer: f64,
    expert_s: f64,
    store: Arc<HostExpertStore>,
}

impl InferenceEngine {
    pub fn new(
        backend: Box<dyn Backend>,
        store: Arc<HostExpertStore>,
        cfg: EngineConfig,
    ) -> Self {
        let mc = *backend.config();
        let scale = ModelScale {
            name: "live",
            n_layers: mc.n_layers,
            hidden: mc.hidden_size,
            ffn: mc.ffn_size,
            n_experts: mc.n_experts,
            top_k: mc.top_k,
            expert_bytes: store.expert_transfer_bytes(),
            expert_bytes_resident: mc.expert_bytes_f32(),
            static_bytes: 0,
        };
        let dense_s_per_layer =
            cfg.profile.compute_time(scale.dense_flops_per_token()) / mc.n_layers as f64;
        let expert_s = cfg.profile.compute_time(scale.expert_flops());
        let cache = ExpertCache::new(mc.n_layers, cfg.cache_capacity, cfg.policy, cfg.seed);
        let overlap = (cfg.overlap).then(|| OverlapWorker::spawn(Arc::clone(&store)));
        let trace = cfg
            .record_trace
            .then(|| Trace::new(mc.n_layers, mc.n_experts, mc.top_k));
        InferenceEngine {
            backend,
            cfg,
            cache,
            transfer: TransferEngine::new(Arc::clone(&store)),
            overlap,
            clock: SimClock::new(),
            pending_prefetch: Vec::new(),
            spec_pr: PrecisionRecall::default(),
            session_stats: HashMap::new(),
            cross_session_prefetch_hits: 0,
            spec_guess: None,
            trace,
            dense_s_per_layer,
            expert_s,
            store,
        }
    }

    pub fn config(&self) -> &crate::model::ModelConfig {
        self.backend.config()
    }

    /// Simulated transfer duration of one expert.
    fn transfer_s(&self) -> f64 {
        self.cfg.profile.transfer_time(self.store.expert_transfer_bytes())
    }

    /// Forget any in-flight prefetch record for `(layer, expert)`. Called
    /// when the cached product of a prefetch disappears (eviction) or is
    /// superseded (demand transfer, re-prefetch), so stale records can
    /// neither accumulate in a long-lived server nor credit a later,
    /// unrelated access as a prefetch hit.
    fn drop_pending_prefetch(&mut self, layer: usize, expert: usize) {
        self.pending_prefetch
            .retain(|p| !(p.layer == layer && p.expert == expert));
    }

    /// Ensure `e` is resident in layer `l`'s cache; returns whether it was a
    /// hit and updates the sim clock for any stall. `session` attributes the
    /// lookup (and any cross-session prefetch credit) under concurrency.
    fn ensure_resident(
        &mut self,
        session: u64,
        l: usize,
        e: usize,
        ev: &mut TokenEvents,
    ) -> Result<bool> {
        // already resident?
        if self.cache.layers[l].access(e).is_some() {
            // if it arrived via an in-flight prefetch, we may still need to
            // wait for the (simulated) bus to finish delivering it
            if let Some(i) = self
                .pending_prefetch
                .iter()
                .position(|p| p.layer == l && p.expert == e)
            {
                let pending = self.pending_prefetch.swap_remove(i);
                let now = self.clock.now();
                if pending.done_at > now {
                    self.clock.advance(pending.done_at - now);
                } else {
                    ev.hidden_transfers += 1;
                }
                self.cache.layers[l].stats.prefetch_hits += 1;
                if pending.session != session {
                    // another session's speculation paid for this transfer:
                    // the shared cache amortized it across sessions
                    self.cross_session_prefetch_hits += 1;
                }
            }
            return Ok(true);
        }
        // miss: demand transfer, fully on the critical path. Any pending
        // prefetch record for this expert is stale (its product was
        // evicted before use) — the demand transfer supersedes it.
        self.drop_pending_prefetch(l, e);
        ev.misses += 1;
        let handle = if let Some(w) = &mut self.overlap {
            // an in-flight overlap prefetch may already have dequantized it
            if let Some(r) = w.wait_for(l, e) {
                self.backend.upload_expert(r.w1, r.w3, r.w2)?
            } else {
                let (h, _) = self.transfer.fetch(self.backend.as_ref(), l, e)?;
                h
            }
        } else {
            let (h, _) = self.transfer.fetch(self.backend.as_ref(), l, e)?;
            h
        };
        let now = self.clock.now();
        let done = self.transfer.schedule_bus(now, self.transfer_s());
        self.clock.advance(done - now);
        if let Some((victim, _)) = self.cache.layers[l].insert(e, handle) {
            self.drop_pending_prefetch(l, victim);
        }
        Ok(false)
    }

    /// Issue speculative prefetches for `next_layer` on behalf of `session`.
    fn prefetch(
        &mut self,
        session: u64,
        next_layer: usize,
        guesses: &[usize],
        ev: &mut TokenEvents,
    ) -> Result<()> {
        for &e in guesses {
            if self.cache.layers[next_layer].peek(e).is_some() {
                continue; // already resident: free
            }
            // transfer early; simulated completion is bus-serialized but NOT
            // awaited — compute continues (overlap)
            let now = self.clock.now();
            let done = self.transfer.schedule_bus(now, self.transfer_s());
            // a re-prefetch supersedes any stale record for this expert
            self.drop_pending_prefetch(next_layer, e);
            self.pending_prefetch.push(PendingPrefetch {
                session,
                layer: next_layer,
                expert: e,
                done_at: done,
            });
            let handle = if let Some(w) = &mut self.overlap {
                w.submit(next_layer, e);
                None // uploaded lazily when collected or demanded
            } else {
                let (h, _) = self.transfer.fetch(self.backend.as_ref(), next_layer, e)?;
                Some(h)
            };
            if let Some(h) = handle {
                if let Some((victim, _)) = self.cache.layers[next_layer].insert(e, h) {
                    self.drop_pending_prefetch(next_layer, victim);
                }
            }
            ev.wasted_prefetches += 1; // provisional; settled below
        }
        Ok(())
    }

    /// Collect overlap-worker results and upload them into the cache.
    fn collect_overlap(&mut self) -> Result<()> {
        let ready = match &mut self.overlap {
            Some(w) => w.collect_ready(),
            None => return Ok(()),
        };
        for r in ready {
            let handle = self.backend.upload_expert(r.w1, r.w3, r.w2)?;
            if let Some((victim, _)) = self.cache.layers[r.layer].insert(r.expert, handle) {
                self.drop_pending_prefetch(r.layer, victim);
            }
        }
        Ok(())
    }

    /// Run one token through the model; returns logits. Single-sequence
    /// convenience over [`InferenceEngine::step_session`] (session
    /// [`SOLO_SESSION`]).
    pub fn step(&mut self, tok: u32, kv: &mut KvState, pos: usize, ev: &mut TokenEvents) -> Result<Vec<f32>> {
        self.step_session(SOLO_SESSION, tok, kv, pos, ev)
    }

    /// Run one token of `session` through the model; returns logits.
    ///
    /// Concurrent serving interleaves sessions token-by-token on one engine
    /// (DESIGN.md §6). Each call is self-contained with respect to
    /// speculation — a guess issued at layer *l* settles at layer *l+1* of
    /// the same call — but the expert cache, the simulated bus, and any
    /// still-pending prefetch transfers are shared across sessions, which is
    /// exactly the paper's persistent-cache semantics under contention.
    /// Cache traffic and speculation quality are attributed to `session` in
    /// [`InferenceEngine::session_stats`].
    pub fn step_session(
        &mut self,
        session: u64,
        tok: u32,
        kv: &mut KvState,
        pos: usize,
        ev: &mut TokenEvents,
    ) -> Result<Vec<f32>> {
        if let Some(t) = &mut self.trace {
            t.push_token(tok);
        }
        let token_idx = self.trace.as_ref().map_or(0, |t| t.n_tokens() - 1);

        // baselines for per-session attribution (settled below even when a
        // layer errors mid-token, so the per-session partition of the
        // shared cache's totals stays exact across failures)
        let stats0 = self.cache.total_stats();
        let spec0 = self.spec_pr;
        let wasted0 = ev.wasted_prefetches;

        let result = self.step_layers(session, tok, kv, pos, ev, token_idx);

        // attribute this token's shared-cache traffic to the session
        let stats1 = self.cache.total_stats();
        let spec1 = self.spec_pr;
        let tally = self.session_stats.entry(session).or_default();
        tally.tokens += 1;
        tally.hits += stats1.hits.saturating_sub(stats0.hits);
        tally.misses += stats1.misses.saturating_sub(stats0.misses);
        tally.wasted_prefetches +=
            ev.wasted_prefetches.saturating_sub(wasted0) as u64;
        tally.spec_pr.merge(&PrecisionRecall {
            tp: spec1.tp.saturating_sub(spec0.tp),
            fp: spec1.fp.saturating_sub(spec0.fp),
            fn_: spec1.fn_.saturating_sub(spec0.fn_),
        });
        result
    }

    /// The fallible per-layer body of [`InferenceEngine::step_session`].
    fn step_layers(
        &mut self,
        session: u64,
        tok: u32,
        kv: &mut KvState,
        pos: usize,
        ev: &mut TokenEvents,
        token_idx: usize,
    ) -> Result<Vec<f32>> {
        let mc = *self.backend.config();
        let mut x = self.backend.embed(tok)?;
        for l in 0..mc.n_layers {
            self.collect_overlap()?;
            let x_res = self.backend.attn(l, &x, kv, pos)?;
            self.clock.advance(self.dense_s_per_layer);
            let (h, probs) = self.backend.router(l, &x_res)?;
            let selected = top_k(&probs, mc.top_k);
            ev.activations += selected.len();

            // settle last layer's speculative guess against the truth.
            // The session/layer guard also quietly discards a guess left
            // behind by a step that errored mid-token — the scheduler keeps
            // the engine alive across per-session failures.
            if let Some(g) = self.spec_guess.take() {
                if g.layer == l && g.session == session {
                    self.spec_pr.record(&g.experts, &selected);
                    if let Some(t) = &mut self.trace {
                        t.at_mut(token_idx, l).spec_guess = Some(g.experts.clone());
                    }
                    // correct guesses were not wasted
                    let correct = g.experts.iter().filter(|e| selected.contains(e)).count();
                    ev.wasted_prefetches = ev.wasted_prefetches.saturating_sub(correct);
                }
            }

            // trace snapshot BEFORE the demand lookups (paper's figures)
            if let Some(t) = &mut self.trace {
                let rec = t.at_mut(token_idx, l);
                rec.cached_before = self.cache.layers[l].resident();
                rec.activated = selected.clone();
            }

            // renormalized top-k gate weights
            let wsum: f32 = selected.iter().map(|&e| probs[e]).sum();
            let gate_w: Vec<f32> = selected.iter().map(|&e| probs[e] / wsum).collect();
            if let Some(t) = &mut self.trace {
                t.at_mut(token_idx, l).weights = gate_w.clone();
            }

            // speculative guess for layer l+1 from THIS layer's post-attn
            // hidden states (issued before the expert compute so transfers
            // overlap with it)
            if self.cfg.prefetch.enabled && l + 1 < mc.n_layers {
                let spec_probs = self.backend.spec_router(l + 1, &x_res)?;
                let guesses = top_k(&spec_probs, self.cfg.prefetch.k);
                self.prefetch(session, l + 1, &guesses, ev)?;
                self.spec_guess = Some(TaggedGuess { session, layer: l + 1, experts: guesses });
            }

            // expert compute with cache/transfer
            let mut y = vec![0.0f32; mc.hidden_size];
            for (j, &e) in selected.iter().enumerate() {
                self.ensure_resident(session, l, e, ev)?;
                let handle = self.cache.layers[l].peek(e).expect("just inserted");
                let out = self.backend.expert(&h, handle)?;
                let w = gate_w[j];
                for (yv, &ov) in y.iter_mut().zip(&out) {
                    *yv += w * ov;
                }
                self.clock.advance(self.expert_s);
            }
            for (xv, (&rv, &yv)) in x.iter_mut().zip(x_res.iter().zip(&y)) {
                *xv = rv + yv;
            }
        }
        self.backend.final_logits(&x)
    }

    /// Decode: teacher-force `prompt`, then sample `n_gen` tokens.
    pub fn generate(
        &mut self,
        prompt: &[u32],
        n_gen: usize,
        sampler: &mut Sampler,
    ) -> Result<GenerationOutput> {
        let mc = *self.backend.config();
        let mut kv = self.backend.new_kv()?;
        let mut tokens: Vec<u32> = prompt.to_vec();
        let mut generated = Vec::with_capacity(n_gen);
        let mut events = Vec::new();
        let total = prompt.len() + n_gen;
        anyhow::ensure!(total <= mc.max_seq, "sequence {total} exceeds max_seq {}", mc.max_seq);
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");

        let wall0 = Instant::now();
        let sim0 = self.clock.now();
        let mut next_tok: Option<u32> = None;
        let mut peak_bytes = 0usize;
        for pos in 0..total {
            let tok = if pos < prompt.len() { tokens[pos] } else { next_tok.unwrap() };
            if pos >= prompt.len() {
                tokens.push(tok);
                generated.push(tok);
            }
            let mut ev = TokenEvents::default();
            let logits = self.step(tok, &mut kv, pos, &mut ev)?;
            events.push(ev);
            next_tok = Some(sampler.sample(&logits) as u32);
            let resident = self
                .cache
                .resident_bytes(mc.expert_bytes_f32())
                + KvState::bytes(&mc);
            peak_bytes = peak_bytes.max(resident);
        }

        let wall_s = wall0.elapsed().as_secs_f64();
        let sim_s = self.clock.now() - sim0;
        Ok(GenerationOutput {
            tokens,
            generated,
            trace: self.trace.clone(),
            events,
            throughput: Throughput { tokens: total as u64, wall_s, sim_s },
            cache_stats: self.cache.total_stats(),
            spec_pr: self.spec_pr,
            peak_resident_bytes: peak_bytes,
            transfer_bytes: self.transfer.stats.bytes,
        })
    }

    pub fn cache_stats(&self) -> crate::metrics::CacheStats {
        self.cache.total_stats()
    }
    /// Per-session attribution of the shared cache's traffic and of
    /// speculation quality (keyed by the id given to `step_session`).
    pub fn session_stats(&self) -> &HashMap<u64, SessionTally> {
        &self.session_stats
    }
    /// Copy of one session's tally (zeros if the session never stepped).
    pub fn session_tally(&self, session: u64) -> SessionTally {
        self.session_stats.get(&session).copied().unwrap_or_default()
    }
    /// Remove and return one session's tally (called when a serve session
    /// completes, so the map does not grow with request count).
    pub fn take_session_tally(&mut self, session: u64) -> SessionTally {
        self.session_stats.remove(&session).unwrap_or_default()
    }
    /// Demand lookups satisfied by another session's prefetch — how much
    /// the shared cache amortized speculative transfers across sessions.
    pub fn cross_session_prefetch_hits(&self) -> u64 {
        self.cross_session_prefetch_hits
    }
    pub fn spec_precision_recall(&self) -> PrecisionRecall {
        self.spec_pr
    }
    pub fn take_trace(&mut self) -> Option<Trace> {
        self.trace.take()
    }
    pub fn sim_now(&self) -> f64 {
        self.clock.now()
    }
}
